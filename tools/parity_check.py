#!/usr/bin/env python3
"""End-to-end parity: the Rust photon engine vs the Python oracle.

The engine's correctness contract (DESIGN.md §9/§13) is that
`rust/src/runtime/` bit-mirrors `python/compile/kernels/ref.py`: same
stateless counter RNG, same per-step op sequence, therefore *identical*
per-DOM hit counts (integers) and status counts, with float summaries
agreeing to fp32 accumulation noise.  This script actually checks that,
end to end:

  ref.propagate (jax)  <-- compare -->  `icecloud parity` (Rust binary)
                       <-- compare -->  tools/engine_mirror.py (numpy)

Modes:
  --impl bin     run the built `icecloud` binary (CI: the real check)
  --impl mirror  run the numpy mirror instead (no Rust toolchain needed;
                 also the right tool for bisecting a CI failure to
                 "physics/RNG" vs "Rust-specific")

Modes "scalar", "batched" (SoA walk, lane sweep off) and "simd" (SoA
walk, lane sweep on) are all held to the same bit-mirror contract —
the SIMD path ships default-on *because* this suite pins it to the
scalar hit counts exactly.

Checks per (variant, seed, mode):
  * per-DOM hits: exactly equal
  * detected/absorbed/alive/alive-step counts: exactly equal
  * path/hit-time sums: relative tolerance (accumulation order differs
    between the oracle's f32 block sums and the engine's f64 fold)

Exit code 0 = all comparisons passed.

Usage:
  python3 tools/parity_check.py --impl bin --icecloud target/release/icecloud
  python3 tools/parity_check.py --impl mirror --variants small,default
"""

import argparse
import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "python"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

FLOAT_RTOL = 5e-4  # path_sum / hit_time_sum (different accumulation order)


def ref_result(variant, seed):
    """(hits, summary) from the jax oracle for a geometry variant."""
    from compile import geometry
    from compile.kernels import ref

    v = geometry.VARIANTS[variant]
    source, media, doms, params = geometry.variant_inputs(v, seed=seed)
    hits, summary = ref.propagate(source, media, doms, params,
                                  v.num_photons, v.num_steps)
    return (np.asarray(hits).astype(np.int64),
            np.asarray(summary, dtype=np.float64))


def bin_result(icecloud, variant, seed, mode, threads, bunch):
    """(hits, summary) from the Rust engine via `icecloud parity`."""
    cmd = [icecloud, "parity", "--variant", variant, "--seed", str(seed),
           "--mode", mode, "--threads", str(threads), "--bunch", str(bunch)]
    proc = subprocess.run(cmd, capture_output=True, text=True, check=False)
    if proc.returncode != 0:
        raise RuntimeError(
            f"{' '.join(cmd)} failed ({proc.returncode}):\n{proc.stderr}")
    doc = json.loads(proc.stdout)
    return (np.asarray(doc["hits"]).astype(np.int64),
            np.asarray(doc["summary"], dtype=np.float64))


def mirror_result(variant, seed, mode, threads, bunch):
    """(hits, summary) from the numpy mirror of the Rust engine."""
    import engine_mirror

    hits, summary = engine_mirror.run(variant, seed, mode=mode,
                                      threads=threads, bunch=bunch)
    return hits.astype(np.int64), np.asarray(summary, dtype=np.float64)


def compare(label, ref, got, max_hit_moves=0):
    """Return a list of failure strings (empty = parity holds).

    `max_hit_moves` bounds the number of photons allowed to land on a
    different DOM (or flip detected/undetected).  The default 0 is the
    bit-mirror contract; a nonzero value exists purely as a diagnostic
    escape hatch should a platform's libm round one of the ~1e6
    transcendental evaluations differently — raise it in CI only with
    a comment citing the divergent (variant, seed, dom).
    """
    rhits, rsum = ref
    ghits, gsum = got
    fails = []
    if not np.array_equal(rhits, ghits):
        moved = int(np.abs(rhits - ghits).sum()) // 2 + abs(
            int(rhits.sum()) - int(ghits.sum()))
        diff = np.nonzero(rhits != ghits)[0]
        if moved > max_hit_moves:
            fails.append(
                f"{label}: per-DOM hits differ at doms {diff.tolist()[:8]} "
                f"(ref {rhits[diff].tolist()[:8]} vs "
                f"{ghits[diff].tolist()[:8]}; ~{moved} photon(s) moved, "
                f"allowed {max_hit_moves})")
        else:
            print(f"[parity] {label}: WARNING ~{moved} photon(s) moved "
                  f"(<= --max-hit-moves {max_hit_moves})")
    for idx, name in [(0, "detected"), (1, "absorbed"), (2, "alive"),
                      (5, "alive_steps")]:
        if int(rsum[idx]) != int(gsum[idx]):
            fails.append(f"{label}: {name} {int(rsum[idx])} != {int(gsum[idx])}")
    for idx, name in [(3, "path_sum"), (4, "hit_time_sum")]:
        denom = max(abs(rsum[idx]), 1.0)
        rel = abs(rsum[idx] - gsum[idx]) / denom
        if rel > FLOAT_RTOL:
            fails.append(
                f"{label}: {name} rel err {rel:.2e} > {FLOAT_RTOL:.0e} "
                f"({rsum[idx]} vs {gsum[idx]})")
    return fails


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--impl", choices=["bin", "mirror"], default="bin")
    ap.add_argument("--icecloud", default="target/release/icecloud",
                    help="path to the icecloud binary (--impl bin)")
    ap.add_argument("--variants", default="small,default")
    ap.add_argument("--seeds", default="0,1,7")
    ap.add_argument("--modes", default="scalar,batched,simd",
                    help="engine modes to check (passed straight to "
                         "`icecloud parity --mode` under --impl bin): "
                         "scalar, batched (lane sweep off), simd "
                         "(lane sweep on)")
    ap.add_argument("--threads", type=int, default=2,
                    help="engine threads for batched mode")
    ap.add_argument("--bunch", type=int, default=1000,
                    help="SoA bunch size for batched mode (odd sizes chop "
                         "bunches mid-range, which is the interesting case)")
    ap.add_argument("--max-hit-moves", type=int, default=0,
                    help="photons allowed to land on a different DOM "
                         "(0 = bit-mirror contract; see compare())")
    args = ap.parse_args()

    variants = [v for v in args.variants.split(",") if v]
    seeds = [int(s) for s in args.seeds.split(",") if s]
    modes = [m for m in args.modes.split(",") if m]

    failures = []
    checked = 0
    for variant in variants:
        for seed in seeds:
            ref = ref_result(variant, seed)
            for mode in modes:
                label = f"{variant}/seed{seed}/{mode}/{args.impl}"
                if args.impl == "bin":
                    got = bin_result(args.icecloud, variant, seed, mode,
                                     args.threads, args.bunch)
                else:
                    got = mirror_result(variant, seed, mode,
                                        args.threads, args.bunch)
                fails = compare(label, ref, got, args.max_hit_moves)
                checked += 1
                status = "FAIL" if fails else "ok"
                print(f"[parity] {label}: detected={int(ref[1][0])} {status}")
                failures.extend(fails)

    if failures:
        print(f"\n{len(failures)} parity failure(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"[parity] OK — {checked} comparisons, hits identical everywhere")
    return 0


if __name__ == "__main__":
    sys.exit(main())
