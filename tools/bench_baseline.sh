#!/usr/bin/env sh
# Regenerate the committed benchmark baseline (BENCH_pr<N>.json).
#
# Runs the sweep-scaling and photon-engine benches through the in-tree
# harness (util::bench) and collects their machine-readable BENCHJSON
# lines into one JSON-lines file: a `meta` line first, then one line per
# benchmark.  Usage:
#
#   tools/bench_baseline.sh [out-file]          # full sampling
#   ICECLOUD_BENCH_FAST=1 tools/bench_baseline.sh   # quick smoke pass
#
# Gate a fresh file against the committed trajectory with
#   tools/bench_compare.sh BENCH_pr10.json fresh.json
# or eyeball across PRs with e.g.:
#   jq -s 'map(select(.bench)) | .[] | {bench, mean_s, throughput}' BENCH_pr*.json
set -eu

out="${1:-BENCH_pr10.json}"
host="$(uname -sm 2>/dev/null || echo unknown)"
date="$(date -u +%Y-%m-%dT%H:%M:%SZ)"
rustc_v="$(rustc --version 2>/dev/null || echo unknown)"

tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT

for bench in sweep photon_engine serve; do
    echo "== cargo bench --bench $bench" >&2
    cargo bench --bench "$bench" 2>/dev/null \
        | sed -n "s/^BENCHJSON //p" >> "$tmp"
done

{
    printf '{"meta":{"file":"%s","generated":"%s","host":"%s","rustc":"%s","measured":true,"regenerate":"tools/bench_baseline.sh"}}\n' \
        "$out" "$date" "$host" "$rustc_v"
    cat "$tmp"
} > "$out"

echo "wrote $out ($(wc -l < "$out") lines)" >&2
