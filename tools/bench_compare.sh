#!/usr/bin/env sh
# Gate a fresh benchmark run against the committed trajectory.
#
#   tools/bench_compare.sh <committed BENCH_pr<N>.json> <fresh.json>
#
# Both files are JSON lines as written by tools/bench_baseline.sh: one
# `meta` line, then one line per benchmark ({bench, mean_s, throughput?,
# unit?, ...} from util::bench BENCHJSON output).  The gate fails when:
#
#   * a benchmark with measured baseline numbers regresses by more than
#     ICECLOUD_BENCH_TOL (default 0.25): throughput down >25%, or — for
#     the cold-replay latency bench — mean_s up >25%;
#   * a measured baseline benchmark disappeared from the fresh run
#     (renames must update the committed trajectory);
#   * the fresh run's batched photon engine is not at least
#     ICECLOUD_MIN_SPEEDUP (default 2.0) times the scalar walk —
#     the machine-independent claim of DESIGN.md §13, checked on
#     whatever runner executed the fresh benches;
#   * the fresh run's lane-sweep engine (engine/simd-1t) is not at
#     least ICECLOUD_MIN_SIMD_SPEEDUP (default 1.0) times the
#     loop-sweep engine (engine/batched-1t) — the SIMD fast path must
#     never be a slowdown (DESIGN.md §18);
#   * a *Rust-native* baseline line has null metrics.  Null lines used
#     to be skipped as "recorded schema"; that silently turned the
#     whole Rust gate off whenever the committed trajectory came from
#     a machine without a toolchain.  CI now seeds such a baseline
#     with fresh measurements first (see .github/workflows/ci.yml);
#     running against an unseeded null baseline is an error, not a
#     skip.  mirror/* lines keep the old skip-with-notice behaviour —
#     they come from a different harness and are never cross-compared.
set -eu

if [ $# -ne 2 ]; then
    echo "usage: tools/bench_compare.sh <committed.json> <fresh.json>" >&2
    exit 2
fi

python3 - "$1" "$2" "${ICECLOUD_BENCH_TOL:-0.25}" \
    "${ICECLOUD_MIN_SPEEDUP:-2.0}" \
    "${ICECLOUD_MIN_SIMD_SPEEDUP:-1.0}" <<'PYEOF'
import json
import sys

committed_path, fresh_path, tol_s, min_speedup_s, min_simd_s = sys.argv[1:6]
tol = float(tol_s)
min_speedup = float(min_speedup_s)
min_simd_speedup = float(min_simd_s)

# benches gated on latency (mean_s) as well as throughput
LATENCY_GATED = {"serve/sweep-cold-replay"}


def load(path):
    meta, benches = {}, {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            if "meta" in doc:
                meta = doc["meta"]
            elif "bench" in doc:
                benches[doc["bench"]] = doc
    return meta, benches


base_meta, base = load(committed_path)
_, fresh = load(fresh_path)
failures, skipped = [], 0

for name, b in sorted(base.items()):
    if b.get("mean_s") is None:
        if name.startswith("mirror/"):
            skipped += 1
            continue
        failures.append(
            f"{name}: baseline metrics are null — the committed "
            f"trajectory was never measured on a Rust-equipped machine. "
            f"Seed it first (run tools/bench_baseline.sh and merge, as "
            f"CI's bench-baseline job does) instead of gating against "
            f"nothing.")
        continue
    f = fresh.get(name)
    if f is None:
        # mirror/* lines come from the Python harness
        # (tools/bench_mirror.py); a Rust-native fresh run will not
        # have them, and cross-harness numbers must never be compared
        if name.startswith("mirror/"):
            skipped += 1
            continue
        failures.append(f"{name}: in {committed_path} but missing from "
                        f"the fresh run (rename the trajectory entry too)")
        continue
    btp, ftp = b.get("throughput"), f.get("throughput")
    if btp and ftp is not None:
        floor = btp * (1.0 - tol)
        if ftp < floor:
            failures.append(
                f"{name}: throughput {ftp:.3g} {f.get('unit', '')}/s < "
                f"{floor:.3g} (baseline {btp:.3g}, tol {tol:.0%})")
    if name in LATENCY_GATED and f.get("mean_s") is not None:
        ceil = b["mean_s"] * (1.0 + tol)
        if f["mean_s"] > ceil:
            failures.append(
                f"{name}: mean {f['mean_s']:.4g}s > {ceil:.4g}s "
                f"(baseline {b['mean_s']:.4g}s, tol {tol:.0%})")

# machine-independent speedup gate, evaluated on the fresh run alone
scalar = fresh.get("engine/scalar", {}).get("throughput")
batched = [(n, f["throughput"]) for n, f in fresh.items()
           if n.startswith("engine/batched-") and f.get("throughput")]
if scalar is None or not batched:
    failures.append("fresh run is missing engine/scalar or engine/batched-* "
                    "benches (cargo bench --bench sweep emits them)")
else:
    best_name, best = max(batched, key=lambda kv: kv[1])
    ratio = best / scalar
    verdict = "ok" if ratio >= min_speedup else "FAIL"
    print(f"[bench-compare] speedup: {best_name} {best:.3g} photons/s vs "
          f"engine/scalar {scalar:.3g} -> {ratio:.2f}x "
          f"(need >= {min_speedup}x) {verdict}")
    if ratio < min_speedup:
        failures.append(
            f"batched engine speedup {ratio:.2f}x < required {min_speedup}x")

# SIMD-sweep gate: the default-on lane path must not be a slowdown
simd = fresh.get("engine/simd-1t", {}).get("throughput")
loop = fresh.get("engine/batched-1t", {}).get("throughput")
if simd is None or loop is None:
    failures.append("fresh run is missing engine/simd-1t or "
                    "engine/batched-1t (cargo bench --bench sweep emits "
                    "both sweep variants)")
else:
    ratio = simd / loop
    verdict = "ok" if ratio >= min_simd_speedup else "FAIL"
    print(f"[bench-compare] simd sweep: engine/simd-1t {simd:.3g} vs "
          f"engine/batched-1t {loop:.3g} -> {ratio:.2f}x "
          f"(need >= {min_simd_speedup}x) {verdict}")
    if ratio < min_simd_speedup:
        failures.append(
            f"simd sweep speedup {ratio:.2f}x < required "
            f"{min_simd_speedup}x (set ICECLOUD_MIN_SIMD_SPEEDUP to tune)")

print(f"[bench-compare] {len(base)} baseline entries, {skipped} unmeasured "
      f"(skipped), {len(failures)} failure(s)")
for msg in failures:
    print(f"  FAIL {msg}", file=sys.stderr)
sys.exit(1 if failures else 0)
PYEOF
