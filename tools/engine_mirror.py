"""Strict-fp32 numpy mirror of the Rust photon engine (scalar + batched SoA).

This module exists because the Rust engine's correctness contract is
cross-language: `rust/src/runtime/engine.rs` (scalar walk) and
`rust/src/runtime/batch.rs` (batched SoA walk) both claim bit-identical
per-DOM hit counts with the jax oracle `python/compile/kernels/ref.py`.
The mirror implements the *Rust* op sequence in numpy float32, which lets
a machine without a Rust toolchain (or a CI debugging session) check all
three implementations against each other:

  jax ref (`ref.propagate`)  <-- parity_check.py -->  this mirror
                                    \\-- parity_check.py --> `icecloud parity`

Semantics mirrored from the Rust engine:

* stateless counter RNG: two lowbias32 rounds over
  ``seed ^ pid*K_PID ^ step*K_STEP ^ stream*K_STREAM`` (uint32 wrap),
  top 24 bits scaled by 2^-24;
* per-step walk: step length, segment-DOM closest approach (earliest
  hit wins, ties to the lowest DOM index), absorption, HG scatter;
* per-photon outcomes (status, dom, f64 path, f64 hit time, steps)
  reduced to the summary by a sequential fold in photon-id order, which
  is what makes the batched engine bit-identical across bunch sizes and
  thread counts.

Pure Python loops are used for the scalar walk (slow, reference only)
and vectorized numpy for the batched walk (the SoA algorithm, including
order-preserving compaction and lazy scatter draws).
"""

import math

import numpy as np

F = np.float32
TWO_PI = F(2.0 * math.pi)
INV_2_24 = F(1.0 / (1 << 24))

K_PID = 0x9E3779B9
K_STEP = 0x85EBCA6B
K_STREAM = 0xC2B2AE35
U32 = 0xFFFFFFFF

STREAM_LEN = 0
STREAM_ABSORB = 1
STREAM_COS = 2
STREAM_PHI = 3
STREAM_INIT_COS = 4
STREAM_INIT_PHI = 5

# Variant shape table mirrored from python/compile/geometry.py VARIANTS
# (and from the `icecloud parity` built-in table).
VARIANTS = {
    "small": dict(num_photons=256, num_doms=16, num_steps=16, num_layers=10),
    "default": dict(num_photons=4096, num_doms=60, num_steps=64, num_layers=10),
    "large": dict(num_photons=16384, num_doms=240, num_steps=96, num_layers=10),
}


# ---- counter RNG ------------------------------------------------------------

def _mix32_int(x):
    x ^= x >> 16
    x = (x * 0x7FEB352D) & U32
    x ^= x >> 15
    x = (x * 0x846CA68B) & U32
    x ^= x >> 16
    return x


def uniform_scalar(seed, pid, step, stream):
    """One uniform, via exact Python-int u32 arithmetic."""
    key = (seed ^ (pid * K_PID) ^ (step * K_STEP) ^ (stream * K_STREAM)) & U32
    v = _mix32_int(_mix32_int(key))
    return F(v >> 8) * INV_2_24


def _mix32_vec(x):
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


def uniform_vec(seed, pid, step, stream):
    """Vector of uniforms for a uint32 pid array (bitwise == scalar)."""
    key = (np.uint32(seed)
           ^ (pid * np.uint32(K_PID))
           ^ np.uint32((step * K_STEP) & U32)
           ^ np.uint32((stream * K_STREAM) & U32))
    v = _mix32_vec(_mix32_vec(key))
    return (v >> np.uint32(8)).astype(np.float32) * INV_2_24


# ---- inputs (mirror of rust/src/runtime/artifact.rs build_inputs) -----------

def build_inputs(variant, seed, dusty=True):
    """(source, media, doms, params) float32 arrays for a variant name."""
    v = VARIANTS[variant]
    num_doms = v["num_doms"]
    num_layers = v["num_layers"]
    spacing = F(17.0)
    if num_doms <= 80:
        doms = np.zeros((num_doms, 3), dtype=np.float32)
        doms[:, 2] = -spacing * np.arange(num_doms, dtype=np.float32)
    else:
        per = num_doms // 4
        pitch = F(125.0)
        parts = []
        for ix in range(2):
            for iy in range(2):
                s = np.zeros((per, 3), dtype=np.float32)
                s[:, 0] = F(ix) * pitch - pitch / F(2.0)
                s[:, 1] = F(iy) * pitch - pitch / F(2.0)
                s[:, 2] = -spacing * np.arange(per, dtype=np.float32)
                parts.append(s)
        doms = np.concatenate(parts, axis=0)[:num_doms]
    media = np.zeros((num_layers, 4), dtype=np.float32)
    media[:, 0] = 25.0
    media[:, 1] = 100.0
    media[:, 2] = 0.9
    if dusty and num_layers >= 3:
        mid = num_layers // 2
        media[mid, 0] = 5.0
        media[mid, 1] = 20.0
    depth_span = spacing * F(num_doms + 4.0)
    params = np.zeros(8, dtype=np.float32)
    params[0] = F(0.16510) * F(12.0)
    params[1] = 40.0
    params[2] = depth_span / F(10.0)
    params[3] = F(0.299792458) / F(1.35)
    params[4] = 1e-7
    mid_z = F(np.float32(doms[:, 2].sum()) / F(num_doms))
    source = np.zeros(8, dtype=np.float32)
    source[0] = 10.0
    source[2] = mid_z
    source[7] = F(seed)
    return source, media, doms, params


# ---- scalar walk (mirror of engine.rs walk_photon) --------------------------

def _hg_cos_theta(g, u):
    if abs(g) < F(1e-3):
        return min(max(F(1.0) - F(2.0) * u, F(-1.0)), F(1.0))
    frac = (F(1.0) - g * g) / (F(1.0) - g + F(2.0) * g * u)
    val = (F(1.0) + g * g - frac * frac) / (F(2.0) * g)
    return min(max(val, F(-1.0)), F(1.0))


def _rotate_dir(d, cos_t, phi):
    sign = F(1.0) if d[2] >= F(0.0) else F(-1.0)
    a = F(-1.0) / (sign + d[2])
    b = d[0] * d[1] * a
    b1 = [F(1.0) + sign * d[0] * d[0] * a, sign * b, -sign * d[0]]
    b2 = [b, sign + d[1] * d[1] * a, -d[1]]
    sin_t = np.sqrt(max(F(1.0) - cos_t * cos_t, F(0.0)))
    sp, cp = np.sin(phi), np.cos(phi)
    nd = [sin_t * cp * b1[i] + sin_t * sp * b2[i] + cos_t * d[i]
          for i in range(3)]
    norm = max(np.sqrt(nd[0] * nd[0] + nd[1] * nd[1] + nd[2] * nd[2]),
               F(1e-12))
    return [nd[0] / norm, nd[1] / norm, nd[2] / norm]


def scalar_outcomes(source, media, doms, params, num_photons, num_steps):
    """Per-photon outcomes from the scalar reference walk.

    Returns dict of arrays indexed by photon id: status (0 alive,
    1 absorbed, 2 detected), dom (-1 when undetected), path (f64),
    hit_time (f64), steps (int).
    """
    num_doms = doms.shape[0]
    num_layers = media.shape[0]
    seed = int(source[7])
    r2 = params[0] * params[0]
    z0, dz, v_group, eps = params[1], params[2], params[3], params[4]
    status = np.zeros(num_photons, dtype=np.int8)
    dom = np.full(num_photons, -1, dtype=np.int64)
    path = np.zeros(num_photons, dtype=np.float64)
    hit_time = np.zeros(num_photons, dtype=np.float64)
    steps = np.zeros(num_photons, dtype=np.int64)
    for p in range(num_photons):
        pos = [source[0], source[1], source[2]]
        t = source[6]
        u_cos = uniform_scalar(seed, p, 0, STREAM_INIT_COS)
        u_phi = uniform_scalar(seed, p, 0, STREAM_INIT_PHI)
        cos_t = F(1.0) - F(2.0) * u_cos
        sin_t = np.sqrt(max(F(1.0) - cos_t * cos_t, F(0.0)))
        phi = TWO_PI * u_phi
        dire = [sin_t * np.cos(phi), sin_t * np.sin(phi), cos_t]
        st = 0
        for k in range(num_steps):
            if st != 0:
                break
            steps[p] += 1
            li = int(np.floor((z0 - pos[2]) / dz))
            li = min(max(li, 0), num_layers - 1)
            lam_s, lam_a, g = media[li, 0], media[li, 1], media[li, 2]
            u_len = uniform_scalar(seed, p, k, STREAM_LEN)
            d = -lam_s * np.log(max(u_len, eps))
            best_t, best_dom = F(np.inf), -1
            for di in range(num_doms):
                rel = [doms[di, 0] - pos[0], doms[di, 1] - pos[1],
                       doms[di, 2] - pos[2]]
                ta = rel[0] * dire[0] + rel[1] * dire[1] + rel[2] * dire[2]
                ta = min(max(ta, F(0.0)), d)
                diff = [rel[i] - ta * dire[i] for i in range(3)]
                dist2 = (diff[0] * diff[0] + diff[1] * diff[1]
                         + diff[2] * diff[2])
                if dist2 <= r2 and ta < best_t:
                    best_t, best_dom = ta, di
            if best_dom >= 0:
                st = 2
                dom[p] = best_dom
                hit_time[p] = float(t + best_t / v_group)
                path[p] += float(best_t)
                break
            for i in range(3):
                pos[i] = pos[i] + dire[i] * d
            t = t + d / v_group
            path[p] += float(d)
            u_abs = uniform_scalar(seed, p, k, STREAM_ABSORB)
            if not (u_abs < np.exp(-d / lam_a)):
                st = 1
                break
            u_cos = uniform_scalar(seed, p, k, STREAM_COS)
            u_phi = uniform_scalar(seed, p, k, STREAM_PHI)
            cos_s = _hg_cos_theta(g, u_cos)
            dire = _rotate_dir(dire, cos_s, TWO_PI * u_phi)
        status[p] = st
    return dict(status=status, dom=dom, path=path, hit_time=hit_time,
                steps=steps)


# ---- batched SoA walk (mirror of batch.rs walk_bunch) -----------------------

def _hg_cos_theta_vec(g, u):
    iso = np.clip(F(1.0) - F(2.0) * u, F(-1.0), F(1.0))
    g_safe = np.where(np.abs(g) < F(1e-3), F(1.0), g)
    frac = (F(1.0) - g_safe * g_safe) / (F(1.0) - g_safe + F(2.0) * g_safe * u)
    hg = (F(1.0) + g_safe * g_safe - frac * frac) / (F(2.0) * g_safe)
    return np.where(np.abs(g) < F(1e-3), iso, np.clip(hg, F(-1.0), F(1.0)))


def _rotate_dir_vec(dx, dy, dz, cos_t, phi):
    sign = np.where(dz >= F(0.0), F(1.0), F(-1.0))
    a = F(-1.0) / (sign + dz)
    b = dx * dy * a
    b1 = (F(1.0) + sign * dx * dx * a, sign * b, -sign * dx)
    b2 = (b, sign + dy * dy * a, -dy)
    sin_t = np.sqrt(np.maximum(F(1.0) - cos_t * cos_t, F(0.0)))
    sp, cp = np.sin(phi), np.cos(phi)
    nx = sin_t * cp * b1[0] + sin_t * sp * b2[0] + cos_t * dx
    ny = sin_t * cp * b1[1] + sin_t * sp * b2[1] + cos_t * dy
    nz = sin_t * cp * b1[2] + sin_t * sp * b2[2] + cos_t * dz
    norm = np.maximum(np.sqrt(nx * nx + ny * ny + nz * nz), F(1e-12))
    return nx / norm, ny / norm, nz / norm


# DOM rows per block of the "simd" sweep (mirror of the Rust lane sweep
# in rust/src/runtime/simd.rs, transposed: Rust blocks photons into
# LANES-wide vectors, the numpy mirror blocks DOMs into 2-D broadcasts —
# both evaluate the identical per-(dom, photon) f32 op sequence, so both
# are bit-identical to the per-DOM loop).
DOM_BLOCK = 32


def _sweep_doms_loop(doms, px, py, pz, dx, dy, dz_, d, r2):
    """Pass B, per-DOM loop (mirror of batch.rs SimdMode::Off)."""
    n = px.shape[0]
    best_t = np.full(n, np.inf, dtype=np.float32)
    best_dom = np.full(n, -1, dtype=np.int64)
    for di in range(doms.shape[0]):
        relx = doms[di, 0] - px
        rely = doms[di, 1] - py
        relz = doms[di, 2] - pz
        ta = relx * dx + rely * dy + relz * dz_
        ta = np.minimum(np.maximum(ta, F(0.0)), d)
        ex = relx - ta * dx
        ey = rely - ta * dy
        ez = relz - ta * dz_
        dist2 = ex * ex + ey * ey + ez * ez
        better = (dist2 <= r2) & (ta < best_t)
        best_t = np.where(better, ta, best_t)
        best_dom = np.where(better, di, best_dom)
    return best_t, best_dom


def _sweep_doms_blocked(doms, px, py, pz, dx, dy, dz_, d, r2):
    """Pass B, blocked 2-D sweep (mirror of batch.rs SimdMode::Lanes).

    Each block broadcasts DOM_BLOCK doms against every photon at once;
    the per-(dom, photon) arithmetic is elementwise-identical to the
    loop form.  Tie-breaking is preserved exactly: ``argmin`` returns
    the *first* (lowest) dom index of the block minimum, and blocks
    merge in ascending order under strict ``<`` — together that is the
    sequential sweep's "earliest hit wins, ties to the lowest DOM
    index" rule, bit for bit.
    """
    n = px.shape[0]
    best_t = np.full(n, np.inf, dtype=np.float32)
    best_dom = np.full(n, -1, dtype=np.int64)
    cols = np.arange(n)
    inf = np.float32(np.inf)
    for d0 in range(0, doms.shape[0], DOM_BLOCK):
        blk = doms[d0:d0 + DOM_BLOCK]
        relx = blk[:, 0:1] - px[None, :]
        rely = blk[:, 1:2] - py[None, :]
        relz = blk[:, 2:3] - pz[None, :]
        ta = relx * dx[None, :] + rely * dy[None, :] + relz * dz_[None, :]
        ta = np.minimum(np.maximum(ta, F(0.0)), d[None, :])
        ex = relx - ta * dx[None, :]
        ey = rely - ta * dy[None, :]
        ez = relz - ta * dz_[None, :]
        dist2 = ex * ex + ey * ey + ez * ez
        masked = np.where(dist2 <= r2, ta, inf)
        arg = masked.argmin(axis=0)
        blockmin = masked[arg, cols]
        better = blockmin < best_t
        best_t = np.where(better, blockmin, best_t)
        best_dom = np.where(better, d0 + arg, best_dom)
    return best_t, best_dom


SWEEPS = {"loop": _sweep_doms_loop, "blocked": _sweep_doms_blocked}


def _walk_bunch(source, media, doms, params, num_steps, pid0, m, out,
                sweep="loop"):
    """Walk photons [pid0, pid0+m) in one SoA bunch; fill `out` arrays."""
    num_layers = media.shape[0]
    seed = int(source[7])
    r2 = params[0] * params[0]
    z0, dz, v_group, eps = params[1], params[2], params[3], params[4]

    pid = np.uint32(pid0) + np.arange(m, dtype=np.uint32)
    px = np.full(m, source[0], dtype=np.float32)
    py = np.full(m, source[1], dtype=np.float32)
    pz = np.full(m, source[2], dtype=np.float32)
    t = np.full(m, source[6], dtype=np.float32)
    path = np.zeros(m, dtype=np.float64)

    u_cos = uniform_vec(seed, pid, 0, STREAM_INIT_COS)
    u_phi = uniform_vec(seed, pid, 0, STREAM_INIT_PHI)
    cos_t = F(1.0) - F(2.0) * u_cos
    sin_t = np.sqrt(np.maximum(F(1.0) - cos_t * cos_t, F(0.0)))
    phi = TWO_PI * u_phi
    dx, dy, dz_ = sin_t * np.cos(phi), sin_t * np.sin(phi), cos_t

    for k in range(num_steps):
        n = pid.shape[0]
        if n == 0:
            break
        li = np.clip(np.floor((z0 - pz) / dz).astype(np.int64), 0,
                     num_layers - 1)
        lam_s, lam_a, g = media[li, 0], media[li, 1], media[li, 2]
        u_len = uniform_vec(seed, pid, k, STREAM_LEN)
        d = -lam_s * np.log(np.maximum(u_len, eps))

        best_t, best_dom = SWEEPS[sweep](doms, px, py, pz, dx, dy, dz_,
                                         d, r2)

        detected = best_dom >= 0
        slots = (pid - np.uint32(pid0)).astype(np.int64)
        dslots = slots[detected]
        out["status"][dslots] = 2
        out["dom"][dslots] = best_dom[detected]
        out["hit_time"][dslots] = (t[detected]
                                   + best_t[detected] / v_group).astype(
                                       np.float64)
        out["path"][dslots] = path[detected] + best_t[detected].astype(
            np.float64)
        out["steps"][dslots] = k + 1

        # survivors of the DOM sweep move the full step
        live = ~detected
        px = px + dx * d
        py = py + dy * d
        pz = pz + dz_ * d
        t = t + d / v_group
        path = path + d.astype(np.float64)

        u_abs = uniform_vec(seed, pid, k, STREAM_ABSORB)
        survived = u_abs < np.exp(-d / lam_a)
        absorbed = live & ~survived
        aslots = slots[absorbed]
        out["status"][aslots] = 1
        out["path"][aslots] = path[absorbed]
        out["steps"][aslots] = k + 1

        alive = live & survived
        u_cos = uniform_vec(seed, pid, k, STREAM_COS)
        u_phi = uniform_vec(seed, pid, k, STREAM_PHI)
        cos_s = _hg_cos_theta_vec(g, u_cos)
        ndx, ndy, ndz = _rotate_dir_vec(dx, dy, dz_, cos_s,
                                        TWO_PI * u_phi)
        dx = np.where(alive, ndx, dx)
        dy = np.where(alive, ndy, dy)
        dz_ = np.where(alive, ndz, dz_)

        # order-preserving compaction of terminated photons
        pid = pid[alive]
        px, py, pz = px[alive], py[alive], pz[alive]
        dx, dy, dz_ = dx[alive], dy[alive], dz_[alive]
        t, path = t[alive], path[alive]

    slots = (pid - np.uint32(pid0)).astype(np.int64)
    out["status"][slots] = 0
    out["path"][slots] = path
    out["steps"][slots] = num_steps


def empty_outcomes(num_photons):
    """Allocate the outcome arrays one bunch execution fills."""
    return dict(
        status=np.zeros(num_photons, dtype=np.int8),
        dom=np.full(num_photons, -1, dtype=np.int64),
        path=np.zeros(num_photons, dtype=np.float64),
        hit_time=np.zeros(num_photons, dtype=np.float64),
        steps=np.zeros(num_photons, dtype=np.int64),
    )


def chunk_ranges(num_photons, threads):
    """Contiguous (start, size) pid ranges, first remainder one larger —
    the same split rule as `batch.rs`."""
    threads = max(1, min(threads, num_photons or 1))
    base, rem = divmod(num_photons, threads)
    ranges, start = [], 0
    for c in range(threads):
        size = base + (1 if c < rem else 0)
        ranges.append((start, size))
        start += size
    return ranges


def walk_chunk(source, media, doms, params, num_steps, start, size, bunch,
               out, sweep="loop"):
    """Walk photons [start, start+size) in SoA sub-bunches into `out`
    (disjoint slices per chunk, so chunks may run concurrently)."""
    bunch = max(1, bunch)
    pid = start
    while pid < start + size:
        m = min(bunch, start + size - pid)
        sub = {key: arr[pid:pid + m] for key, arr in out.items()}
        _walk_bunch(source, media, doms, params, num_steps, pid, m, sub,
                    sweep=sweep)
        pid += m


def batched_outcomes(source, media, doms, params, num_photons, num_steps,
                     threads=1, bunch=4096, sweep="loop"):
    """Per-photon outcomes from the batched SoA walk.

    `threads` here only selects the chunk split (the mirror runs the
    chunks sequentially); photon independence is what makes the Rust
    engine's parallel execution bit-identical to this.  `sweep` picks
    the pass-B kernel: "loop" (per-DOM, SimdMode::Off) or "blocked"
    (2-D broadcast, SimdMode::Lanes) — bit-identical by construction.
    """
    out = empty_outcomes(num_photons)
    for start, size in chunk_ranges(num_photons, threads):
        walk_chunk(source, media, doms, params, num_steps, start, size,
                   bunch, out, sweep=sweep)
    return out


# ---- reduction (mirror of engine.rs reduce_outcomes) ------------------------

def reduce_outcomes(out, num_doms):
    """(hits int64[D], summary f32[8]) via the pid-ordered sequential fold."""
    hits = np.zeros(num_doms, dtype=np.int64)
    for d in out["dom"]:
        if d >= 0:
            hits[d] += 1
    n_det = int((out["status"] == 2).sum())
    n_abs = int((out["status"] == 1).sum())
    n_alive = int((out["status"] == 0).sum())
    path_sum = 0.0
    hit_time_sum = 0.0
    for p in out["path"]:
        path_sum += float(p)
    for h in out["hit_time"]:
        hit_time_sum += float(h)
    steps = int(out["steps"].sum())
    summary = np.array([n_det, n_abs, n_alive, path_sum, hit_time_sum,
                        steps, 0.0, 0.0], dtype=np.float32)
    return hits, summary


def run(variant, seed, mode="batched", threads=1, bunch=4096, dusty=True):
    """hits/summary for a named variant (the parity_check entry point).

    Modes mirror `icecloud parity --mode`: "scalar" (per-photon walk),
    "batched" (SoA walk, per-DOM sweep = SimdMode::Off) and "simd"
    (SoA walk, blocked sweep = SimdMode::Lanes).
    """
    v = VARIANTS[variant]
    source, media, doms, params = build_inputs(variant, seed, dusty)
    if mode == "scalar":
        out = scalar_outcomes(source, media, doms, params,
                              v["num_photons"], v["num_steps"])
    else:
        sweep = "blocked" if mode == "simd" else "loop"
        out = batched_outcomes(source, media, doms, params,
                               v["num_photons"], v["num_steps"],
                               threads=threads, bunch=bunch, sweep=sweep)
    return reduce_outcomes(out, v["num_doms"])
