#!/usr/bin/env python3
"""Independent generator for the canonical-serialization golden fixture.

Rebuilds the byte-exact canonical compact JSON the Rust side must emit
for a handful of pinned configs — WITHOUT going through the Rust code —
and writes `rust/tests/golden/canonical_v2.json`.  The golden test
(`rust/tests/golden_canonical.rs`) compares `CampaignConfig`/
`ScenarioConfig::canonical_json().to_string_compact()` and the sweep
cache key against this fixture, so a byte change in the canonical form
fails CI unless the canonical version tag is bumped and this fixture is
regenerated on purpose.

The serializer here mirrors `rust/src/util/json.rs` exactly:
  * object keys sorted (BTreeMap iteration order),
  * compact output (no whitespace),
  * `write_num`: integral finite floats with |v| < 9e15 print as i64
    ("58000", not "58000.0"); other finite floats print via Rust's
    shortest-round-trip `{}` formatting, which agrees with Python repr
    for every value used below.
"""

import hashlib
import json
import os

OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "rust",
    "tests",
    "golden",
    "canonical_v2.json",
)

DAY = 86_400
HOUR = 3_600
MINUTE = 60


def fmt_num(v):
    """Mirror util::json::write_num."""
    f = float(v)
    if f != f or f in (float("inf"), float("-inf")):
        return "null"
    if f == int(f) and abs(f) < 9e15:
        return str(int(f))
    r = repr(f)
    assert float(r) == f, r
    return r


def compact(j):
    """Mirror Json::to_string_compact (sorted keys, no spaces)."""
    if j is None:
        return "null"
    if j is True:
        return "true"
    if j is False:
        return "false"
    if isinstance(j, str):
        assert all(c not in j for c in '"\\\n\r\t'), j
        return '"' + j + '"'
    if isinstance(j, (int, float)):
        return fmt_num(j)
    if isinstance(j, list):
        return "[" + ",".join(compact(x) for x in j) + "]"
    if isinstance(j, dict):
        return (
            "{"
            + ",".join(
                '"' + k + '":' + compact(j[k]) for k in sorted(j)
            )
            + "}"
        )
    raise TypeError(type(j))


def ramp_step(target, hold_s):
    return {"target": target, "hold_s": hold_s}


def campaign_default():
    """CampaignConfig::default().canonical_json() (config/scenario.rs)."""
    return {
        "v": 2,
        "seed": 20210921,
        "duration_s": 14 * DAY,
        "tick_s": MINUTE,
        "sample_every_s": 10 * MINUTE,
        "control_period_s": 5 * MINUTE,
        "negotiation_period_s": 5 * MINUTE,
        "budget_usd": 58_000.0,
        "alert_thresholds": [0.75, 0.5, 0.25, 0.1],
        "overhead_fraction": 0.18,
        "budget_reserve_fraction": 0.02,
        "low_budget_resume_fraction": 0.25,
        "post_outage_target": 1000,
        "keepalive_s": 60,
        "preempt_multiplier": 1.0,
        "nat_override": "provider-default",
        "checkpoint": "none",
        # gpu_slots_per_instance / checkpoint_size_gb /
        # checkpoint_transfer_mbps are at their defaults and therefore
        # OMITTED — that omission is itself part of the golden contract
        # (pre-PR-10 cache keys must not move).
        "ramp": [
            ramp_step(50, DAY),
            ramp_step(400, 2 * DAY),
            ramp_step(900, 2 * DAY),
            ramp_step(1200, 2 * DAY),
            ramp_step(1600, 2 * DAY),
            ramp_step(2000, 30 * DAY),
        ],
        "outage": {"at_s": 11 * DAY + 6 * HOUR, "duration_s": 2 * HOUR},
        "policy": {"fixed": {"aws": 0.15, "gcp": 0.15, "azure": 0.7}},
        "onprem": {
            "slots": 1150,
            "keepalive_s": 300,
            "availability": 0.97,
        },
        "generator": {
            "backlog_factor": 1.5,
            "min_backlog": 500,
            "request_memory_mb": 8192,
            "runtimes": {
                "median_s": 3600.0,
                "sigma": 0.45,
                "min_s": 600,
                "max_s": 4 * 3600,
            },
        },
        "flops_per_bunch": 1.2e10,
        "real_compute": None,
    }


def campaign_new_knobs():
    """Default campaign with the three PR-10 knobs off their defaults."""
    c = campaign_default()
    c["gpu_slots_per_instance"] = 4
    c["checkpoint_size_gb"] = 2.5
    c["checkpoint_transfer_mbps"] = 500.0
    return c


def scenario_bare():
    """`[scenario.bare]` with no overrides: name only."""
    return {"name": "bare"}


def scenario_full():
    """Every scenario override set (the spec in golden_canonical.rs)."""
    return {
        "name": "full",
        "seed": 7,
        "duration_s": int(2.5 * DAY),
        "budget_usd": 29_000.0,
        "preempt_multiplier": 4.0,
        "keepalive_s": 300,
        "nat_override": {"idle_timeout_s": 120},
        "outage": {"at_s": int(1.5 * DAY), "duration_s": 6 * HOUR},
        "ramp": [ramp_step(100, DAY), ramp_step(200, int(0.5 * DAY))],
        "onprem_slots": 10,
        "policy": "risk-aware",
        "checkpoint": {
            "interval": {"every_s": 900, "resume_overhead_s": 30}
        },
        "gpu_slots_per_instance": 4,
        "checkpoint_size_gb": 2.5,
        "checkpoint_transfer_mbps": 500.0,
    }


def main():
    base = compact(campaign_default())
    bare = compact(scenario_bare())
    key_doc = '{"base":' + base + ',"scenarios":[' + bare + "]}"
    fixture = {
        "canonical_version": 2,
        "campaign_default": base,
        "campaign_new_knobs": compact(campaign_new_knobs()),
        "scenario_bare": bare,
        "scenario_full": compact(scenario_full()),
        "sweep_key_default_bare": hashlib.sha256(
            key_doc.encode()
        ).hexdigest(),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(fixture, f, indent=2, sort_keys=True)
        f.write("\n")
    print("wrote", OUT)
    for k, v in sorted(fixture.items()):
        print(f"  {k}: {str(v)[:80]}{'...' if len(str(v)) > 80 else ''}")


if __name__ == "__main__":
    main()
