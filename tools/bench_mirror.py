#!/usr/bin/env python3
"""Measure the numpy engine mirror and emit a benchmark-trajectory file.

The benchmark trajectory (BENCH_pr<N>.json at the repo root) is normally
produced by `tools/bench_baseline.sh` from the Rust benches.  On a
machine without a Rust toolchain — like the container this repository is
grown in — this script provides the honest fallback: it measures the
*numpy mirror* of the same engines (tools/engine_mirror.py, the code
`tools/parity_check.py` pins against the jax oracle), clearly labels
the lines `mirror/...`, and writes the Rust bench names with null
metrics as recorded schema, exactly like BENCH_pr2.json did.

The mirror numbers are real measurements of the same algorithms (scalar
per-photon walk vs batched SoA with compaction, chunked over threads) —
they demonstrate the batching claim — but they are *Python* numbers: do
not compare them against Rust-native lines across files.  CI's
bench-baseline job regenerates Rust-native numbers on every push and
`tools/bench_compare.sh` gates the batched>=2x-scalar claim there.

Usage:
  python3 tools/bench_mirror.py --out BENCH_pr3.json --pr 3
"""

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import engine_mirror as em

# Rust bench names whose schema is recorded (null until a Rust-equipped
# machine or the CI artifact fills them in).
RUST_BENCHES = [
    # the builtin matrix grew to 14 scenarios in PR 5; the bench name
    # derives from the matrix length (rust/benches/sweep.rs)
    ("sweep/14-scenarios-1-threads", "replays"),
    ("sweep/14-scenarios-2-threads", "replays"),
    ("sweep/14-scenarios-4-threads", "replays"),
    ("sweep/14-scenarios-8-threads", "replays"),
    # PR 9: [grid] cartesian expansion of the 3-axis {4,4,4} spec
    ("sweep/grid-expand-64", "scenarios"),
    # PR 10: the registry-backed axes — a 64-value slot carve-up sweep
    # and the 1x8x8 checkpoint-transfer plane (rust/benches/sweep.rs)
    ("sweep/grid-expand-gpu-slots-64", "scenarios"),
    ("sweep/grid-expand-checkpoint-transfer-64", "scenarios"),
    ("engine/scalar", "photons"),
    ("engine/batched-1t", "photons"),
    ("engine/batched-2t", "photons"),
    ("engine/batched-4t", "photons"),
    # PR 8: the lane-based SIMD sweep (SimdMode::Lanes, the default)
    ("engine/simd-1t", "photons"),
    ("engine/simd-2t", "photons"),
    ("engine/simd-4t", "photons"),
    ("photon/small-bunch", "photons"),
    ("photon/small-bunch-mt", "photons"),
    ("photon/default-bunch", "photons"),
    ("photon/default-bunch-mt", "photons"),
    ("photon/large-bunch", "photons"),
    ("photon/large-bunch-mt", "photons"),
    # PR 8: same bunch walk with the lane sweep forced off
    ("photon/small-bunch-scalar-sweep", "photons"),
    ("photon/default-bunch-scalar-sweep", "photons"),
    ("photon/large-bunch-scalar-sweep", "photons"),
    ("photon/compile-small", None),
    ("serve/sweep-cold-replay", "requests"),
    ("serve/sweep-cached", "requests"),
    ("serve/disk-hit", "requests"),
    ("serve/async-submit", "requests"),
    # PR 9: cached 64-cell [grid] POST — expansion + keying on the
    # request path
    ("serve/grid-submit", "requests"),
    # PR 6: cold sweeps dispatched over the lease/heartbeat protocol
    ("serve/fleet-2w", "requests"),
    # PR 7: event-bus publish rate with zero / four live SSE streams
    ("serve/events-stream-0sub", "events"),
    ("serve/events-stream-4sub", "events"),
]


def bench_line(name, samples, work=None, unit=None):
    samples = sorted(samples)
    n = len(samples)
    mean = sum(samples) / n
    var = sum((s - mean) ** 2 for s in samples) / n
    line = {
        "bench": name,
        "mean_s": mean,
        "std_s": var ** 0.5,
        "p50_s": samples[n // 2],
        "p95_s": samples[min(n - 1, int(0.95 * n))],
        "samples": n,
    }
    if work is not None:
        line["throughput"] = work / mean
        line["unit"] = unit
    return line


def time_runs(fn, runs):
    out = []
    for _ in range(runs):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return out


def measure(variant, scalar_runs, batched_runs, threads):
    v = em.VARIANTS[variant]
    n, steps, doms = v["num_photons"], v["num_steps"], v["num_doms"]
    src, med, dom, par = em.build_inputs(variant, seed=7)
    lines = []

    print(f"[bench-mirror] {variant}: {n} photons x {steps} steps x "
          f"{doms} DOMs", file=sys.stderr)

    lines.append(bench_line(
        f"mirror/{variant}-scalar",
        time_runs(lambda: em.scalar_outcomes(src, med, dom, par, n, steps),
                  scalar_runs),
        work=n, unit="photons"))
    print(f"[bench-mirror]   scalar: {n / lines[-1]['mean_s']:.0f} photons/s",
          file=sys.stderr)

    lines.append(bench_line(
        f"mirror/{variant}-batched-1t",
        time_runs(lambda: em.batched_outcomes(src, med, dom, par, n, steps,
                                              threads=1, bunch=4096),
                  batched_runs),
        work=n, unit="photons"))
    print(f"[bench-mirror]   batched-1t: "
          f"{n / lines[-1]['mean_s']:.0f} photons/s", file=sys.stderr)

    def parallel_run(sweep):
        out = em.empty_outcomes(n)
        with ThreadPoolExecutor(max_workers=threads) as ex:
            futs = [ex.submit(em.walk_chunk, src, med, dom, par, steps,
                              start, size, 4096, out, sweep)
                    for start, size in em.chunk_ranges(n, threads)]
            for f in futs:
                f.result()

    lines.append(bench_line(
        f"mirror/{variant}-batched-{threads}t",
        time_runs(lambda: parallel_run("loop"), batched_runs),
        work=n, unit="photons"))
    print(f"[bench-mirror]   batched-{threads}t: "
          f"{n / lines[-1]['mean_s']:.0f} photons/s", file=sys.stderr)

    # PR 8: the blocked sweep, mirror of the Rust lane path (default-on)
    lines.append(bench_line(
        f"mirror/{variant}-simd-1t",
        time_runs(lambda: em.batched_outcomes(src, med, dom, par, n, steps,
                                              threads=1, bunch=4096,
                                              sweep="blocked"),
                  batched_runs),
        work=n, unit="photons"))
    print(f"[bench-mirror]   simd-1t: "
          f"{n / lines[-1]['mean_s']:.0f} photons/s", file=sys.stderr)

    lines.append(bench_line(
        f"mirror/{variant}-simd-{threads}t",
        time_runs(lambda: parallel_run("blocked"), batched_runs),
        work=n, unit="photons"))
    print(f"[bench-mirror]   simd-{threads}t: "
          f"{n / lines[-1]['mean_s']:.0f} photons/s", file=sys.stderr)
    return lines


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pr3.json")
    ap.add_argument("--pr", type=int, default=3)
    ap.add_argument("--variant", default="default")
    ap.add_argument("--scalar-runs", type=int, default=3)
    ap.add_argument("--batched-runs", type=int, default=10)
    ap.add_argument("--threads", type=int, default=os.cpu_count() or 2)
    args = ap.parse_args()

    lines = measure(args.variant, args.scalar_runs, args.batched_runs,
                    args.threads)
    host = subprocess.run(["uname", "-sm"], capture_output=True,
                          text=True, check=False).stdout.strip() or "unknown"
    meta = {
        "file": args.out,
        "pr": args.pr,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": host,
        "cores": os.cpu_count(),
        "measured": True,
        "harness": "tools/bench_mirror.py (numpy mirror of the Rust "
                   "engines; authoring container has no Rust toolchain)",
        "note": "mirror/* lines are measured Python-mirror numbers for "
                "the scalar vs batched-SoA photon walk; Rust bench names "
                "are recorded schema with null metrics until a "
                "Rust-equipped machine runs tools/bench_baseline.sh (CI's "
                "bench-baseline job measures + gates them on every push "
                "via tools/bench_compare.sh). "
                "mirror/*-simd-* lines are new in PR 8: the blocked "
                "pass-B sweep, the numpy transpose of the Rust lane "
                "sweep (rust/src/runtime/simd.rs), bit-identical to the "
                "per-DOM loop and gated against it via "
                "ICECLOUD_MIN_SIMD_SPEEDUP in tools/bench_compare.sh. "
                "Do not compare mirror/* against Rust-native lines.",
        "regenerate": "tools/bench_baseline.sh (Rust) or "
                      "tools/bench_mirror.py (mirror)",
        "benches": ["sweep", "photon_engine", "serve"],
    }
    with open(args.out, "w") as f:
        f.write(json.dumps({"meta": meta}) + "\n")
        for line in lines:
            f.write(json.dumps(line) + "\n")
        for name, unit in RUST_BENCHES:
            rec = {"bench": name, "mean_s": None, "std_s": None,
                   "p50_s": None, "p95_s": None, "samples": 0}
            if unit is not None:
                rec["throughput"] = None
                rec["unit"] = unit
            f.write(json.dumps(rec) + "\n")

    scalar = next(l for l in lines if l["bench"].endswith("-scalar"))
    best = max((l for l in lines if "-batched-" in l["bench"]),
               key=lambda l: l["throughput"])
    ratio = best["throughput"] / scalar["throughput"]
    print(f"[bench-mirror] wrote {args.out}; batched/scalar speedup "
          f"{ratio:.1f}x ({best['bench']})", file=sys.stderr)
    simd1 = next(l for l in lines if l["bench"].endswith("-simd-1t"))
    batched1 = next(l for l in lines if l["bench"].endswith("-batched-1t"))
    simd_ratio = simd1["throughput"] / batched1["throughput"]
    print(f"[bench-mirror] simd/batched (1t) speedup {simd_ratio:.2f}x",
          file=sys.stderr)


if __name__ == "__main__":
    main()
