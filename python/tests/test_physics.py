"""Statistical physics tests on the propagation model components."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import geometry
from compile.kernels import ref, rng

N = 1 << 14


def _u(seed, stream=0):
    pid = jnp.arange(N, dtype=jnp.uint32)
    return rng.uniform(seed, pid, 0, stream)


class TestHenyeyGreenstein:
    @pytest.mark.parametrize("g", [0.3, 0.6, 0.9, 0.95])
    def test_mean_cosine_equals_g(self, g):
        """<cos theta> of HG sampling equals the asymmetry parameter g."""
        cos_t = np.asarray(ref.hg_cos_theta(jnp.float32(g), _u(5)))
        assert abs(cos_t.mean() - g) < 0.02

    def test_isotropic_limit(self):
        cos_t = np.asarray(ref.hg_cos_theta(jnp.float32(0.0), _u(6)))
        assert abs(cos_t.mean()) < 0.02
        assert np.all(cos_t >= -1.0) and np.all(cos_t <= 1.0)

    def test_range_clipped(self):
        for g in (0.5, 0.99):
            cos_t = np.asarray(ref.hg_cos_theta(jnp.float32(g), _u(7)))
            assert np.all(cos_t >= -1.0) and np.all(cos_t <= 1.0)

    def test_forward_peaked(self):
        cos_t = np.asarray(ref.hg_cos_theta(jnp.float32(0.9), _u(8)))
        assert (cos_t > 0.5).mean() > 0.7


class TestIsotropicInit:
    def test_unit_norm(self):
        pid = jnp.arange(N, dtype=jnp.uint32)
        d = np.asarray(ref.isotropic_dirs(3, pid))
        np.testing.assert_allclose(np.linalg.norm(d, axis=1), 1.0,
                                   atol=1e-5)

    def test_mean_zero(self):
        pid = jnp.arange(N, dtype=jnp.uint32)
        d = np.asarray(ref.isotropic_dirs(3, pid))
        assert np.all(np.abs(d.mean(axis=0)) < 0.02)

    def test_cos_uniform(self):
        pid = jnp.arange(N, dtype=jnp.uint32)
        d = np.asarray(ref.isotropic_dirs(3, pid))
        counts, _ = np.histogram(d[:, 2], bins=8, range=(-1, 1))
        expected = N / 8
        assert np.all(np.abs(counts - expected) < 6 * np.sqrt(expected))


class TestRotation:
    def test_preserves_norm(self):
        pid = jnp.arange(N, dtype=jnp.uint32)
        d = ref.isotropic_dirs(11, pid)
        cos_t = ref.hg_cos_theta(jnp.float32(0.9), _u(12))
        phi = 2 * jnp.pi * _u(13, 1)
        nd = np.asarray(ref.rotate_dir(d, cos_t, phi))
        np.testing.assert_allclose(np.linalg.norm(nd, axis=1), 1.0,
                                   atol=1e-5)

    def test_achieves_requested_angle(self):
        pid = jnp.arange(N, dtype=jnp.uint32)
        d = ref.isotropic_dirs(11, pid)
        cos_t = ref.hg_cos_theta(jnp.float32(0.9), _u(12))
        phi = 2 * jnp.pi * _u(13, 1)
        nd = ref.rotate_dir(d, cos_t, phi)
        got = np.asarray(jnp.sum(nd * d, axis=1))
        np.testing.assert_allclose(got, np.asarray(cos_t), atol=1e-3)

    def test_identity_rotation(self):
        pid = jnp.arange(64, dtype=jnp.uint32)
        d = ref.isotropic_dirs(1, pid)
        nd = np.asarray(ref.rotate_dir(d, jnp.float32(1.0),
                                       jnp.float32(0.3)))
        np.testing.assert_allclose(nd, np.asarray(d), atol=1e-4)

    def test_handles_polar_directions(self):
        # the Duff ONB must be stable for d = +-z
        d = jnp.asarray([[0.0, 0.0, 1.0], [0.0, 0.0, -1.0]],
                        dtype=jnp.float32)
        nd = np.asarray(ref.rotate_dir(d, jnp.float32(0.5),
                                       jnp.float32(1.0)))
        assert np.all(np.isfinite(nd))
        np.testing.assert_allclose(np.linalg.norm(nd, axis=1), 1.0,
                                   atol=1e-5)


class TestLayerIndex:
    def test_top_layer(self):
        li = ref.layer_index(jnp.float32(39.0), 40.0, 100.0, 10)
        assert int(li) == 0

    def test_bottom_clamped(self):
        li = ref.layer_index(jnp.float32(-1e6), 40.0, 100.0, 10)
        assert int(li) == 9

    def test_above_top_clamped(self):
        li = ref.layer_index(jnp.float32(1e6), 40.0, 100.0, 10)
        assert int(li) == 0

    def test_monotone_with_depth(self):
        z = jnp.linspace(40.0, -960.0, 50)
        li = np.asarray(ref.layer_index(z, 40.0, 100.0, 10))
        assert np.all(np.diff(li) >= 0)


class TestIceEffects:
    """Macro physics: ice properties drive detection the right way."""

    def _run(self, media, seed=17, num_photons=512, num_steps=24):
        v = geometry.Variant("t", num_photons=num_photons, block=num_photons,
                             num_doms=30, num_steps=num_steps)
        src, _, doms, params = geometry.variant_inputs(v, seed=seed)
        hits, summ = ref.propagate(src, jnp.asarray(media), doms, params,
                                   num_photons=num_photons,
                                   num_steps=num_steps)
        return np.asarray(hits), np.asarray(summ)

    def test_dust_layer_absorbs_more(self):
        _, clear = self._run(geometry.clear_ice())
        _, dusty = self._run(geometry.layered_ice(dusty=True))
        assert dusty[ref.SUM_ABS] > clear[ref.SUM_ABS]

    def test_short_absorption_kills_photons(self):
        media = geometry.clear_ice()
        media[:, geometry.COL_ABS] = 5.0
        _, short = self._run(media)
        _, normal = self._run(geometry.clear_ice())
        assert short[ref.SUM_ABS] > normal[ref.SUM_ABS]
        assert short[ref.SUM_PATH] < normal[ref.SUM_PATH]

    def test_no_absorption_no_kills(self):
        media = geometry.clear_ice()
        media[:, geometry.COL_ABS] = 1e9
        _, summ = self._run(media)
        assert summ[ref.SUM_ABS] == 0


class TestGeometryHelpers:
    def test_string_doms_spacing(self):
        doms = geometry.string_doms(60)
        assert doms.shape == (60, 3)
        dz = np.diff(doms[:, 2])
        np.testing.assert_allclose(dz, -geometry.DOM_SPACING_M)

    def test_grid_doms_count(self):
        doms = geometry.grid_doms(2, 2, 60)
        assert doms.shape == (240, 3)
        assert len(np.unique(doms[:, :2], axis=0)) == 4

    def test_variant_inputs_shapes(self):
        v = geometry.VARIANTS["default"]
        src, media, doms, params = geometry.variant_inputs(v)
        assert src.shape == (8,)
        assert media.shape == (v.num_layers, 4)
        assert doms.shape == (v.num_doms, 3)
        assert params.shape == (8,)

    def test_flops_estimate_positive_and_scales(self):
        s = geometry.VARIANTS["small"].flops_estimate()
        d = geometry.VARIANTS["default"].flops_estimate()
        assert 0 < s < d

    def test_variant_grid(self):
        v = geometry.VARIANTS["default"]
        assert v.grid * v.block == v.num_photons
