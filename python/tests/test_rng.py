"""Tests for the stateless counter RNG (kernels/rng.py)."""

import jax.numpy as jnp
import numpy as np

from compile.kernels import rng

N = 1 << 14


def _uniforms(seed=1, step=0, stream=0, n=N):
    pid = jnp.arange(n, dtype=jnp.uint32)
    return np.asarray(rng.uniform(seed, pid, step, stream))


class TestRange:
    def test_in_unit_interval(self):
        u = _uniforms()
        assert np.all(u >= 0.0)
        assert np.all(u < 1.0)

    def test_exact_multiples_of_2_24(self):
        u = _uniforms()
        scaled = u * (1 << 24)
        assert np.array_equal(scaled, np.round(scaled))


class TestUniformity:
    def test_mean_and_var(self):
        u = _uniforms(seed=42)
        # mean 0.5 +- 5 sigma of 1/sqrt(12 N)
        assert abs(u.mean() - 0.5) < 5.0 / np.sqrt(12 * N)
        assert abs(u.var() - 1.0 / 12.0) < 0.005

    def test_histogram_flat(self):
        u = _uniforms(seed=3)
        counts, _ = np.histogram(u, bins=16, range=(0, 1))
        expected = N / 16
        # chi-square-ish bound: each bin within 6 sigma
        assert np.all(np.abs(counts - expected) < 6 * np.sqrt(expected))


class TestDecorrelation:
    def test_streams_differ(self):
        a = _uniforms(stream=0)
        b = _uniforms(stream=1)
        assert not np.array_equal(a, b)
        assert abs(np.corrcoef(a, b)[0, 1]) < 0.05

    def test_steps_differ(self):
        a = _uniforms(step=0)
        b = _uniforms(step=1)
        assert not np.array_equal(a, b)
        assert abs(np.corrcoef(a, b)[0, 1]) < 0.05

    def test_seeds_differ(self):
        a = _uniforms(seed=1)
        b = _uniforms(seed=2)
        assert not np.array_equal(a, b)
        assert abs(np.corrcoef(a, b)[0, 1]) < 0.05

    def test_adjacent_pids_uncorrelated(self):
        u = _uniforms(seed=9)
        assert abs(np.corrcoef(u[:-1], u[1:])[0, 1]) < 0.05


class TestDeterminism:
    def test_reproducible(self):
        assert np.array_equal(_uniforms(seed=7), _uniforms(seed=7))

    def test_float_seed_matches_int_seed(self):
        # the artifact passes the seed through an f32 slot
        pid = jnp.arange(64, dtype=jnp.uint32)
        a = rng.uniform(jnp.float32(1234.0), pid, 3, 2)
        b = rng.uniform(1234, pid, 3, 2)
        assert np.array_equal(np.asarray(a), np.asarray(b))


class TestMix32:
    def test_avalanche(self):
        # flipping one input bit flips ~half the output bits on average
        x = jnp.arange(1024, dtype=jnp.uint32)
        base = np.asarray(rng.mix32(x), dtype=np.uint64)
        flipped = np.asarray(rng.mix32(x ^ jnp.uint32(1)), dtype=np.uint64)
        diff = base ^ flipped
        popcount = np.array([bin(int(v)).count("1") for v in diff])
        assert 12.0 < popcount.mean() < 20.0

    def test_bijective_sample(self):
        # mix32 is a bijection on uint32; no collisions on a sample
        x = jnp.arange(1 << 16, dtype=jnp.uint32)
        y = np.asarray(rng.mix32(x))
        assert len(np.unique(y)) == len(y)
