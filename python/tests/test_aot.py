"""AOT pipeline tests: HLO text artifacts + meta.json."""

import json
import os

import pytest

from compile import aot, geometry


@pytest.fixture(scope="module")
def small_hlo():
    return aot.lower_variant(geometry.VARIANTS["small"])


class TestHloText:
    def test_is_hlo_module(self, small_hlo):
        assert small_hlo.startswith("HloModule")

    def test_entry_layout_matches_variant(self, small_hlo):
        v = geometry.VARIANTS["small"]
        # inputs: source f32[8], media f32[L,4], doms f32[D,3], params f32[8]
        assert f"f32[{v.num_layers},4]" in small_hlo
        assert f"f32[{v.num_doms},3]" in small_hlo
        # outputs: (hits f32[D], summary f32[8])
        assert f"->(f32[{v.num_doms}]" in small_hlo

    def test_no_custom_calls(self, small_hlo):
        # interpret=True must not leak Mosaic custom-calls the CPU PJRT
        # client cannot execute
        assert "custom-call" not in small_hlo

    def test_deterministic_lowering(self, small_hlo):
        again = aot.lower_variant(geometry.VARIANTS["small"])
        assert again == small_hlo


class TestBuild:
    def test_build_writes_artifacts(self, tmp_path):
        meta = aot.build(str(tmp_path), ["small"])
        assert (tmp_path / "photon_small.hlo.txt").exists()
        assert (tmp_path / "meta.json").exists()
        on_disk = json.loads((tmp_path / "meta.json").read_text())
        assert on_disk == meta

    def test_meta_contents(self, tmp_path):
        meta = aot.build(str(tmp_path), ["small"])
        m = meta["variants"]["small"]
        v = geometry.VARIANTS["small"]
        assert m["num_photons"] == v.num_photons
        assert m["num_doms"] == v.num_doms
        assert m["flops_estimate"] == v.flops_estimate()
        assert m["file"] == "photon_small.hlo.txt"
        assert [i["name"] for i in m["inputs"]] == [
            "source", "media", "doms", "params"]
        assert [o["name"] for o in m["outputs"]] == ["hits", "summary"]

    def test_unknown_variant_rejected(self, tmp_path):
        with pytest.raises(KeyError):
            aot.build(str(tmp_path), ["nope"])


class TestRepoArtifacts:
    """If `make artifacts` has run, the checked artifacts must be sane."""

    ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..",
                             "artifacts")

    @pytest.fixture(scope="class")
    def meta(self):
        path = os.path.join(self.ARTIFACTS, "meta.json")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        with open(path) as f:
            return json.load(f)

    def test_all_variant_files_exist(self, meta):
        for name, m in meta["variants"].items():
            assert os.path.exists(os.path.join(self.ARTIFACTS, m["file"])), \
                f"missing artifact for {name}"

    def test_flops_match_geometry(self, meta):
        for name, m in meta["variants"].items():
            v = geometry.VARIANTS[name]
            assert m["flops_estimate"] == pytest.approx(v.flops_estimate())
