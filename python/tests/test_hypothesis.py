"""Hypothesis sweeps: kernel==oracle across shapes, seeds and ice models."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import geometry, model
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def _check(num_photons, block, num_doms, num_steps, seed, dusty):
    v = geometry.Variant("h", num_photons=num_photons, block=block,
                         num_doms=num_doms, num_steps=num_steps)
    src, media, doms, params = geometry.variant_inputs(v, seed=seed,
                                                       dusty=dusty)
    hits_k, summ_k = model.simulate(src, media, doms, params,
                                    num_photons=num_photons, block=block,
                                    num_steps=num_steps)
    hits_r, summ_r = model.simulate_ref(src, media, doms, params,
                                        num_photons=num_photons,
                                        num_steps=num_steps)
    assert np.array_equal(np.asarray(hits_k), np.asarray(hits_r))
    np.testing.assert_allclose(np.asarray(summ_k), np.asarray(summ_r),
                               rtol=1e-5, atol=1e-3)
    # conservation under arbitrary shapes
    s = np.asarray(summ_k)
    assert s[ref.SUM_DET] + s[ref.SUM_ABS] + s[ref.SUM_ALIVE] == num_photons
    assert np.asarray(hits_k).sum() == s[ref.SUM_DET]


@settings(**SETTINGS)
@given(
    blocks=st.sampled_from([(64, 16), (64, 32), (64, 64), (128, 32),
                            (96, 32), (160, 32)]),
    num_doms=st.integers(min_value=4, max_value=24),
    num_steps=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_equals_ref_sweep(blocks, num_doms, num_steps, seed):
    num_photons, block = blocks
    _check(num_photons, block, num_doms, num_steps, seed, dusty=True)


@settings(**SETTINGS)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dusty=st.booleans(),
)
def test_kernel_equals_ref_ice_models(seed, dusty):
    _check(64, 32, 12, 8, seed, dusty)


@settings(**SETTINGS)
@given(
    g=st.floats(min_value=0.0, max_value=0.96875, width=32),
    u=st.floats(min_value=0.0, max_value=0.999755859375, width=32),
)
def test_hg_cos_in_range(g, u):
    c = float(ref.hg_cos_theta(jnp.float32(g), jnp.float32(u)))
    assert -1.0 <= c <= 1.0


@settings(**SETTINGS)
@given(
    z=st.floats(min_value=-1e5, max_value=1e5, width=32),
    z0=st.floats(min_value=-100.0, max_value=100.0, width=32),
    dz=st.floats(min_value=1.0, max_value=1000.0, width=32),
    n=st.integers(min_value=1, max_value=64),
)
def test_layer_index_always_valid(z, z0, dz, n):
    li = int(ref.layer_index(jnp.float32(z), z0, dz, n))
    assert 0 <= li < n
