"""Pallas kernel vs pure-jnp oracle — the core L1 correctness gate.

Per-DOM hit counts are integer-valued f32 and must match the oracle
EXACTLY; float summaries match to 1e-5 (block summation order differs).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import geometry, model
from compile.kernels import ref

RTOL = 1e-5
ATOL = 1e-3


def run_both(v, seed, dusty=True):
    src, media, doms, params = geometry.variant_inputs(v, seed=seed,
                                                       dusty=dusty)
    hits_k, summ_k = model.simulate(
        src, media, doms, params, num_photons=v.num_photons,
        block=v.block, num_steps=v.num_steps)
    hits_r, summ_r = model.simulate_ref(
        src, media, doms, params, num_photons=v.num_photons,
        num_steps=v.num_steps)
    return (np.asarray(hits_k), np.asarray(summ_k),
            np.asarray(hits_r), np.asarray(summ_r))


@pytest.mark.parametrize("seed", [1, 7, 42, 20210921])
def test_kernel_matches_ref_small(seed):
    v = geometry.VARIANTS["small"]
    hits_k, summ_k, hits_r, summ_r = run_both(v, seed)
    assert np.array_equal(hits_k, hits_r)
    np.testing.assert_allclose(summ_k, summ_r, rtol=RTOL, atol=ATOL)


def test_kernel_matches_ref_default():
    v = geometry.VARIANTS["default"]
    hits_k, summ_k, hits_r, summ_r = run_both(v, 11)
    assert np.array_equal(hits_k, hits_r)
    np.testing.assert_allclose(summ_k, summ_r, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("block", [32, 64, 128, 256])
def test_block_size_invariance(block):
    """The per-DOM histogram must not depend on the Pallas tiling."""
    v = geometry.Variant("t", num_photons=256, block=block, num_doms=16,
                         num_steps=12)
    src, media, doms, params = geometry.variant_inputs(v, seed=5)
    hits, summ = model.simulate(src, media, doms, params,
                                num_photons=v.num_photons, block=block,
                                num_steps=v.num_steps)
    hits_r, summ_r = model.simulate_ref(src, media, doms, params,
                                        num_photons=v.num_photons,
                                        num_steps=v.num_steps)
    assert np.array_equal(np.asarray(hits), np.asarray(hits_r))
    np.testing.assert_allclose(np.asarray(summ), np.asarray(summ_r),
                               rtol=RTOL, atol=ATOL)


def test_determinism():
    v = geometry.VARIANTS["small"]
    a = run_both(v, 99)
    b = run_both(v, 99)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_seed_sensitivity():
    v = geometry.VARIANTS["small"]
    h1, _, _, _ = run_both(v, 1)
    h2, _, _, _ = run_both(v, 2)
    assert not np.array_equal(h1, h2)


class TestConservation:
    """Population bookkeeping invariants on the kernel outputs."""

    @pytest.fixture(scope="class")
    def result(self):
        v = geometry.VARIANTS["small"]
        src, media, doms, params = geometry.variant_inputs(v, seed=13)
        hits, summ = model.simulate(src, media, doms, params,
                                    num_photons=v.num_photons,
                                    block=v.block, num_steps=v.num_steps)
        return v, np.asarray(hits), np.asarray(summ)

    def test_status_partition(self, result):
        v, hits, summ = result
        det, absd, alive = summ[ref.SUM_DET], summ[ref.SUM_ABS], summ[ref.SUM_ALIVE]
        assert det + absd + alive == v.num_photons

    def test_hits_equal_detected(self, result):
        _, hits, summ = result
        assert hits.sum() == summ[ref.SUM_DET]

    def test_hits_nonnegative_integers(self, result):
        _, hits, _ = result
        assert np.all(hits >= 0)
        assert np.array_equal(hits, np.round(hits))

    def test_path_positive(self, result):
        _, _, summ = result
        assert summ[ref.SUM_PATH] > 0

    def test_hit_times_nonnegative(self, result):
        _, _, summ = result
        assert summ[ref.SUM_HITT] >= 0

    def test_alive_steps_bounded(self, result):
        v, _, summ = result
        assert 0 < summ[ref.SUM_STEPS] <= v.num_photons * v.num_steps


class TestRefState:
    """Final-state invariants exposed by the oracle (return_state=True)."""

    @pytest.fixture(scope="class")
    def state(self):
        v = geometry.VARIANTS["small"]
        src, media, doms, params = geometry.variant_inputs(v, seed=21)
        hits, summ, st = ref.propagate(src, media, doms, params,
                                       num_photons=v.num_photons,
                                       num_steps=v.num_steps,
                                       return_state=True)
        return np.asarray(hits), np.asarray(summ), {
            k: np.asarray(x) for k, x in st.items()}

    def test_directions_unit_norm(self, state):
        _, _, st = state
        norms = np.linalg.norm(st["dir"], axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)

    def test_status_codes_valid(self, state):
        _, _, st = state
        assert set(np.unique(st["status"])) <= {0, 1, 2}

    def test_time_consistent_with_path(self, state):
        _, _, st = state
        v_group = geometry.V_GROUP_M_NS
        np.testing.assert_allclose(st["t"], st["path"] / v_group, rtol=1e-3)

    def test_detected_photons_near_a_dom(self, state):
        _, _, st = state
        v = geometry.VARIANTS["small"]
        doms = geometry.string_doms(v.num_doms)
        det = st["status"] == 2
        if det.sum() == 0:
            pytest.skip("no detections with this seed")
        dpos = st["pos"][det]
        d = np.linalg.norm(dpos[:, None, :] - doms[None, :, :], axis=2)
        # detected photons stopped at their hit point (within DOM radius
        # plus fp slack from the clipped segment parameterization)
        assert np.all(d.min(axis=1) < geometry.R_DOM_EFF * 1.5)


def test_pid_offset_matches_blocks():
    """ref.propagate(pid0=k*B) over blocks == one ref run over all photons.

    This pins the pid convention the Pallas kernel relies on.
    """
    v = geometry.Variant("t", num_photons=128, block=32, num_doms=8,
                         num_steps=8)
    src, media, doms, params = geometry.variant_inputs(v, seed=3)
    hits_full, summ_full = ref.propagate(src, media, doms, params,
                                         num_photons=128, num_steps=8)
    hits_acc = np.zeros(8, dtype=np.float32)
    summ_acc = np.zeros(8, dtype=np.float32)
    for b in range(4):
        h, s = ref.propagate(src, media, doms, params, num_photons=32,
                             num_steps=8, pid0=b * 32)
        hits_acc += np.asarray(h)
        summ_acc += np.asarray(s)
    assert np.array_equal(hits_acc, np.asarray(hits_full))
    np.testing.assert_allclose(summ_acc, np.asarray(summ_full),
                               rtol=RTOL, atol=ATOL)
