"""L2 JAX model: the IceCube photon-propagation forward graph.

One artifact execution = one *photon bunch*: emit ``num_photons`` photons
from a cascade vertex, propagate them through layered ice with the L1
Pallas kernel, and reduce the per-block partials into the detector-level
observables the downstream (Rust) job pipeline consumes:

* ``hits``    f32[D]  — per-DOM photo-electron counts,
* ``summary`` f32[8]  — population accounting (detected / absorbed /
  alive, path-length sum, hit-time sum, alive-step sum).

The module also exposes ``simulate_ref`` (same signature, pure-jnp oracle)
for the pytest correctness gate, and ``artifact_fn`` — the exact closure
that ``aot.py`` lowers to HLO text for the Rust runtime.
"""

import functools

import jax.numpy as jnp

from .kernels import photon, ref


def _combine(hits_blocks, summ_blocks):
    """Reduce per-block partials (all summary entries are sums)."""
    return hits_blocks.sum(axis=0), summ_blocks.sum(axis=0)


def simulate(source, media, doms, params, *, num_photons, block, num_steps):
    """Propagate a photon bunch via the Pallas kernel (L1) and reduce."""
    hits_b, summ_b = photon.propagate_blocked(
        source, media, doms, params,
        num_photons=num_photons, block=block, num_steps=num_steps)
    return _combine(hits_b, summ_b)


def simulate_ref(source, media, doms, params, *, num_photons, block=None,
                 num_steps):
    """Pure-jnp oracle with the same call signature as ``simulate``."""
    del block  # the oracle is unblocked
    return ref.propagate(source, media, doms, params,
                         num_photons=num_photons, num_steps=num_steps)


def artifact_fn(variant):
    """The function lowered to one AOT artifact for a shape variant.

    Closes over the static shapes; takes the 4 runtime inputs and returns
    the ``(hits, summary)`` tuple. This is what the Rust runtime executes.
    """

    def run(source, media, doms, params):
        return simulate(source, media, doms, params,
                        num_photons=variant.num_photons,
                        block=variant.block,
                        num_steps=variant.num_steps)

    run.__name__ = f"icecube_photon_{variant.name}"
    return run


@functools.lru_cache(maxsize=None)
def input_specs(num_doms, num_layers=10):
    """ShapeDtypeStructs of the artifact inputs, in call order."""
    import jax

    return (
        jax.ShapeDtypeStruct((8,), jnp.float32),            # source
        jax.ShapeDtypeStruct((num_layers, 4), jnp.float32),  # media
        jax.ShapeDtypeStruct((num_doms, 3), jnp.float32),    # doms
        jax.ShapeDtypeStruct((8,), jnp.float32),             # params
    )
