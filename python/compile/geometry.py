"""Detector geometry, ice model and artifact variant specs.

The production IceCube ice model (SPICE) and detector geometry are not
redistributable; we use an openly-specified synthetic equivalent that
preserves the compute shape: a vertical string (or small grid of strings) of
DOMs with 17 m spacing, layered ice with a short-scattering "dust layer" in
the middle, Henyey-Greenstein scattering with g≈0.9, and DOM oversizing
(ppc itself oversizes DOMs by 5–16x to boost statistics — we do the same).
See DESIGN.md §6 Substitution log.
"""

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

# --- physical constants (values used by IceCube toy models) ---------------
C_VACUUM_M_NS = 0.299792458  # m / ns
N_GROUP = 1.35  # group refractive index of deep ice
V_GROUP_M_NS = C_VACUUM_M_NS / N_GROUP  # photon group velocity in ice

DOM_SPACING_M = 17.0  # vertical DOM spacing on an IceCube string
DOM_RADIUS_M = 0.16510  # physical DOM radius
DOM_OVERSIZE = 12.0  # ppc-style oversizing factor
R_DOM_EFF = DOM_RADIUS_M * DOM_OVERSIZE

N_LAYERS = 10  # ice layers in the media table

# media table columns
COL_SCAT = 0  # effective scattering length lambda_s [m]
COL_ABS = 1  # absorption length lambda_a [m]
COL_G = 2  # Henyey-Greenstein asymmetry parameter
COL_PAD = 3

# params vector layout (f32[8])
P_RDOM = 0  # effective DOM radius [m]
P_Z0 = 1  # top of the layered-ice stack [m]
P_DZ = 2  # layer thickness [m]
P_VGRP = 3  # group velocity [m/ns]
P_EPS = 4  # log()-guard epsilon
# 5..7 reserved

# source vector layout (f32[8]): x y z dx dy dz t0 seed
S_X, S_Y, S_Z, S_DX, S_DY, S_DZ, S_T0, S_SEED = range(8)


def string_doms(num_doms: int, x: float = 0.0, y: float = 0.0,
                z_top: float = 0.0) -> np.ndarray:
    """DOM positions of a single vertical string, f32[num_doms, 3]."""
    z = z_top - DOM_SPACING_M * np.arange(num_doms, dtype=np.float32)
    out = np.zeros((num_doms, 3), dtype=np.float32)
    out[:, 0] = x
    out[:, 1] = y
    out[:, 2] = z
    return out


def grid_doms(strings_x: int, strings_y: int, doms_per_string: int,
              pitch_m: float = 125.0) -> np.ndarray:
    """A small rectangular grid of strings (IceCube string pitch ~125 m)."""
    parts = []
    for ix in range(strings_x):
        for iy in range(strings_y):
            parts.append(
                string_doms(doms_per_string,
                            x=ix * pitch_m - (strings_x - 1) * pitch_m / 2,
                            y=iy * pitch_m - (strings_y - 1) * pitch_m / 2))
    return np.concatenate(parts, axis=0)


def layered_ice(num_layers: int = N_LAYERS, dusty: bool = True) -> np.ndarray:
    """Media table f32[num_layers, 4]: clear ice with an optional dust layer.

    Layer i covers z in [z0 - (i+1)*dz, z0 - i*dz] (top layer is i=0).
    """
    media = np.zeros((num_layers, 4), dtype=np.float32)
    media[:, COL_SCAT] = 25.0  # effective scattering length [m]
    media[:, COL_ABS] = 100.0  # absorption length [m]
    media[:, COL_G] = 0.9
    if dusty and num_layers >= 3:
        mid = num_layers // 2
        media[mid, COL_SCAT] = 5.0  # dust: strong scattering
        media[mid, COL_ABS] = 20.0  # dust: strong absorption
    return media


def clear_ice(num_layers: int = N_LAYERS) -> np.ndarray:
    return layered_ice(num_layers, dusty=False)


def default_params(num_doms: int, z0: float = 40.0) -> np.ndarray:
    """Params vector covering the DOM string depth range with N_LAYERS."""
    depth_span = DOM_SPACING_M * (num_doms + 4)
    params = np.zeros(8, dtype=np.float32)
    params[P_RDOM] = R_DOM_EFF
    params[P_Z0] = z0
    params[P_DZ] = depth_span / N_LAYERS
    params[P_VGRP] = V_GROUP_M_NS
    params[P_EPS] = 1e-7
    return params


def cascade_source(x: float, y: float, z: float, seed: int,
                   t0: float = 0.0) -> np.ndarray:
    """Point-cascade light source: isotropic emission from (x, y, z)."""
    src = np.zeros(8, dtype=np.float32)
    src[S_X], src[S_Y], src[S_Z] = x, y, z
    # dx,dy,dz unused for isotropic cascades (kept for track sources)
    src[S_T0] = t0
    src[S_SEED] = float(seed)
    return src


# --- artifact variants -----------------------------------------------------

@dataclass(frozen=True)
class Variant:
    """Static shape configuration of one AOT-compiled photon artifact."""
    name: str
    num_photons: int
    block: int  # photons per Pallas block (P_BLK)
    num_doms: int
    num_steps: int
    num_layers: int = N_LAYERS

    @property
    def grid(self) -> int:
        assert self.num_photons % self.block == 0
        return self.num_photons // self.block

    def flops_estimate(self) -> float:
        """Analytic fp32 FLOP count of one artifact execution.

        Per photon-step: ~170 flops of RNG/transport/scattering plus a
        dense segment-DOM distance test of ~15 flops per DOM.
        """
        per_step = 170.0 + 15.0 * self.num_doms
        return float(self.num_photons) * self.num_steps * per_step


VARIANTS = {
    "small": Variant("small", num_photons=256, block=128, num_doms=16,
                     num_steps=16),
    "default": Variant("default", num_photons=4096, block=512, num_doms=60,
                       num_steps=64),
    "large": Variant("large", num_photons=16384, block=1024, num_doms=240,
                     num_steps=96),
}


def variant_inputs(v: Variant, seed: int = 7, dusty: bool = True):
    """Build a concrete (source, media, doms, params) input set."""
    if v.num_doms <= 80:
        doms = string_doms(v.num_doms)
    else:
        per = v.num_doms // 4
        doms = grid_doms(2, 2, per)[: v.num_doms]
    mid_z = float(np.mean(doms[:, 2]))
    source = cascade_source(10.0, 0.0, mid_z, seed=seed)
    media = layered_ice(v.num_layers, dusty=dusty)
    params = default_params(v.num_doms)
    return (jnp.asarray(source), jnp.asarray(media), jnp.asarray(doms),
            jnp.asarray(params))
