"""Stateless counter-based RNG shared by the Pallas kernel and the ref oracle.

IceCube's CUDA propagators (ppc/clsim) carry per-thread XORWOW state; on a
TPU-style vector machine carried RNG state is hostile (it serializes lanes
and bloats the carried loop state), so we use a *stateless* counter-based
construction instead: every uniform is a pure hash of
``(seed, photon_id, step, stream)``.  This is the same design point as
Philox/Threefry counter RNGs, reduced to a cheap 32-bit finalizer that is
exactly representable in both the Pallas kernel and the pure-jnp oracle
(bit-identical results are part of the correctness contract).

The mixer is the ``lowbias32`` avalanche function (two rounds applied for
extra diffusion across the structured counter inputs).
"""

import jax.numpy as jnp

# Odd 32-bit constants decorrelating the counter dimensions.
K_PID = 0x9E3779B9  # golden-ratio increment, decorrelates photon ids
K_STEP = 0x85EBCA6B  # murmur3 c2
K_STREAM = 0xC2B2AE35  # murmur3 final mix constant

_INV_2_24 = float(1.0 / (1 << 24))


def _u32(x):
    return jnp.asarray(x).astype(jnp.uint32)


def mix32(x):
    """One round of the lowbias32 avalanche finalizer (uint32 -> uint32)."""
    x = _u32(x)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def counter_key(seed, pid, step, stream):
    """Combine the counter coordinates into a single uint32 key."""
    seed = _u32(seed)
    pid = _u32(pid)
    step = _u32(step)
    stream = _u32(stream)
    return (
        seed
        ^ (pid * jnp.uint32(K_PID))
        ^ (step * jnp.uint32(K_STEP))
        ^ (stream * jnp.uint32(K_STREAM))
    )


def uniform(seed, pid, step, stream):
    """Uniform f32 in [0, 1) from the (seed, pid, step, stream) counter.

    Two mix rounds; the top 24 bits become the mantissa so the result is an
    exact multiple of 2^-24 (reproducible across backends).
    """
    v = mix32(mix32(counter_key(seed, pid, step, stream)))
    return (v >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(_INV_2_24)
