"""L1 Pallas kernel: blocked photon propagation.

TPU-shaped formulation of the propagation spec in ``ref.py`` (which holds
the canonical physics helpers — we import those so the physics cannot
drift; what this module owns is the *execution shape*):

* the photon population is tiled into VMEM-sized blocks of ``block``
  photons; one Pallas grid step propagates one block end-to-end
  (``num_steps`` scattering steps in an on-chip ``fori_loop``), so photon
  state never round-trips to HBM between steps;
* the DOM table and media table are small and replicated into every
  block's VMEM via constant ``BlockSpec`` index maps;
* control flow is lane-uniform: dead photons are masked, never branched
  on (the CUDA original lets threads exit divergently — see DESIGN.md
  §Hardware-Adaptation);
* per-DOM hit histograms are produced per block as a dense one-hot
  reduction (an MXU-friendly contraction, replacing CUDA atomics) and
  summed across blocks by the L2 graph.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowering produces plain HLO that both the
pytest suite and the Rust runtime execute.  Real-TPU efficiency is
estimated analytically in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rng
from .ref import (
    STREAM_ABSORB,
    STREAM_COS,
    STREAM_LEN,
    STREAM_PHI,
    TWO_PI,
    hg_cos_theta,
    isotropic_dirs,
    layer_index,
    rotate_dir,
)


def _propagate_kernel(source_ref, media_ref, doms_ref, params_ref,
                      hits_ref, summ_ref, *, block, num_steps):
    """Propagate one block of ``block`` photons (one Pallas grid step)."""
    source = source_ref[...]
    media = media_ref[...]
    doms = doms_ref[...]
    params = params_ref[...]

    num_layers = media.shape[0]
    num_doms = doms.shape[0]

    seed = source[7]
    blk = pl.program_id(0)
    pid = (jnp.uint32(block) * blk.astype(jnp.uint32)
           + jnp.arange(block, dtype=jnp.uint32))

    r2 = params[0] * params[0]
    z0 = params[1]
    dz = params[2]
    v_group = params[3]
    eps = params[4]

    pos0 = jnp.broadcast_to(source[0:3], (block, 3))
    dir0 = isotropic_dirs(seed, pid)
    t0 = jnp.full((block,), source[6], dtype=jnp.float32)
    status0 = jnp.zeros((block,), dtype=jnp.int32)
    hits0 = jnp.zeros((num_doms,), dtype=jnp.float32)
    path0 = jnp.zeros((block,), dtype=jnp.float32)
    hitt0 = jnp.float32(0.0)
    steps0 = jnp.float32(0.0)

    dom_idx = jnp.arange(num_doms, dtype=jnp.int32)

    def step(k, state):
        pos, dire, t, status, hits, path, hitt, steps = state
        alive = status == 0

        li = layer_index(pos[:, 2], z0, dz, num_layers)
        lam_s = media[li, 0]
        lam_a = media[li, 1]
        g = media[li, 2]

        u_len = rng.uniform(seed, pid, k, STREAM_LEN)
        u_abs = rng.uniform(seed, pid, k, STREAM_ABSORB)
        u_cos = rng.uniform(seed, pid, k, STREAM_COS)
        u_phi = rng.uniform(seed, pid, k, STREAM_PHI)

        d = -lam_s * jnp.log(jnp.maximum(u_len, eps))

        # dense (block, D) segment-DOM closest-approach test in VMEM
        rel = doms[None, :, :] - pos[:, None, :]
        t_along = jnp.sum(rel * dire[:, None, :], axis=-1)
        t_along = jnp.clip(t_along, 0.0, d[:, None])
        closest = pos[:, None, :] + t_along[..., None] * dire[:, None, :]
        diff = doms[None, :, :] - closest
        dist2 = jnp.sum(diff * diff, axis=-1)
        hitm = (dist2 <= r2) & alive[:, None]
        any_hit = jnp.any(hitm, axis=1)
        t_cand = jnp.where(hitm, t_along, jnp.float32(jnp.inf))
        first = jnp.argmin(t_cand, axis=1).astype(jnp.int32)
        # one-hot reduction: the TPU-side replacement for CUDA atomics
        onehot = (dom_idx[None, :] == first[:, None]) & any_hit[:, None]
        hits = hits + jnp.sum(onehot.astype(jnp.float32), axis=0)
        t_sel = jnp.take_along_axis(t_along, first[:, None], axis=1)[:, 0]
        hitt = hitt + jnp.sum(
            jnp.where(any_hit, t + t_sel / v_group, 0.0))

        survived = u_abs < jnp.exp(-d / lam_a)
        status = jnp.where(
            any_hit, 2, jnp.where(alive & ~survived, 1, status))

        move = jnp.where(alive, jnp.where(any_hit, t_sel, d), 0.0)
        pos = pos + dire * move[:, None]
        t = t + move / v_group
        path = path + move
        steps = steps + jnp.sum(alive.astype(jnp.float32))

        cos_t = hg_cos_theta(g, u_cos)
        phi = jnp.float32(TWO_PI) * u_phi
        new_dir = rotate_dir(dire, cos_t, phi)
        still = (status == 0)[:, None]
        dire = jnp.where(still, new_dir, dire)
        return pos, dire, t, status, hits, path, hitt, steps

    state = (pos0, dir0, t0, status0, hits0, path0, hitt0, steps0)
    pos, dire, t, status, hits, path, hitt, steps = jax.lax.fori_loop(
        0, num_steps, step, state)

    summ = jnp.stack([
        jnp.sum((status == 2).astype(jnp.float32)),
        jnp.sum((status == 1).astype(jnp.float32)),
        jnp.sum((status == 0).astype(jnp.float32)),
        jnp.sum(path),
        hitt,
        steps,
        jnp.float32(0.0),
        jnp.float32(0.0),
    ])

    hits_ref[0, :] = hits
    summ_ref[0, :] = summ


@functools.partial(jax.jit, static_argnames=("num_photons", "block",
                                             "num_steps"))
def propagate_blocked(source, media, doms, params, *, num_photons, block,
                      num_steps):
    """Run the Pallas kernel over the photon population.

    Returns per-block partials: ``(hits f32[G, D], summary f32[G, 8])``
    with ``G = num_photons // block``; the L2 graph reduces over blocks.
    """
    if num_photons % block != 0:
        raise ValueError(
            f"num_photons={num_photons} not divisible by block={block}")
    grid = num_photons // block
    num_layers = media.shape[0]
    num_doms = doms.shape[0]

    kernel = functools.partial(_propagate_kernel, block=block,
                               num_steps=num_steps)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((num_layers, 4), lambda i: (0, 0)),
            pl.BlockSpec((num_doms, 3), lambda i: (0, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, num_doms), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid, num_doms), jnp.float32),
            jax.ShapeDtypeStruct((grid, 8), jnp.float32),
        ],
        interpret=True,
    )(source, media, doms, params)
