"""Pure-jnp oracle for the photon-propagation kernel.

This is the correctness contract for ``kernels/photon.py``: the same
physics, written as straight vectorized jax.numpy over the full photon
array (no Pallas, no blocking).  Because both implementations consume the
same stateless counter RNG (``kernels.rng``) and apply the same op
sequence, per-DOM hit counts must match the Pallas kernel *exactly*
(they are integer-valued) and float summaries must match to ~1e-5
(block-wise summation order differs).

Physics spec (shared by kernel and oracle)
------------------------------------------
Photons start at the cascade vertex with isotropic directions and undergo
``num_steps`` scattering steps.  Per step ``k`` for photon ``p``:

1. sample step length  d = -lambda_s(z) * ln(max(u0, eps))
2. segment [pos, pos + d*dir] is tested against every DOM sphere
   (closest-approach distance); the earliest hit (min t_along) detects the
   photon (status=2) and increments that DOM's hit counter
3. survivors sample absorption over the step: u1 >= exp(-d/lambda_a) kills
   the photon (status=1)
4. survivors move by d, advance time by d/v_group, and scatter into a new
   direction: Henyey-Greenstein cos(theta) from u2, azimuth 2*pi*u3,
   rotated about the old direction (Duff et al. orthonormal basis)

RNG streams: 0=step length, 1=absorption, 2=HG cos, 3=azimuth,
4=initial cos, 5=initial azimuth (streams 4/5 used only at step 0).

Status codes: 0 = alive, 1 = absorbed, 2 = detected.

Summary vector (f32[8], all entries are sums so block results combine by
addition): [n_detected, n_absorbed, n_alive, path_length_sum,
hit_time_sum, alive_step_sum, 0, 0].
"""

import jax
import jax.numpy as jnp

from . import rng

# summary indices
SUM_DET, SUM_ABS, SUM_ALIVE, SUM_PATH, SUM_HITT, SUM_STEPS = range(6)

# RNG streams
STREAM_LEN = 0
STREAM_ABSORB = 1
STREAM_COS = 2
STREAM_PHI = 3
STREAM_INIT_COS = 4
STREAM_INIT_PHI = 5

TWO_PI = 2.0 * jnp.pi


def isotropic_dirs(seed, pid):
    """Initial isotropic unit vectors from RNG streams 4/5 at step 0."""
    u_cos = rng.uniform(seed, pid, 0, STREAM_INIT_COS)
    u_phi = rng.uniform(seed, pid, 0, STREAM_INIT_PHI)
    cos_t = 1.0 - 2.0 * u_cos
    sin_t = jnp.sqrt(jnp.maximum(0.0, 1.0 - cos_t * cos_t))
    phi = jnp.float32(TWO_PI) * u_phi
    return jnp.stack(
        [sin_t * jnp.cos(phi), sin_t * jnp.sin(phi), cos_t], axis=-1)


def hg_cos_theta(g, u):
    """Henyey-Greenstein scattering angle cosine (isotropic at |g|→0)."""
    g_safe = jnp.where(jnp.abs(g) < 1e-3, jnp.float32(1.0), g)
    frac = (1.0 - g_safe * g_safe) / (1.0 - g_safe + 2.0 * g_safe * u)
    cos_hg = (1.0 + g_safe * g_safe - frac * frac) / (2.0 * g_safe)
    cos_iso = 1.0 - 2.0 * u
    return jnp.clip(
        jnp.where(jnp.abs(g) < 1e-3, cos_iso, cos_hg), -1.0, 1.0)


def rotate_dir(d, cos_t, phi):
    """Rotate unit vectors ``d`` by polar angle acos(cos_t), azimuth phi.

    Uses the branchless Duff et al. orthonormal basis; re-normalizes to
    suppress fp32 drift across many scattering steps.
    """
    dx, dy, dz = d[..., 0], d[..., 1], d[..., 2]
    sign = jnp.where(dz >= 0.0, jnp.float32(1.0), jnp.float32(-1.0))
    a = -1.0 / (sign + dz)
    b = dx * dy * a
    b1 = jnp.stack([1.0 + sign * dx * dx * a, sign * b, -sign * dx], axis=-1)
    b2 = jnp.stack([b, sign + dy * dy * a, -dy], axis=-1)
    sin_t = jnp.sqrt(jnp.maximum(0.0, 1.0 - cos_t * cos_t))
    nd = (sin_t * jnp.cos(phi))[..., None] * b1 \
        + (sin_t * jnp.sin(phi))[..., None] * b2 \
        + cos_t[..., None] * d
    norm = jnp.sqrt(jnp.sum(nd * nd, axis=-1, keepdims=True))
    return nd / jnp.maximum(norm, 1e-12)


def layer_index(z, z0, dz, num_layers):
    """Ice layer index for depth z (layer 0 at the top, z decreasing)."""
    li = jnp.floor((z0 - z) / dz).astype(jnp.int32)
    return jnp.clip(li, 0, num_layers - 1)


def propagate(source, media, doms, params, num_photons, num_steps,
              pid0=0, return_state=False):
    """Reference propagation of ``num_photons`` photons.

    Args:
      source: f32[8] — x y z dx dy dz t0 seed (see geometry.py layout)
      media: f32[L, 4] — per-layer [lambda_s, lambda_a, g, pad]
      doms: f32[D, 3] — DOM centers
      params: f32[8] — [r_dom, z0, dz, v_group, eps, ...]
      pid0: first photon id (the Pallas kernel uses block_id * block)
    Returns:
      (hits f32[D], summary f32[8]) and optionally the final photon state.
    """
    num_layers = media.shape[0]
    num_doms = doms.shape[0]
    seed = source[7]
    pid = jnp.uint32(pid0) + jnp.arange(num_photons, dtype=jnp.uint32)

    r2 = params[0] * params[0]
    z0 = params[1]
    dz = params[2]
    v_group = params[3]
    eps = params[4]

    pos0 = jnp.broadcast_to(source[0:3], (num_photons, 3))
    dir0 = isotropic_dirs(seed, pid)
    t0 = jnp.full((num_photons,), source[6], dtype=jnp.float32)
    status0 = jnp.zeros((num_photons,), dtype=jnp.int32)
    hits0 = jnp.zeros((num_doms,), dtype=jnp.float32)
    path0 = jnp.zeros((num_photons,), dtype=jnp.float32)
    hitt0 = jnp.float32(0.0)
    steps0 = jnp.float32(0.0)

    dom_idx = jnp.arange(num_doms, dtype=jnp.int32)

    def step(k, state):
        pos, dire, t, status, hits, path, hitt, steps = state
        alive = status == 0

        li = layer_index(pos[:, 2], z0, dz, num_layers)
        lam_s = media[li, 0]
        lam_a = media[li, 1]
        g = media[li, 2]

        u_len = rng.uniform(seed, pid, k, STREAM_LEN)
        u_abs = rng.uniform(seed, pid, k, STREAM_ABSORB)
        u_cos = rng.uniform(seed, pid, k, STREAM_COS)
        u_phi = rng.uniform(seed, pid, k, STREAM_PHI)

        d = -lam_s * jnp.log(jnp.maximum(u_len, eps))

        # segment–DOM closest approach: rel (P, D, 3)
        rel = doms[None, :, :] - pos[:, None, :]
        t_along = jnp.sum(rel * dire[:, None, :], axis=-1)
        t_along = jnp.clip(t_along, 0.0, d[:, None])
        closest = pos[:, None, :] + t_along[..., None] * dire[:, None, :]
        diff = doms[None, :, :] - closest
        dist2 = jnp.sum(diff * diff, axis=-1)
        hitm = (dist2 <= r2) & alive[:, None]
        any_hit = jnp.any(hitm, axis=1)
        t_cand = jnp.where(hitm, t_along, jnp.float32(jnp.inf))
        first = jnp.argmin(t_cand, axis=1).astype(jnp.int32)
        onehot = (dom_idx[None, :] == first[:, None]) & any_hit[:, None]
        hits = hits + jnp.sum(onehot.astype(jnp.float32), axis=0)
        t_sel = jnp.take_along_axis(t_along, first[:, None], axis=1)[:, 0]
        hitt = hitt + jnp.sum(
            jnp.where(any_hit, t + t_sel / v_group, 0.0))

        survived = u_abs < jnp.exp(-d / lam_a)
        status = jnp.where(
            any_hit, 2, jnp.where(alive & ~survived, 1, status))

        move = jnp.where(alive, jnp.where(any_hit, t_sel, d), 0.0)
        pos = pos + dire * move[:, None]
        t = t + move / v_group
        path = path + move
        steps = steps + jnp.sum(alive.astype(jnp.float32))

        cos_t = hg_cos_theta(g, u_cos)
        phi = jnp.float32(TWO_PI) * u_phi
        new_dir = rotate_dir(dire, cos_t, phi)
        still = (status == 0)[:, None]
        dire = jnp.where(still, new_dir, dire)
        return pos, dire, t, status, hits, path, hitt, steps

    state = (pos0, dir0, t0, status0, hits0, path0, hitt0, steps0)
    pos, dire, t, status, hits, path, hitt, steps = jax.lax.fori_loop(
        0, num_steps, step, state)

    summary = jnp.zeros((8,), dtype=jnp.float32)
    summary = summary.at[SUM_DET].set(jnp.sum((status == 2).astype(jnp.float32)))
    summary = summary.at[SUM_ABS].set(jnp.sum((status == 1).astype(jnp.float32)))
    summary = summary.at[SUM_ALIVE].set(jnp.sum((status == 0).astype(jnp.float32)))
    summary = summary.at[SUM_PATH].set(jnp.sum(path))
    summary = summary.at[SUM_HITT].set(hitt)
    summary = summary.at[SUM_STEPS].set(steps)

    if return_state:
        return hits, summary, dict(pos=pos, dir=dire, t=t, status=status,
                                   path=path)
    return hits, summary
