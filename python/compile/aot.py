"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime
(`rust/src/runtime/`) loads the text with ``HloModuleProto::from_text_file``
and compiles it on the PJRT CPU client.  Python never runs on the
simulation/serving path.

HLO **text** — not ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate
links) rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (per variant in --variants):
  artifacts/photon_<variant>.hlo.txt   — the HLO module
  artifacts/meta.json                  — shapes, FLOP estimates, file map
"""

import argparse
import json
import os

import jax

from . import geometry, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_variant(variant):
    """Lower one shape variant; returns the HLO text."""
    fn = model.artifact_fn(variant)
    specs = model.input_specs(variant.num_doms, variant.num_layers)
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def variant_meta(variant, hlo_file):
    v = variant
    return {
        "file": hlo_file,
        "num_photons": v.num_photons,
        "block": v.block,
        "num_doms": v.num_doms,
        "num_steps": v.num_steps,
        "num_layers": v.num_layers,
        "grid": v.grid,
        "flops_estimate": v.flops_estimate(),
        "inputs": [
            {"name": "source", "shape": [8], "dtype": "f32"},
            {"name": "media", "shape": [v.num_layers, 4], "dtype": "f32"},
            {"name": "doms", "shape": [v.num_doms, 3], "dtype": "f32"},
            {"name": "params", "shape": [8], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "hits", "shape": [v.num_doms], "dtype": "f32"},
            {"name": "summary", "shape": [8], "dtype": "f32"},
        ],
    }


def build(outdir, variant_names):
    os.makedirs(outdir, exist_ok=True)
    meta = {"artifact_version": 1, "variants": {}}
    for name in variant_names:
        variant = geometry.VARIANTS[name]
        hlo = lower_variant(variant)
        fname = f"photon_{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        meta["variants"][name] = variant_meta(variant, fname)
        print(f"[aot] wrote {path} ({len(hlo)} chars)")
    meta_path = os.path.join(outdir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {meta_path}")
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--variants", default="small,default,large",
                    help="comma-separated variant names (see geometry.py)")
    args = ap.parse_args()
    names = [n.strip() for n in args.variants.split(",") if n.strip()]
    for n in names:
        if n not in geometry.VARIANTS:
            raise SystemExit(f"unknown variant {n!r}; "
                             f"known: {sorted(geometry.VARIANTS)}")
    build(args.outdir, names)


if __name__ == "__main__":
    main()
