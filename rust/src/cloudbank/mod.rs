//! CloudBank substrate: managed multi-cloud budget services.
//!
//! Models the two CloudBank services the paper used (§III): the
//! single-window budget page aggregating spend across all three
//! providers, and threshold-triggered alert emails carrying the recent
//! spending rate — plus the account creation/linking workflow.

pub mod account;
pub mod ledger;
pub mod report;

pub use account::{Account, AccountSet, Enrollment};
pub use ledger::{Alert, BudgetSnapshot, Ledger};
