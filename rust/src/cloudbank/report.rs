//! Human-readable CloudBank reports (the "web page" rendering).

use super::ledger::{BudgetSnapshot, Ledger};
use crate::sim::SimTime;
use crate::util::json::Json;

/// Render the single-window budget page as text.
pub fn render_snapshot(s: &BudgetSnapshot) -> String {
    let mut out = String::new();
    out.push_str("== CloudBank allocation status ==\n");
    out.push_str(&format!("budget:     ${:>12.2}\n", s.budget_usd));
    out.push_str(&format!(
        "spent:      ${:>12.2}  ({:.1}%)\n",
        s.spent_usd,
        100.0 * s.spent_usd / s.budget_usd
    ));
    out.push_str(&format!(
        "remaining:  ${:>12.2}  ({:.1}%)\n",
        s.remaining_usd(),
        100.0 * s.remaining_fraction()
    ));
    out.push_str("per provider:\n");
    out.push_str(&format!("  azure:    ${:>12.2}\n", s.azure_usd));
    out.push_str(&format!("  gcp:      ${:>12.2}\n", s.gcp_usd));
    out.push_str(&format!("  aws:      ${:>12.2}\n", s.aws_usd));
    out
}

/// One roll-up line: a scenario's budget snapshot plus its wall-hour
/// split (goodput vs wasted instance-hours — HEPCloud-style accounting
/// of what the spend actually bought).
pub struct RollupRow {
    pub name: String,
    pub snapshot: BudgetSnapshot,
    /// Instance-hours that ended as job goodput.
    pub goodput_hours: f64,
    /// Billed instance-hours that did not (idle, boot, lost attempts,
    /// restore overheads).
    pub wasted_hours: f64,
}

/// Render a per-scenario CloudBank roll-up: one budget line per replay,
/// the "single window" view across a whole sweep matrix.
pub fn render_rollup(rows: &[RollupRow]) -> String {
    let mut out = String::new();
    out.push_str("== CloudBank sweep roll-up (per-scenario spend) ==\n");
    out.push_str(&format!(
        "{:<24} {:>10} {:>10} {:>7} {:>10} {:>10} {:>10} {:>8} {:>8}\n",
        "scenario", "budget $", "spent $", "left%", "azure $", "gcp $",
        "aws $", "good h", "waste h"
    ));
    for row in rows {
        let s = &row.snapshot;
        out.push_str(&format!(
            "{:<24} {:>10.0} {:>10.2} {:>6.1}% {:>10.2} {:>10.2} {:>10.2} \
             {:>8.1} {:>8.1}\n",
            row.name,
            s.budget_usd,
            s.spent_usd,
            100.0 * s.remaining_fraction(),
            s.azure_usd,
            s.gcp_usd,
            s.aws_usd,
            row.goodput_hours,
            row.wasted_hours,
        ));
    }
    out
}

/// Machine-readable snapshot (for the results directory).
pub fn snapshot_json(ledger: &Ledger, now: SimTime) -> Json {
    let s = ledger.snapshot(now);
    let mut o = Json::obj();
    o.set("at_s", Json::from(s.at));
    o.set("budget_usd", Json::from(s.budget_usd));
    o.set("spent_usd", Json::from(s.spent_usd));
    o.set("remaining_usd", Json::from(s.remaining_usd()));
    o.set("remaining_fraction", Json::from(s.remaining_fraction()));
    o.set("azure_usd", Json::from(s.azure_usd));
    o.set("gcp_usd", Json::from(s.gcp_usd));
    o.set("aws_usd", Json::from(s.aws_usd));
    o.set("spend_rate_per_day", Json::from(ledger.spend_rate_per_day()));
    o.set(
        "instance_hours",
        Json::from(ledger.total_instance_hours()),
    );
    o.set("busy_hours", Json::from(ledger.total_busy_hours()));
    let alerts: Vec<Json> = ledger
        .alerts()
        .iter()
        .map(|a| {
            let mut j = Json::obj();
            j.set("at_s", Json::from(a.at));
            j.set("threshold", Json::from(a.threshold));
            j.set("remaining_usd", Json::from(a.remaining_usd));
            j.set("spend_rate_per_day", Json::from(a.spend_rate_per_day));
            j
        })
        .collect();
    o.set("alerts", Json::Arr(alerts));
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudbank::account::AccountSet;

    #[test]
    fn snapshot_renders_all_fields() {
        let ledger = Ledger::new(AccountSet::paper_setup(0), 58_000.0, &[]);
        let text = render_snapshot(&ledger.snapshot(0));
        assert!(text.contains("budget"));
        assert!(text.contains("58000.00"));
        assert!(text.contains("azure"));
    }

    #[test]
    fn rollup_lists_every_scenario_with_hour_split() {
        let ledger = Ledger::new(AccountSet::paper_setup(0), 58_000.0, &[]);
        let rows = vec![
            RollupRow {
                name: "baseline".to_string(),
                snapshot: ledger.snapshot(0),
                goodput_hours: 120.5,
                wasted_hours: 30.25,
            },
            RollupRow {
                name: "half-budget".to_string(),
                snapshot: ledger.snapshot(10),
                goodput_hours: 60.0,
                wasted_hours: 15.0,
            },
        ];
        let text = render_rollup(&rows);
        assert!(text.contains("baseline"));
        assert!(text.contains("half-budget"));
        assert!(text.contains("azure"));
        assert!(text.contains("good h"));
        assert!(text.contains("waste h"));
        assert!(text.contains("120.5"));
        assert!(text.contains("30.2"));
        assert_eq!(text.lines().count(), 4);
    }

    #[test]
    fn json_parses_back() {
        let ledger = Ledger::paper_allocation(0);
        let j = snapshot_json(&ledger, 42);
        let s = j.to_string_pretty();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.get("budget_usd").unwrap().as_f64(), Some(58_000.0));
        assert_eq!(back.get("at_s").unwrap().as_u64(), Some(42));
    }
}
