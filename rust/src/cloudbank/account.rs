//! Cloud account creation and linking.
//!
//! §III of the paper: CloudBank established a brand-new account at one
//! provider and *linked* the team's two pre-existing accounts at the
//! others into its accounting system — the institutional-procurement pain
//! point CloudBank exists to remove.

use crate::cloud::Provider;
use crate::sim::SimTime;

/// How an account came under CloudBank management.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Enrollment {
    /// CloudBank created the account (new provider relationship).
    CreatedByCloudbank,
    /// Pre-existing institutional account linked into CloudBank billing.
    LinkedExisting,
}

/// A provider account managed by CloudBank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Account {
    pub provider: Provider,
    pub enrollment: Enrollment,
    pub enrolled_at: SimTime,
    pub billing_connected: bool,
}

/// The set of accounts backing a CloudBank allocation.
#[derive(Debug, Default)]
pub struct AccountSet {
    accounts: Vec<Account>,
}

impl AccountSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// The paper's setup: AWS + GCP already existed, Azure was created
    /// through CloudBank.
    pub fn paper_setup(now: SimTime) -> Self {
        let mut s = Self::new();
        s.link_existing(Provider::Aws, now).unwrap();
        s.link_existing(Provider::Gcp, now).unwrap();
        s.create_account(Provider::Azure, now).unwrap();
        s
    }

    pub fn create_account(
        &mut self,
        provider: Provider,
        now: SimTime,
    ) -> Result<(), String> {
        self.enroll(provider, Enrollment::CreatedByCloudbank, now)
    }

    pub fn link_existing(
        &mut self,
        provider: Provider,
        now: SimTime,
    ) -> Result<(), String> {
        self.enroll(provider, Enrollment::LinkedExisting, now)
    }

    fn enroll(
        &mut self,
        provider: Provider,
        enrollment: Enrollment,
        now: SimTime,
    ) -> Result<(), String> {
        if self.account(provider).is_some() {
            return Err(format!("{provider} account already enrolled"));
        }
        self.accounts.push(Account {
            provider,
            enrollment,
            enrolled_at: now,
            billing_connected: true,
        });
        Ok(())
    }

    pub fn account(&self, provider: Provider) -> Option<&Account> {
        self.accounts.iter().find(|a| a.provider == provider)
    }

    /// Billing feeds may only be consumed for enrolled, connected accounts.
    pub fn can_meter(&self, provider: Provider) -> bool {
        self.account(provider).map(|a| a.billing_connected).unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.accounts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accounts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_setup_has_all_three() {
        let s = AccountSet::paper_setup(0);
        assert_eq!(s.len(), 3);
        assert_eq!(
            s.account(Provider::Azure).unwrap().enrollment,
            Enrollment::CreatedByCloudbank
        );
        assert_eq!(
            s.account(Provider::Aws).unwrap().enrollment,
            Enrollment::LinkedExisting
        );
        for p in Provider::ALL {
            assert!(s.can_meter(p));
        }
    }

    #[test]
    fn double_enrollment_rejected() {
        let mut s = AccountSet::new();
        s.create_account(Provider::Azure, 0).unwrap();
        assert!(s.link_existing(Provider::Azure, 1).is_err());
    }

    #[test]
    fn unenrolled_provider_cannot_meter() {
        let s = AccountSet::new();
        assert!(!s.can_meter(Provider::Gcp));
    }
}
