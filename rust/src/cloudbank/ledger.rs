//! The CloudBank ledger: multi-provider spend aggregation + budget state.
//!
//! Provides the two services §III says were sufficient for the exercise:
//! a single-window view of total/per-provider spend against the budget,
//! and threshold-crossing alerts with the recent spending rate.

use super::account::AccountSet;
use crate::cloud::{BillingMeter, Provider};
use crate::sim::{SimTime, DAY};
use std::collections::VecDeque;

/// A snapshot of the budget "web page".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetSnapshot {
    pub at: SimTime,
    pub budget_usd: f64,
    pub spent_usd: f64,
    pub aws_usd: f64,
    pub gcp_usd: f64,
    pub azure_usd: f64,
}

impl BudgetSnapshot {
    pub fn remaining_usd(&self) -> f64 {
        self.budget_usd - self.spent_usd
    }

    pub fn remaining_fraction(&self) -> f64 {
        self.remaining_usd() / self.budget_usd
    }
}

/// A threshold alert (the periodic CloudBank email).
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    pub at: SimTime,
    /// The remaining-budget fraction threshold that was crossed (e.g. 0.5).
    pub threshold: f64,
    pub remaining_usd: f64,
    pub remaining_fraction: f64,
    /// Average spend rate over the trailing window ($/day).
    pub spend_rate_per_day: f64,
    /// Rendered email body (what the operators actually read).
    pub body: String,
}

/// The managed allocation.
#[derive(Debug)]
pub struct Ledger {
    pub accounts: AccountSet,
    pub budget_usd: f64,
    spent: [f64; 3], // indexed by provider order in Provider::ALL
    /// Per-provider (instance_hours, busy_hours) mirrored from the
    /// billing meters at sync time — the wasted-hours view of the
    /// "single window" page (Holzman et al.: wall-hour accounting is
    /// what makes cloud bursting cost-defensible).
    hours: [(f64, f64); 3],
    /// Remaining-fraction thresholds that still have a pending alert
    /// (sorted descending; e.g. [0.75, 0.5, 0.25, 0.1]).
    pending_thresholds: Vec<f64>,
    alerts: Vec<Alert>,
    /// Trailing (time, cumulative spend) samples for the spend-rate
    /// estimate in alert emails ("spending rate over the past few days").
    history: VecDeque<(SimTime, f64)>,
    history_window_s: u64,
}

impl Ledger {
    pub fn new(accounts: AccountSet, budget_usd: f64, thresholds: &[f64]) -> Self {
        let mut pending: Vec<f64> = thresholds.to_vec();
        pending.sort_by(|a, b| b.partial_cmp(a).unwrap());
        Ledger {
            accounts,
            budget_usd,
            spent: [0.0; 3],
            hours: [(0.0, 0.0); 3],
            pending_thresholds: pending,
            alerts: Vec::new(),
            history: VecDeque::new(),
            history_window_s: 3 * DAY,
        }
    }

    /// The paper's allocation: ~$58k all-included, alerts at standard
    /// CloudBank thresholds.
    pub fn paper_allocation(now: SimTime) -> Self {
        Ledger::new(
            AccountSet::paper_setup(now),
            58_000.0,
            &[0.75, 0.5, 0.25, 0.1],
        )
    }

    fn provider_idx(p: Provider) -> usize {
        Provider::ALL.iter().position(|x| *x == p).unwrap()
    }

    /// Ingest the current provider-side meters (absolute totals).
    /// Only enrolled accounts are visible to CloudBank.
    pub fn sync_from_meter(&mut self, meter: &BillingMeter, now: SimTime) {
        for p in Provider::ALL {
            if self.accounts.can_meter(p) {
                let m = meter.provider(p);
                let i = Self::provider_idx(p);
                self.spent[i] = m.spend_usd;
                self.hours[i] = (m.instance_hours, m.busy_hours);
            }
        }
        self.record_history(now);
        self.check_thresholds(now);
    }

    /// Per-provider (instance_hours, busy_hours) as of the last sync.
    pub fn hours_for(&self, p: Provider) -> (f64, f64) {
        self.hours[Self::provider_idx(p)]
    }

    /// Total billed instance-hours across enrolled providers.
    pub fn total_instance_hours(&self) -> f64 {
        self.hours.iter().map(|(i, _)| i).sum()
    }

    /// Total busy (job-executing) instance-hours across enrolled
    /// providers.
    pub fn total_busy_hours(&self) -> f64 {
        self.hours.iter().map(|(_, b)| b).sum()
    }

    fn record_history(&mut self, now: SimTime) {
        let total = self.total_spent();
        self.history.push_back((now, total));
        while let Some(&(t, _)) = self.history.front() {
            if now.saturating_sub(t) > self.history_window_s
                && self.history.len() > 2
            {
                self.history.pop_front();
            } else {
                break;
            }
        }
    }

    /// Average $/day over the trailing window.
    pub fn spend_rate_per_day(&self) -> f64 {
        match (self.history.front(), self.history.back()) {
            (Some(&(t0, s0)), Some(&(t1, s1))) if t1 > t0 => {
                (s1 - s0) / ((t1 - t0) as f64 / DAY as f64)
            }
            _ => 0.0,
        }
    }

    fn check_thresholds(&mut self, now: SimTime) {
        let snap = self.snapshot(now);
        while let Some(&th) = self.pending_thresholds.first() {
            if snap.remaining_fraction() <= th {
                self.pending_thresholds.remove(0);
                let rate = self.spend_rate_per_day();
                let body = format!(
                    "CloudBank allocation alert: remaining budget \
                     ${:.0} ({:.0}% of ${:.0}); spend rate over the past \
                     days: ${:.0}/day; at this rate funds last {:.1} more days.",
                    snap.remaining_usd(),
                    snap.remaining_fraction() * 100.0,
                    self.budget_usd,
                    rate,
                    if rate > 0.0 { snap.remaining_usd() / rate } else { f64::INFINITY },
                );
                self.alerts.push(Alert {
                    at: now,
                    threshold: th,
                    remaining_usd: snap.remaining_usd(),
                    remaining_fraction: snap.remaining_fraction(),
                    spend_rate_per_day: rate,
                    body,
                });
            } else {
                break;
            }
        }
    }

    pub fn total_spent(&self) -> f64 {
        self.spent.iter().sum()
    }

    pub fn spent_for(&self, p: Provider) -> f64 {
        self.spent[Self::provider_idx(p)]
    }

    pub fn remaining(&self) -> f64 {
        self.budget_usd - self.total_spent()
    }

    pub fn remaining_fraction(&self) -> f64 {
        self.remaining() / self.budget_usd
    }

    /// The "single window" web page.
    pub fn snapshot(&self, now: SimTime) -> BudgetSnapshot {
        BudgetSnapshot {
            at: now,
            budget_usd: self.budget_usd,
            spent_usd: self.total_spent(),
            aws_usd: self.spent_for(Provider::Aws),
            gcp_usd: self.spent_for(Provider::Gcp),
            azure_usd: self.spent_for(Provider::Azure),
        }
    }

    pub fn alerts(&self) -> &[Alert] {
        &self.alerts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::fleet::CloudSim;
    use crate::cloud::providers;
    use crate::cloud::RegionId;
    use crate::sim::HOUR;
    use crate::util::rng::Rng;

    fn meter_with_spend(az_hours: f64) -> BillingMeter {
        // run a real fleet for determinism-free spend: simpler to accrue
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        fleet.set_target(RegionId(0), 100);
        fleet.tick(0, 60);
        let mut m = BillingMeter::new();
        m.accrue(&fleet, (az_hours * 3600.0) as u64);
        m
    }

    #[test]
    fn aggregates_per_provider() {
        let mut ledger = Ledger::paper_allocation(0);
        let meter = meter_with_spend(10.0);
        ledger.sync_from_meter(&meter, HOUR);
        let snap = ledger.snapshot(HOUR);
        assert!(snap.azure_usd > 0.0);
        assert_eq!(snap.aws_usd, 0.0);
        assert!((snap.spent_usd - snap.azure_usd).abs() < 1e-9);
        assert!(snap.remaining_usd() < 58_000.0);
    }

    #[test]
    fn threshold_alerts_fire_once_in_order() {
        let mut ledger = Ledger::new(AccountSet::paper_setup(0), 100.0, &[0.5, 0.25]);
        let mut meter = BillingMeter::new();
        // hand-crafted meter states via accrual on a tiny fleet is clumsy;
        // drive thresholds through a fleet of known cost instead:
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        fleet.set_target(RegionId(0), 100); // azure @ 2.9/day/inst
        fleet.tick(0, 60);
        // 100 instances cost $12.08/h; cross 50% ($50) after ~4.1h
        for h in 1..=8 {
            meter.accrue(&fleet, 3600);
            ledger.sync_from_meter(&meter, h * HOUR);
        }
        let alerts = ledger.alerts();
        assert!(!alerts.is_empty());
        assert_eq!(alerts[0].threshold, 0.5);
        // each threshold fires exactly once
        let count_half = alerts.iter().filter(|a| a.threshold == 0.5).count();
        assert_eq!(count_half, 1);
        if alerts.len() > 1 {
            assert_eq!(alerts[1].threshold, 0.25);
            assert!(alerts[1].at > alerts[0].at);
        }
        assert!(alerts[0].body.contains("remaining budget"));
    }

    #[test]
    fn spend_rate_over_window() {
        let mut ledger = Ledger::paper_allocation(0);
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        fleet.set_target(RegionId(0), 240); // azure: $29/day at $2.9/day each... 240*2.9=$696/day
        fleet.tick(0, 60);
        let mut meter = BillingMeter::new();
        for d in 1..=4u64 {
            meter.accrue(&fleet, DAY);
            ledger.sync_from_meter(&meter, d * DAY);
        }
        let rate = ledger.spend_rate_per_day();
        assert!((rate - 240.0 * 2.9).abs() < 1.0, "rate={rate}");
    }

    #[test]
    fn hours_mirror_the_meter_split() {
        let mut ledger = Ledger::paper_allocation(0);
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        fleet.set_target(RegionId(0), 10); // azure
        fleet.tick(0, 60);
        let mut meter = BillingMeter::new();
        meter.accrue(&fleet, HOUR);
        meter.accrue_busy([0, 0, 7], HOUR);
        ledger.sync_from_meter(&meter, HOUR);
        let (instance, busy) = ledger.hours_for(Provider::Azure);
        assert!((instance - 10.0).abs() < 1e-9);
        assert!((busy - 7.0).abs() < 1e-9);
        assert_eq!(ledger.hours_for(Provider::Aws), (0.0, 0.0));
        assert!((ledger.total_instance_hours() - 10.0).abs() < 1e-9);
        assert!((ledger.total_busy_hours() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn unenrolled_provider_spend_invisible() {
        let mut accounts = AccountSet::new();
        accounts.link_existing(Provider::Aws, 0).unwrap();
        let mut ledger = Ledger::new(accounts, 1000.0, &[]);
        let meter = meter_with_spend(5.0); // all spend is on azure
        ledger.sync_from_meter(&meter, HOUR);
        assert_eq!(ledger.total_spent(), 0.0, "azure not enrolled");
    }

    #[test]
    fn remaining_fraction_math() {
        let mut ledger = Ledger::new(AccountSet::paper_setup(0), 200.0, &[]);
        assert_eq!(ledger.remaining_fraction(), 1.0);
        ledger.spent = [50.0, 0.0, 0.0];
        assert_eq!(ledger.remaining(), 150.0);
        assert_eq!(ledger.remaining_fraction(), 0.75);
    }
}
