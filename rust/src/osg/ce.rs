//! The Compute Element: OSG's portal interface in front of the cloud pool.
//!
//! The paper instantiated a dedicated HTCondor-CE on a cloud VM,
//! registered it in OSG "with the stated policy of only accepting IceCube
//! jobs", and routed all glidein traffic through it.  The CE is also the
//! campaign's single point of failure: when the provider hosting it had a
//! network outage, the whole backend WMS collapsed (Fig 1's cliff).

use crate::cloud::Provider;
use crate::sim::SimTime;

/// Reasons a pilot submission is refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CeError {
    /// VO not in the CE's authorization list.
    Unauthorized(String),
    /// CE host unreachable (provider network outage).
    Unavailable,
}

impl std::fmt::Display for CeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CeError::Unauthorized(vo) => write!(f, "VO '{vo}' not authorized"),
            CeError::Unavailable => write!(f, "CE host unreachable"),
        }
    }
}

/// A pilot (glidein) submission accepted by the CE.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PilotTicket {
    pub vo: String,
    pub accepted_at: SimTime,
}

/// The HTCondor-CE.
#[derive(Debug, Clone)]
pub struct ComputeElement {
    pub name: String,
    /// The cloud provider whose VM hosts this CE.
    pub hosted_on: Provider,
    authorized_vos: Vec<String>,
    available: bool,
    pub accepted: u64,
    pub rejected: u64,
}

impl ComputeElement {
    /// The paper's CE: dedicated VM, IceCube-only policy.
    pub fn new(name: &str, hosted_on: Provider, vos: &[&str]) -> Self {
        ComputeElement {
            name: name.to_string(),
            hosted_on,
            authorized_vos: vos.iter().map(|s| s.to_string()).collect(),
            available: true,
            accepted: 0,
            rejected: 0,
        }
    }

    pub fn authorizes(&self, vo: &str) -> bool {
        self.authorized_vos.iter().any(|v| v == vo)
    }

    /// Extend the policy to another community ("the same exact setup
    /// could have been used to serve any other set of OSG communities").
    pub fn authorize_vo(&mut self, vo: &str) {
        if !self.authorizes(vo) {
            self.authorized_vos.push(vo.to_string());
        }
    }

    pub fn set_available(&mut self, up: bool) {
        self.available = up;
    }

    pub fn is_available(&self) -> bool {
        self.available
    }

    /// Pilot factories submit through the CE; jobs of unauthorized VOs
    /// never reach the backend.
    pub fn submit_pilot(
        &mut self,
        vo: &str,
        now: SimTime,
    ) -> Result<PilotTicket, CeError> {
        if !self.available {
            self.rejected += 1;
            return Err(CeError::Unavailable);
        }
        if !self.authorizes(vo) {
            self.rejected += 1;
            return Err(CeError::Unauthorized(vo.to_string()));
        }
        self.accepted += 1;
        Ok(PilotTicket { vo: vo.to_string(), accepted_at: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ce() -> ComputeElement {
        ComputeElement::new("icecube-cloud-ce", Provider::Azure, &["icecube"])
    }

    #[test]
    fn accepts_icecube_only() {
        let mut c = ce();
        assert!(c.submit_pilot("icecube", 0).is_ok());
        assert_eq!(
            c.submit_pilot("cms", 0),
            Err(CeError::Unauthorized("cms".into()))
        );
        assert_eq!(c.accepted, 1);
        assert_eq!(c.rejected, 1);
    }

    #[test]
    fn outage_makes_ce_unavailable() {
        let mut c = ce();
        c.set_available(false);
        assert_eq!(c.submit_pilot("icecube", 5), Err(CeError::Unavailable));
        c.set_available(true);
        assert!(c.submit_pilot("icecube", 6).is_ok());
    }

    #[test]
    fn can_extend_to_other_communities() {
        let mut c = ce();
        assert!(!c.authorizes("ligo"));
        c.authorize_vo("ligo");
        assert!(c.submit_pilot("ligo", 0).is_ok());
        // idempotent
        c.authorize_vo("ligo");
        assert_eq!(c.authorized_vos.iter().filter(|v| *v == "ligo").count(), 1);
    }
}
