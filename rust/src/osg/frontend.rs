//! The glideinWMS frontend: pressure-based pilot demand.
//!
//! Watches the schedd and computes how many glideins the pool *wants*.
//! The paper's campaign drove targets manually (the ramp plan in
//! `coordinator`), but the same setup normally runs in this automatic
//! mode; we implement both and ablate them (DESIGN.md §8).

use crate::condor::Schedd;

/// Frontend demand policy knobs (glideinWMS frontend group config).
#[derive(Debug, Clone)]
pub struct FrontendPolicy {
    /// Keep at least this many glideins while any work is queued.
    pub min_glideins: u32,
    /// Never request more than this many glideins in total.
    pub max_glideins: u32,
    /// Fraction of idle jobs to cover with new pilots per cycle
    /// (glideinWMS "idle fraction" curb, avoids over-provisioning
    /// short-lived spikes).
    pub idle_fraction: f64,
    /// Extra pilots kept warm above the running count.
    pub reserve: u32,
}

impl Default for FrontendPolicy {
    fn default() -> Self {
        FrontendPolicy {
            min_glideins: 10,
            max_glideins: 2000,
            idle_fraction: 0.5,
            reserve: 50,
        }
    }
}

/// The frontend daemon.
#[derive(Debug, Default)]
pub struct GlideinFrontend {
    pub policy: FrontendPolicy,
    /// Last computed demand (monitoring).
    pub last_demand: u32,
}

impl GlideinFrontend {
    pub fn new(policy: FrontendPolicy) -> Self {
        GlideinFrontend { policy, last_demand: 0 }
    }

    /// Compute total glidein demand from queue pressure.
    pub fn demand(&mut self, schedd: &Schedd) -> u32 {
        let idle = schedd.idle_count() as f64;
        let running = schedd.running_count() as u32;
        let p = &self.policy;
        let demand = if idle == 0.0 && running == 0 {
            0
        } else {
            let idle_cover = (idle * p.idle_fraction).ceil() as u32;
            (running + idle_cover + p.reserve).max(p.min_glideins)
        };
        self.last_demand = demand.min(p.max_glideins);
        self.last_demand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::condor::job::{gpu_job_ad, gpu_requirements};
    use crate::condor::{Schedd, SlotId};

    fn schedd(idle: u64, running: u64) -> Schedd {
        let mut s = Schedd::new();
        for i in 0..(idle + running) {
            let id = s.submit(
                "icecube",
                3600,
                1e15,
                100,
                gpu_job_ad("icecube", 8192),
                gpu_requirements(),
                0,
            );
            if i >= idle {
                s.start(id, SlotId::OnPrem(i as u32), 0);
            }
        }
        s
    }

    #[test]
    fn zero_demand_on_empty_queue() {
        let mut f = GlideinFrontend::new(FrontendPolicy::default());
        assert_eq!(f.demand(&schedd(0, 0)), 0);
    }

    #[test]
    fn covers_running_plus_idle_fraction() {
        let mut f = GlideinFrontend::new(FrontendPolicy {
            min_glideins: 0,
            max_glideins: 10_000,
            idle_fraction: 0.5,
            reserve: 10,
        });
        // 100 running + ceil(200*0.5)=100 idle cover + 10 reserve
        assert_eq!(f.demand(&schedd(200, 100)), 210);
    }

    #[test]
    fn respects_max_cap() {
        let mut f = GlideinFrontend::new(FrontendPolicy {
            max_glideins: 150,
            ..FrontendPolicy::default()
        });
        assert_eq!(f.demand(&schedd(10_000, 0)), 150);
    }

    #[test]
    fn respects_min_floor_with_work() {
        let mut f = GlideinFrontend::new(FrontendPolicy {
            min_glideins: 40,
            max_glideins: 2000,
            idle_fraction: 0.1,
            reserve: 0,
        });
        assert_eq!(f.demand(&schedd(3, 0)), 40);
    }

    #[test]
    fn demand_scales_with_pressure() {
        let mut f = GlideinFrontend::new(FrontendPolicy::default());
        let lo = f.demand(&schedd(100, 0));
        let hi = f.demand(&schedd(2000, 0));
        assert!(hi > lo);
    }
}
