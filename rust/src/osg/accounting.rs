//! Gratia-style usage accounting: GPU wall hours per pool, per day.
//!
//! This is the data source of the paper's Fig 2 ("approximate doubling of
//! GPU wall hours used by IceCube"): daily wall-hour totals split between
//! on-prem and cloud resources, plus fp32 EFLOP-hour conversion at the
//! T4's 8.1 TFLOPS.

use crate::sim::{SimTime, DAY};

/// NVIDIA T4 peak fp32 throughput (TFLOPS) — the paper's EFLOP-hour basis.
pub const T4_FP32_TFLOPS: f64 = 8.1;

/// One day's usage record.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DayUsage {
    pub day: u32,
    pub cloud_gpu_hours: f64,
    pub onprem_gpu_hours: f64,
}

impl DayUsage {
    pub fn total(&self) -> f64 {
        self.cloud_gpu_hours + self.onprem_gpu_hours
    }
}

/// Wall-hour accounting ledger.
#[derive(Debug, Default)]
pub struct UsageAccounting {
    days: Vec<DayUsage>,
}

impl UsageAccounting {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accrue `dt_s` seconds of `cloud_busy` + `onprem_busy` busy GPUs
    /// ending at time `now`.
    pub fn accrue(
        &mut self,
        now: SimTime,
        dt_s: u64,
        cloud_busy: usize,
        onprem_busy: usize,
    ) {
        let day = (now / DAY) as u32;
        while self.days.len() <= day as usize {
            self.days.push(DayUsage {
                day: self.days.len() as u32,
                ..DayUsage::default()
            });
        }
        let rec = &mut self.days[day as usize];
        let dt_h = dt_s as f64 / 3600.0;
        rec.cloud_gpu_hours += cloud_busy as f64 * dt_h;
        rec.onprem_gpu_hours += onprem_busy as f64 * dt_h;
    }

    pub fn days(&self) -> &[DayUsage] {
        &self.days
    }

    pub fn total_cloud_gpu_hours(&self) -> f64 {
        self.days.iter().map(|d| d.cloud_gpu_hours).sum()
    }

    pub fn total_onprem_gpu_hours(&self) -> f64 {
        self.days.iter().map(|d| d.onprem_gpu_hours).sum()
    }

    /// The Fig-2 headline: by what factor did cloud capacity multiply the
    /// GPU wall hours available to IceCube over the period?
    pub fn expansion_factor(&self) -> f64 {
        let onprem = self.total_onprem_gpu_hours();
        if onprem == 0.0 {
            return f64::NAN;
        }
        (onprem + self.total_cloud_gpu_hours()) / onprem
    }

    /// fp32 EFLOP-hours delivered by `gpu_hours` of T4 time.
    pub fn eflop_hours(gpu_hours: f64) -> f64 {
        // TFLOPS * hours = 1e12 FLOP-hours; EFLOP-hours = /1e18 * 1e12
        gpu_hours * T4_FP32_TFLOPS / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::HOUR;

    #[test]
    fn accrues_into_day_buckets() {
        let mut acc = UsageAccounting::new();
        acc.accrue(HOUR, 3600, 100, 50);
        acc.accrue(DAY + HOUR, 3600, 200, 50);
        assert_eq!(acc.days().len(), 2);
        assert!((acc.days()[0].cloud_gpu_hours - 100.0).abs() < 1e-9);
        assert!((acc.days()[0].onprem_gpu_hours - 50.0).abs() < 1e-9);
        assert!((acc.days()[1].cloud_gpu_hours - 200.0).abs() < 1e-9);
    }

    #[test]
    fn fills_gap_days_with_zero() {
        let mut acc = UsageAccounting::new();
        acc.accrue(3 * DAY, 60, 1, 1);
        assert_eq!(acc.days().len(), 4);
        assert_eq!(acc.days()[1].total(), 0.0);
        assert_eq!(acc.days()[1].day, 1);
    }

    #[test]
    fn expansion_factor_doubling() {
        let mut acc = UsageAccounting::new();
        // equal cloud and on-prem hours => factor 2.0 (the paper's claim)
        acc.accrue(HOUR, 3600, 1000, 1000);
        assert!((acc.expansion_factor() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn eflop_hours_matches_paper_headline() {
        // 16k GPU-days = 384k GPU-hours of T4 => ~3.1 fp32 EFLOP-hours
        let eflop = UsageAccounting::eflop_hours(16_000.0 * 24.0);
        assert!((eflop - 3.1104).abs() < 0.001, "eflop={eflop}");
    }

    #[test]
    fn totals_sum_days() {
        let mut acc = UsageAccounting::new();
        acc.accrue(HOUR, 3600, 10, 5);
        acc.accrue(DAY, 1800, 20, 10);
        assert!((acc.total_cloud_gpu_hours() - (10.0 + 10.0)).abs() < 1e-9);
        assert!((acc.total_onprem_gpu_hours() - (5.0 + 5.0)).abs() < 1e-9);
    }
}
