//! OSG integration layer: Compute Element, glidein factory/frontend,
//! topology registry, and Gratia-style usage accounting.
//!
//! This is the federation glue of the paper: the CE abstracts the cloud
//! behind a standard OSG portal, the factory maps pilot demand onto
//! cloud-native group mechanisms (one entry per region), and accounting
//! produces the GPU-wall-hour records behind Fig 2.

pub mod accounting;
pub mod ce;
pub mod factory;
pub mod frontend;
pub mod registry;

pub use accounting::{DayUsage, UsageAccounting, T4_FP32_TFLOPS};
pub use ce::{CeError, ComputeElement};
pub use factory::GlideinFactory;
pub use frontend::{FrontendPolicy, GlideinFrontend};
pub use registry::OsgRegistry;
