//! OSG topology registry: where CEs and VOs are registered.
//!
//! A thin model of the OSG registration step the paper describes
//! ("registered it in OSG with the stated policy of only accepting
//! IceCube jobs") — resource records with VO allow-lists, plus the VO
//! membership list itself.

use crate::cloud::Provider;

/// A registered OSG resource (a CE endpoint).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResourceRecord {
    pub name: String,
    pub hosted_on: Provider,
    pub allowed_vos: Vec<String>,
    pub active: bool,
}

/// The topology registry.
#[derive(Debug, Default)]
pub struct OsgRegistry {
    resources: Vec<ResourceRecord>,
    vos: Vec<String>,
}

impl OsgRegistry {
    pub fn new() -> Self {
        let mut r = OsgRegistry::default();
        // communities relevant to the narrative
        for vo in ["icecube", "cms", "atlas", "ligo"] {
            r.register_vo(vo);
        }
        r
    }

    pub fn register_vo(&mut self, vo: &str) {
        if !self.vos.iter().any(|v| v == vo) {
            self.vos.push(vo.to_string());
        }
    }

    pub fn is_vo(&self, vo: &str) -> bool {
        self.vos.iter().any(|v| v == vo)
    }

    /// Register a CE; unknown VOs in the allow-list are rejected.
    pub fn register_resource(
        &mut self,
        name: &str,
        hosted_on: Provider,
        allowed_vos: &[&str],
    ) -> Result<(), String> {
        if self.resources.iter().any(|r| r.name == name) {
            return Err(format!("resource '{name}' already registered"));
        }
        for vo in allowed_vos {
            if !self.is_vo(vo) {
                return Err(format!("unknown VO '{vo}'"));
            }
        }
        self.resources.push(ResourceRecord {
            name: name.to_string(),
            hosted_on,
            allowed_vos: allowed_vos.iter().map(|s| s.to_string()).collect(),
            active: true,
        });
        Ok(())
    }

    pub fn resource(&self, name: &str) -> Option<&ResourceRecord> {
        self.resources.iter().find(|r| r.name == name)
    }

    /// Resources a VO may submit to.
    pub fn resources_for_vo(&self, vo: &str) -> Vec<&ResourceRecord> {
        self.resources
            .iter()
            .filter(|r| r.active && r.allowed_vos.iter().any(|v| v == vo))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_lookup() {
        let mut reg = OsgRegistry::new();
        reg.register_resource("icecube-cloud-ce", Provider::Azure, &["icecube"])
            .unwrap();
        let r = reg.resource("icecube-cloud-ce").unwrap();
        assert_eq!(r.hosted_on, Provider::Azure);
        assert_eq!(reg.resources_for_vo("icecube").len(), 1);
        assert!(reg.resources_for_vo("cms").is_empty());
    }

    #[test]
    fn duplicate_name_rejected() {
        let mut reg = OsgRegistry::new();
        reg.register_resource("ce", Provider::Aws, &["icecube"]).unwrap();
        assert!(reg.register_resource("ce", Provider::Gcp, &["cms"]).is_err());
    }

    #[test]
    fn unknown_vo_rejected() {
        let mut reg = OsgRegistry::new();
        assert!(reg
            .register_resource("ce", Provider::Aws, &["nonexistent"])
            .is_err());
    }
}
