//! The glidein factory: turns pilot demand into cloud group targets.
//!
//! In glideinWMS terms each cloud region is an *entry point*; the factory
//! receives per-entry pilot requests (from the frontend or the operator's
//! ramp plan), submits them through the CE, and drives the corresponding
//! cloud-native group mechanism to the requested size.  One group per
//! region, exactly as the paper describes.

use super::ce::{CeError, ComputeElement};
use crate::cloud::{CloudSim, RegionId};
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// One region entry point.
#[derive(Debug, Clone)]
pub struct Entry {
    pub region: RegionId,
    pub enabled: bool,
    /// Last target actually applied to the cloud group.
    pub applied_target: u32,
}

/// The pilot factory.
#[derive(Debug)]
pub struct GlideinFactory {
    entries: BTreeMap<RegionId, Entry>,
    pub vo: String,
    /// Target changes refused because the CE was unreachable.
    pub refused_updates: u64,
}

impl GlideinFactory {
    pub fn new(vo: &str, regions: impl Iterator<Item = RegionId>) -> Self {
        let entries = regions
            .map(|r| (r, Entry { region: r, enabled: true, applied_target: 0 }))
            .collect();
        GlideinFactory { entries, vo: vo.to_string(), refused_updates: 0 }
    }

    pub fn entry(&self, region: RegionId) -> Option<&Entry> {
        self.entries.get(&region)
    }

    pub fn enabled_entries(&self) -> impl Iterator<Item = &Entry> {
        self.entries.values().filter(|e| e.enabled)
    }

    pub fn set_enabled(&mut self, region: RegionId, enabled: bool) {
        if let Some(e) = self.entries.get_mut(&region) {
            e.enabled = enabled;
        }
    }

    /// Total pilots currently requested across entries.
    pub fn total_target(&self) -> u32 {
        self.entries.values().map(|e| e.applied_target).sum()
    }

    /// Apply per-region pilot targets through the CE to the cloud groups.
    ///
    /// New/raised targets require the CE (pilot startup needs the portal);
    /// *reducing* targets talks to the cloud control plane directly, which
    /// is how the paper's operators could deprovision everything while the
    /// CE host was down.
    pub fn apply_targets(
        &mut self,
        targets: &BTreeMap<RegionId, u32>,
        ce: &mut ComputeElement,
        fleet: &mut CloudSim,
        now: SimTime,
    ) -> Result<(), CeError> {
        let mut first_err = None;
        for (region, entry) in self.entries.iter_mut() {
            let wanted = if entry.enabled {
                targets.get(region).copied().unwrap_or(0)
            } else {
                0
            };
            if wanted == entry.applied_target {
                continue;
            }
            if wanted > entry.applied_target {
                // scale-up goes through the CE
                match ce.submit_pilot(&self.vo, now) {
                    Ok(_) => {
                        fleet.set_target(*region, wanted);
                        entry.applied_target = wanted;
                    }
                    Err(e) => {
                        self.refused_updates += 1;
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            } else {
                // scale-down is cloud-native (works during a CE outage)
                fleet.set_target(*region, wanted);
                entry.applied_target = wanted;
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Zero every group (the paper's outage response).
    pub fn deprovision_all(&mut self, fleet: &mut CloudSim) {
        for entry in self.entries.values_mut() {
            fleet.set_target(entry.region, 0);
            entry.applied_target = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::{providers, Provider};
    use crate::util::rng::Rng;

    fn setup() -> (GlideinFactory, ComputeElement, CloudSim) {
        let fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        let regions: Vec<RegionId> = fleet.regions().map(|(r, _)| r).collect();
        let factory = GlideinFactory::new("icecube", regions.into_iter());
        let ce = ComputeElement::new("ce", Provider::Azure, &["icecube"]);
        (factory, ce, fleet)
    }

    #[test]
    fn applies_targets_to_fleet() {
        let (mut factory, mut ce, mut fleet) = setup();
        let mut targets = BTreeMap::new();
        targets.insert(RegionId(0), 40u32);
        targets.insert(RegionId(1), 10u32);
        factory.apply_targets(&targets, &mut ce, &mut fleet, 0).unwrap();
        assert_eq!(fleet.region(RegionId(0)).target, 40);
        assert_eq!(fleet.region(RegionId(1)).target, 10);
        assert_eq!(factory.total_target(), 50);
    }

    #[test]
    fn scale_up_blocked_during_ce_outage() {
        let (mut factory, mut ce, mut fleet) = setup();
        ce.set_available(false);
        let mut targets = BTreeMap::new();
        targets.insert(RegionId(0), 40u32);
        let err = factory
            .apply_targets(&targets, &mut ce, &mut fleet, 0)
            .unwrap_err();
        assert_eq!(err, CeError::Unavailable);
        assert_eq!(fleet.region(RegionId(0)).target, 0);
        assert_eq!(factory.refused_updates, 1);
    }

    #[test]
    fn scale_down_works_during_ce_outage() {
        let (mut factory, mut ce, mut fleet) = setup();
        let mut targets = BTreeMap::new();
        targets.insert(RegionId(0), 40u32);
        factory.apply_targets(&targets, &mut ce, &mut fleet, 0).unwrap();
        ce.set_available(false);
        // the paper: "we quickly de-provisioned all the worker instances"
        factory.deprovision_all(&mut fleet);
        assert_eq!(fleet.region(RegionId(0)).target, 0);
        assert_eq!(factory.total_target(), 0);
    }

    #[test]
    fn disabled_entries_forced_to_zero() {
        let (mut factory, mut ce, mut fleet) = setup();
        let mut targets = BTreeMap::new();
        targets.insert(RegionId(0), 40u32);
        factory.apply_targets(&targets, &mut ce, &mut fleet, 0).unwrap();
        factory.set_enabled(RegionId(0), false);
        factory.apply_targets(&targets, &mut ce, &mut fleet, 1).unwrap();
        assert_eq!(fleet.region(RegionId(0)).target, 0);
    }

    #[test]
    fn unchanged_targets_do_not_resubmit() {
        let (mut factory, mut ce, mut fleet) = setup();
        let mut targets = BTreeMap::new();
        targets.insert(RegionId(0), 40u32);
        factory.apply_targets(&targets, &mut ce, &mut fleet, 0).unwrap();
        let accepted_before = ce.accepted;
        factory.apply_targets(&targets, &mut ce, &mut fleet, 1).unwrap();
        assert_eq!(ce.accepted, accepted_before);
    }
}
