//! Provider-side billing meters.
//!
//! Each provider meters billable instance-seconds at the region spot
//! price; CloudBank (the `cloudbank` module) aggregates the three feeds.
//! Accrual is incremental — `accrue(fleet, dt)` each tick — so the ledger
//! can alert on thresholds *during* the campaign, not after it.

use super::fleet::CloudSim;
use super::types::Provider;

/// Accumulated spend and usage per provider.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ProviderMeter {
    pub spend_usd: f64,
    /// Billable instance-hours (booting + running, claimed or not).
    pub instance_hours: f64,
    /// Instance-hours during which the slot was executing a job — the
    /// goodput-accounting numerator's upper bound.  The gap
    /// `instance_hours - busy_hours` is billed idle/boot/drain time.
    pub busy_hours: f64,
}

impl ProviderMeter {
    /// Billed hours with no job on the slot (boot, idle, drain).
    pub fn idle_hours(&self) -> f64 {
        self.instance_hours - self.busy_hours
    }
}

/// Billing meters for the whole multi-cloud fleet.
#[derive(Debug, Clone, Default)]
pub struct BillingMeter {
    aws: ProviderMeter,
    gcp: ProviderMeter,
    azure: ProviderMeter,
    /// Non-instance costs (egress, disks, the CE VM, ...) as a fraction
    /// of instance spend; the paper's $58k is "all included".
    overhead_fraction: f64,
    /// GPU slots carved from each instance (fractional-GPU accounting,
    /// arXiv:2205.09232).  Busy-hours are booked per *slot*: N busy
    /// slots on shared instances accrue N/slots instance-equivalent
    /// busy hours.  0 (the `Default`) behaves like 1 — whole-GPU
    /// accounting.
    gpu_slots: u32,
}

impl BillingMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Meter with a non-instance overhead fraction applied to spend.
    pub fn with_overhead(overhead_fraction: f64) -> Self {
        BillingMeter { overhead_fraction, ..Self::default() }
    }

    /// Meter booking busy-hours per GPU *slot* instead of per whole
    /// instance: with `n` slots carved from each instance, one busy
    /// slot-hour is `1/n` instance-hours of useful occupancy.  Spend
    /// and instance-hours are unchanged — the instance is billed
    /// whole no matter how it is carved.
    pub fn with_gpu_slots(mut self, n: u32) -> Self {
        self.gpu_slots = n;
        self
    }

    /// Accrue `dt_s` seconds of the fleet's current billable population.
    pub fn accrue(&mut self, fleet: &CloudSim, dt_s: u64) {
        let dt_h = dt_s as f64 / 3600.0;
        let cost_factor = 1.0 + self.overhead_fraction;
        for (_, region) in fleet.regions() {
            let n = region.live.len() as f64;
            if n == 0.0 {
                continue;
            }
            let m = self.meter_mut(region.spec().provider);
            m.instance_hours += n * dt_h;
            m.spend_usd += n * region.spec().price_per_hour * dt_h * cost_factor;
        }
    }

    /// Accrue `dt_s` seconds of busy (job-executing) slots per provider
    /// (`[aws, gcp, azure]`, the pool's incremental counters).  Kept
    /// separate from [`accrue`] because the busy census comes from the
    /// workload-management plane, not the fleet.
    pub fn accrue_busy(&mut self, busy: [usize; 3], dt_s: u64) {
        let dt_h = dt_s as f64 / 3600.0;
        let slots = self.gpu_slots.max(1) as f64;
        for (p, n) in Provider::ALL.into_iter().zip(busy) {
            if n > 0 {
                self.meter_mut(p).busy_hours += n as f64 * dt_h / slots;
            }
        }
    }

    pub fn provider(&self, p: Provider) -> ProviderMeter {
        match p {
            Provider::Aws => self.aws,
            Provider::Gcp => self.gcp,
            Provider::Azure => self.azure,
        }
    }

    fn meter_mut(&mut self, p: Provider) -> &mut ProviderMeter {
        match p {
            Provider::Aws => &mut self.aws,
            Provider::Gcp => &mut self.gcp,
            Provider::Azure => &mut self.azure,
        }
    }

    pub fn total_spend(&self) -> f64 {
        self.aws.spend_usd + self.gcp.spend_usd + self.azure.spend_usd
    }

    pub fn total_instance_hours(&self) -> f64 {
        self.aws.instance_hours + self.gcp.instance_hours + self.azure.instance_hours
    }

    pub fn total_busy_hours(&self) -> f64 {
        self.aws.busy_hours + self.gcp.busy_hours + self.azure.busy_hours
    }

    /// GPU-days delivered (1 instance == 1 T4).
    pub fn gpu_days(&self) -> f64 {
        self.total_instance_hours() / 24.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::fleet::CloudSim;
    use crate::cloud::providers;
    use crate::cloud::types::RegionId;
    use crate::sim::{HOUR, MINUTE};
    use crate::util::rng::Rng;

    #[test]
    fn accrues_per_provider_at_spot_price() {
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        // region 0 is azure
        fleet.set_target(RegionId(0), 10);
        fleet.tick(0, MINUTE);
        let mut meter = BillingMeter::new();
        meter.accrue(&fleet, HOUR);
        let az = meter.provider(Provider::Azure);
        assert!((az.instance_hours - 10.0).abs() < 1e-9);
        assert!((az.spend_usd - 10.0 * 2.9 / 24.0).abs() < 1e-9);
        assert_eq!(meter.provider(Provider::Aws), ProviderMeter::default());
        assert!((meter.total_spend() - az.spend_usd).abs() < 1e-12);
    }

    #[test]
    fn busy_hours_accrue_per_provider() {
        let mut m = BillingMeter::new();
        // 10 busy aws slots + 5 busy azure slots for one hour
        m.accrue_busy([10, 0, 5], HOUR);
        m.accrue_busy([0, 0, 0], HOUR); // idle tick adds nothing
        assert!((m.provider(Provider::Aws).busy_hours - 10.0).abs() < 1e-9);
        assert_eq!(m.provider(Provider::Gcp).busy_hours, 0.0);
        assert!((m.provider(Provider::Azure).busy_hours - 5.0).abs() < 1e-9);
        assert!((m.total_busy_hours() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn idle_hours_are_the_billed_gap() {
        let mut fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        fleet.set_target(RegionId(0), 10);
        fleet.tick(0, MINUTE);
        let mut meter = BillingMeter::new();
        meter.accrue(&fleet, HOUR);
        // only 6 of the 10 billed instances were executing jobs
        meter.accrue_busy([0, 0, 6], HOUR);
        let az = meter.provider(Provider::Azure);
        assert!((az.instance_hours - 10.0).abs() < 1e-9);
        assert!((az.busy_hours - 6.0).abs() < 1e-9);
        assert!((az.idle_hours() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_slot_carveup_divides_busy_hours() {
        // 4 slots per instance: 8 busy slot-hours = 2 instance-hours
        // of useful occupancy
        let mut m = BillingMeter::new().with_gpu_slots(4);
        m.accrue_busy([8, 0, 0], HOUR);
        assert!((m.provider(Provider::Aws).busy_hours - 2.0).abs() < 1e-9);
        // 0 (unset) behaves like whole-GPU accounting
        let mut whole = BillingMeter::new();
        whole.accrue_busy([8, 0, 0], HOUR);
        let mut one = BillingMeter::new().with_gpu_slots(1);
        one.accrue_busy([8, 0, 0], HOUR);
        assert_eq!(whole.total_busy_hours(), one.total_busy_hours());
        assert!((whole.total_busy_hours() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_days_conversion() {
        let mut m = BillingMeter::new();
        m.azure.instance_hours = 48.0;
        m.aws.instance_hours = 24.0;
        assert!((m.gpu_days() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_fleet_accrues_nothing() {
        let fleet = CloudSim::new(providers::all_regions(), Rng::new(1));
        let mut meter = BillingMeter::new();
        meter.accrue(&fleet, HOUR);
        assert_eq!(meter.total_spend(), 0.0);
    }
}
