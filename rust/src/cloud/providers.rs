//! Region catalogs for the three providers.
//!
//! Prices are the paper-era (late 2020 / 2021) spot prices for the
//! smallest single-T4 instance type, per T4-day:
//!
//! * Azure (NV/NC T4 v3 spot):  **$2.9 / T4-day** — the paper calls Azure
//!   out as the cheapest, with "plenty of spare capacity with very low
//!   preemption rates", which is why the exercise heavily favored Azure.
//! * GCP (n1-standard-4 + T4 preemptible): ≈ $3.5 / T4-day.
//! * AWS (g4dn.xlarge spot): ≈ $3.8 / T4-day.
//!
//! Capacity numbers are *synthetic* (real spot depth is not public); they
//! are calibrated so the Azure fleet can absorb most of the 2k-GPU peak —
//! the behaviour the paper reports — while AWS/GCP regions are shallower
//! and churn more.  See DESIGN.md §6 Substitution log.

use super::types::{Provider, RegionSpec};
use crate::net::NatProfile;

/// Default boot window: VM allocation + image boot + OSG contextualization.
const BOOT_FAST: (u64, u64) = (90, 240);
const BOOT_SLOW: (u64, u64) = (120, 360);

/// Azure regions (VMSS provisioning, default NAT with 4-min idle timeout).
pub fn azure_regions() -> Vec<RegionSpec> {
    let nat = NatProfile::azure_default();
    let mk = |name, cap, sigma, churn| RegionSpec {
        provider: Provider::Azure,
        name,
        base_capacity: cap,
        capacity_sigma: sigma,
        price_per_hour: 2.9 / 24.0,
        churn_per_hour: churn,
        boot_time_s: BOOT_FAST,
        nat,
    };
    vec![
        // deep US regions: most of the paper's capacity lived here
        mk("azure/eastus", 420.0, 25.0, 0.0015),
        mk("azure/eastus2", 350.0, 22.0, 0.0015),
        mk("azure/southcentralus", 300.0, 20.0, 0.002),
        mk("azure/westus2", 260.0, 18.0, 0.002),
        mk("azure/westeurope", 240.0, 18.0, 0.0025),
        mk("azure/northeurope", 200.0, 15.0, 0.0025),
        mk("azure/uksouth", 120.0, 12.0, 0.003),
        mk("azure/australiaeast", 100.0, 10.0, 0.003),
    ]
}

/// GCP regions (managed instance groups, permissive NAT).
pub fn gcp_regions() -> Vec<RegionSpec> {
    let nat = NatProfile::permissive("gcp-cloud-nat");
    let mk = |name, cap, sigma, churn| RegionSpec {
        provider: Provider::Gcp,
        name,
        base_capacity: cap,
        capacity_sigma: sigma,
        price_per_hour: 3.5 / 24.0,
        churn_per_hour: churn,
        boot_time_s: BOOT_FAST,
        nat,
    };
    vec![
        mk("gcp/us-central1", 180.0, 20.0, 0.006),
        mk("gcp/us-east1", 140.0, 16.0, 0.006),
        mk("gcp/us-west1", 110.0, 14.0, 0.007),
        mk("gcp/europe-west1", 100.0, 12.0, 0.007),
        mk("gcp/europe-west4", 90.0, 12.0, 0.008),
        mk("gcp/asia-east1", 70.0, 10.0, 0.009),
    ]
}

/// AWS regions (spot fleets, permissive NAT).
pub fn aws_regions() -> Vec<RegionSpec> {
    let nat = NatProfile::permissive("aws-nat-gw");
    let mk = |name, cap, sigma, churn| RegionSpec {
        provider: Provider::Aws,
        name,
        base_capacity: cap,
        capacity_sigma: sigma,
        price_per_hour: 3.8 / 24.0,
        churn_per_hour: churn,
        boot_time_s: BOOT_SLOW,
        nat,
    };
    vec![
        mk("aws/us-east-1", 200.0, 24.0, 0.008),
        mk("aws/us-east-2", 140.0, 18.0, 0.008),
        mk("aws/us-west-2", 130.0, 16.0, 0.009),
        mk("aws/eu-west-1", 100.0, 14.0, 0.010),
        mk("aws/eu-central-1", 80.0, 12.0, 0.010),
        mk("aws/ap-southeast-2", 60.0, 10.0, 0.012),
    ]
}

/// The full multi-cloud catalog used by the campaign.
pub fn all_regions() -> Vec<RegionSpec> {
    let mut v = azure_regions();
    v.extend(gcp_regions());
    v.extend(aws_regions());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn azure_is_cheapest_at_2_90_per_day() {
        // T1 headline input: Azure spot T4 at $2.9/day, cheapest of the 3
        let az = azure_regions();
        for r in &az {
            assert!((r.price_per_day() - 2.9).abs() < 1e-9);
        }
        let min_other = gcp_regions()
            .iter()
            .chain(aws_regions().iter())
            .map(|r| r.price_per_day())
            .fold(f64::INFINITY, f64::min);
        assert!(min_other > 2.9);
    }

    #[test]
    fn azure_has_most_capacity_and_least_churn() {
        let cap = |rs: &[RegionSpec]| -> f64 {
            rs.iter().map(|r| r.base_capacity).sum()
        };
        let churn = |rs: &[RegionSpec]| -> f64 {
            rs.iter().map(|r| r.churn_per_hour).sum::<f64>() / rs.len() as f64
        };
        let (az, gc, aw) = (azure_regions(), gcp_regions(), aws_regions());
        assert!(cap(&az) > cap(&gc));
        assert!(cap(&az) > cap(&aw));
        assert!(churn(&az) < churn(&gc));
        assert!(churn(&az) < churn(&aw));
    }

    #[test]
    fn total_capacity_supports_2k_peak() {
        // the paper sustained 2k GPUs; the mean spare capacity across all
        // providers must exceed that with headroom for fluctuation
        let total: f64 = all_regions().iter().map(|r| r.base_capacity).sum();
        assert!(total > 2400.0, "total={total}");
    }

    #[test]
    fn only_azure_has_aggressive_nat() {
        for r in all_regions() {
            match r.provider {
                Provider::Azure => {
                    assert_eq!(r.nat.idle_timeout_s, Some(240))
                }
                _ => assert_eq!(r.nat.idle_timeout_s, None),
            }
        }
    }

    #[test]
    fn region_names_unique() {
        let regions = all_regions();
        let mut names: Vec<_> = regions.iter().map(|r| r.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), regions.len());
    }
}
