//! Core cloud entity types and identifiers.

use crate::net::NatProfile;
use crate::sim::SimTime;

/// The three commercial cloud providers used in the paper's exercise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Provider {
    Aws,
    Gcp,
    Azure,
}

impl Provider {
    pub const ALL: [Provider; 3] = [Provider::Aws, Provider::Gcp, Provider::Azure];

    /// Index into `[aws, gcp, azure]`-ordered per-provider arrays (the
    /// one ordering used by `Provider::ALL`, pool/billing accounting
    /// and `CampaignResult::provider_ops`).
    pub fn index(self) -> usize {
        match self {
            Provider::Aws => 0,
            Provider::Gcp => 1,
            Provider::Azure => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Provider::Aws => "aws",
            Provider::Gcp => "gcp",
            Provider::Azure => "azure",
        }
    }

    /// The provider's group-provisioning mechanism (for logs/reports —
    /// the semantics the paper relies on are identical: "set the desired
    /// number of instances and get as many as available").
    pub fn group_mechanism(self) -> &'static str {
        match self {
            Provider::Aws => "spot-fleet",
            Provider::Gcp => "instance-group",
            Provider::Azure => "vmss",
        }
    }

    pub fn from_name(s: &str) -> Option<Provider> {
        match s.to_ascii_lowercase().as_str() {
            "aws" => Some(Provider::Aws),
            "gcp" => Some(Provider::Gcp),
            "azure" => Some(Provider::Azure),
            _ => None,
        }
    }
}

impl std::fmt::Display for Provider {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Index into the region table of a [`super::fleet::CloudSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub u32);

/// Unique instance identifier (monotonic across the whole campaign).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub u64);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "vm-{}", self.0)
    }
}

/// Static description of one cloud region's spot T4 market.
#[derive(Debug, Clone)]
pub struct RegionSpec {
    pub provider: Provider,
    pub name: &'static str,
    /// Mean spare spot-T4 capacity (instances) the market reverts to.
    pub base_capacity: f64,
    /// Noise amplitude of the capacity process (instances per sqrt-hour).
    pub capacity_sigma: f64,
    /// Spot price per T4 instance-hour (USD).
    pub price_per_hour: f64,
    /// Baseline preemption hazard per instance-hour (churn unrelated to
    /// capacity pressure).
    pub churn_per_hour: f64,
    /// VM boot + OSG-client contextualization time range (uniform).
    pub boot_time_s: (u64, u64),
    /// NAT behaviour on the region's outbound path.
    pub nat: NatProfile,
}

impl RegionSpec {
    pub fn price_per_day(&self) -> f64 {
        self.price_per_hour * 24.0
    }
}

/// Instance lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Provisioned, VM booting / contextualizing (billable).
    Booting,
    /// Worker software up; a startd is (or can be) registered (billable).
    Running,
    /// Reclaimed by the provider (spot preemption).
    Preempted,
    /// Deprovisioned by us (target shrink / campaign end).
    Terminated,
}

impl InstanceState {
    pub fn billable(self) -> bool {
        matches!(self, InstanceState::Booting | InstanceState::Running)
    }
}

/// Why an instance was preempted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptReason {
    /// Spot capacity shrank below our allocation; provider reclaimed.
    CapacityReclaim,
    /// Background churn (provider-side maintenance, random reclaim).
    Churn,
}

/// A provisioned cloud VM with one T4 GPU.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub region: RegionId,
    pub state: InstanceState,
    pub launched_at: SimTime,
    /// When the VM finishes booting and the worker can register.
    pub running_at: SimTime,
    /// Set when the instance leaves a billable state.
    pub stopped_at: Option<SimTime>,
    pub preempt_reason: Option<PreemptReason>,
}

impl Instance {
    /// Billable seconds accrued (up to `now` for live instances).
    pub fn billable_secs(&self, now: SimTime) -> u64 {
        let end = self.stopped_at.unwrap_or(now);
        end.saturating_sub(self.launched_at)
    }

    /// Seconds spent in the Running state (GPU wall time capacity).
    pub fn running_secs(&self, now: SimTime) -> u64 {
        let end = self.stopped_at.unwrap_or(now);
        end.saturating_sub(self.running_at.min(end))
    }
}

/// Events emitted by the cloud layer, consumed by the glidein/WMS layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloudEvent {
    /// VM provisioned and booting.
    Launched(InstanceId),
    /// VM finished booting; worker agent may register with the pool.
    BecameRunning(InstanceId),
    /// Spot preemption (graceful-ish: the worker vanishes).
    Preempted(InstanceId, PreemptReason),
    /// Deprovisioned on request (target shrink).
    Terminated(InstanceId),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provider_names_roundtrip() {
        for p in Provider::ALL {
            assert_eq!(Provider::from_name(p.name()), Some(p));
        }
        assert_eq!(Provider::from_name("AZURE"), Some(Provider::Azure));
        assert_eq!(Provider::from_name("oracle"), None);
    }

    #[test]
    fn group_mechanisms_match_paper() {
        assert_eq!(Provider::Azure.group_mechanism(), "vmss");
        assert_eq!(Provider::Gcp.group_mechanism(), "instance-group");
        assert_eq!(Provider::Aws.group_mechanism(), "spot-fleet");
    }

    #[test]
    fn billable_states() {
        assert!(InstanceState::Booting.billable());
        assert!(InstanceState::Running.billable());
        assert!(!InstanceState::Preempted.billable());
        assert!(!InstanceState::Terminated.billable());
    }

    #[test]
    fn instance_accounting() {
        let mut inst = Instance {
            id: InstanceId(1),
            region: RegionId(0),
            state: InstanceState::Running,
            launched_at: 100,
            running_at: 250,
            stopped_at: None,
            preempt_reason: None,
        };
        assert_eq!(inst.billable_secs(1100), 1000);
        assert_eq!(inst.running_secs(1250), 1000);
        inst.stopped_at = Some(2100);
        assert_eq!(inst.billable_secs(99_999), 2000);
        assert_eq!(inst.running_secs(99_999), 1850);
    }

    #[test]
    fn running_secs_zero_if_never_ran() {
        let inst = Instance {
            id: InstanceId(2),
            region: RegionId(0),
            state: InstanceState::Preempted,
            launched_at: 100,
            running_at: 400, // boot would have finished at 400
            stopped_at: Some(300), // preempted while booting
            preempt_reason: Some(PreemptReason::Churn),
        };
        assert_eq!(inst.running_secs(1000), 0);
        assert_eq!(inst.billable_secs(1000), 200);
    }
}
