//! Cloud-provider substrate: spot markets, group provisioning, billing.
//!
//! Simulates the three commercial clouds the paper provisioned from —
//! regions with synthetic spot-T4 capacity dynamics, the group
//! provisioning mechanisms (Azure VMSS, GCP Instance Groups, AWS Spot
//! Fleets) with maintain-target semantics, instance lifecycle with boot
//! latency, spot preemption (capacity reclaim + churn), and per-provider
//! billing meters.

pub mod billing;
pub mod fleet;
pub mod group;
pub mod market;
pub mod providers;
pub mod types;

pub use billing::BillingMeter;
pub use fleet::{CloudSim, FleetCounts, RegionState};
pub use types::{
    CloudEvent, Instance, InstanceId, InstanceState, PreemptReason, Provider,
    RegionId, RegionSpec,
};
