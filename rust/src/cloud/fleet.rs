//! Multi-cloud fleet simulator: regions, markets, groups and instances.
//!
//! [`CloudSim`] is the cloud-provider side of the stack: it owns every
//! region's spot market and provisioning group, advances them on a fixed
//! reconcile cadence, and emits [`CloudEvent`]s that the glidein/WMS
//! layers consume (launch → boot → running → preempted/terminated).

use super::group::{choose_scale_in_victims, plan_reconcile};
use super::market::SpotMarket;
use super::types::{
    CloudEvent, Instance, InstanceId, InstanceState, PreemptReason, Provider,
    RegionId, RegionSpec,
};
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// One region: market + provisioning group + live instance set.
#[derive(Debug)]
pub struct RegionState {
    pub market: SpotMarket,
    /// Desired group size (VMSS/MIG/fleet target).
    pub target: u32,
    /// Instances currently booting or running (group members).
    pub live: Vec<InstanceId>,
}

impl RegionState {
    pub fn spec(&self) -> &RegionSpec {
        &self.market.spec
    }
}

/// Aggregate instance counts (for monitoring).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetCounts {
    pub booting: u32,
    pub running: u32,
    pub target: u32,
}

impl FleetCounts {
    pub fn live(&self) -> u32 {
        self.booting + self.running
    }
}

/// The multi-cloud fleet simulator.
pub struct CloudSim {
    regions: Vec<RegionState>,
    instances: Vec<Instance>,
    rng: Rng,
    /// Cumulative preemptions per region (stats for the RAMP experiment).
    preemptions: Vec<u64>,
    /// Cumulative launches per region.
    launches: Vec<u64>,
}

impl CloudSim {
    pub fn new(specs: Vec<RegionSpec>, rng: Rng) -> Self {
        let preemptions = vec![0; specs.len()];
        let launches = vec![0; specs.len()];
        let regions = specs
            .into_iter()
            .map(|spec| RegionState {
                market: SpotMarket::new(spec),
                target: 0,
                live: Vec::new(),
            })
            .collect();
        CloudSim { regions, instances: Vec::new(), rng, preemptions, launches }
    }

    // ---- queries ---------------------------------------------------------

    pub fn num_regions(&self) -> usize {
        self.regions.len()
    }

    pub fn region(&self, id: RegionId) -> &RegionState {
        &self.regions[id.0 as usize]
    }

    pub fn regions(&self) -> impl Iterator<Item = (RegionId, &RegionState)> {
        self.regions
            .iter()
            .enumerate()
            .map(|(i, r)| (RegionId(i as u32), r))
    }

    pub fn instance(&self, id: InstanceId) -> &Instance {
        &self.instances[id.0 as usize]
    }

    /// All instances ever launched (terminated ones included) — accounting.
    pub fn all_instances(&self) -> &[Instance] {
        &self.instances
    }

    pub fn counts(&self) -> FleetCounts {
        let mut c = FleetCounts::default();
        for r in &self.regions {
            c.target += r.target;
            for id in &r.live {
                match self.instances[id.0 as usize].state {
                    InstanceState::Booting => c.booting += 1,
                    InstanceState::Running => c.running += 1,
                    _ => unreachable!("live list holds only billable instances"),
                }
            }
        }
        c
    }

    pub fn counts_by_provider(&self, provider: Provider) -> FleetCounts {
        let mut c = FleetCounts::default();
        for r in &self.regions {
            if r.spec().provider != provider {
                continue;
            }
            c.target += r.target;
            for id in &r.live {
                match self.instances[id.0 as usize].state {
                    InstanceState::Booting => c.booting += 1,
                    InstanceState::Running => c.running += 1,
                    _ => unreachable!(),
                }
            }
        }
        c
    }

    /// (launches, preemptions) cumulative per region.
    pub fn region_stats(&self, id: RegionId) -> (u64, u64) {
        (self.launches[id.0 as usize], self.preemptions[id.0 as usize])
    }

    /// Current billable spend rate in $/hour across the fleet.
    pub fn spend_rate_per_hour(&self) -> f64 {
        self.regions
            .iter()
            .map(|r| r.live.len() as f64 * r.spec().price_per_hour)
            .sum()
    }

    // ---- operator actions ------------------------------------------------

    /// Set one region group's desired size.
    pub fn set_target(&mut self, id: RegionId, target: u32) {
        self.regions[id.0 as usize].target = target;
    }

    /// Set every group to zero (the paper's rapid outage response).
    pub fn zero_all_targets(&mut self) {
        for r in &mut self.regions {
            r.target = 0;
        }
    }

    // ---- dynamics ----------------------------------------------------------

    /// Advance every region by `dt_s`; returns lifecycle events.
    pub fn tick(&mut self, now: SimTime, dt_s: u64) -> Vec<CloudEvent> {
        let mut events = Vec::new();
        for ridx in 0..self.regions.len() {
            self.tick_region(ridx, now, dt_s, &mut events);
        }
        events
    }

    fn tick_region(
        &mut self,
        ridx: usize,
        now: SimTime,
        dt_s: u64,
        events: &mut Vec<CloudEvent>,
    ) {
        // 1. boot completions
        {
            let region = &self.regions[ridx];
            for &id in &region.live {
                let inst = &mut self.instances[id.0 as usize];
                if inst.state == InstanceState::Booting && now >= inst.running_at
                {
                    inst.state = InstanceState::Running;
                    events.push(CloudEvent::BecameRunning(id));
                }
            }
        }

        // 2. market dynamics
        self.regions[ridx].market.tick(dt_s, &mut self.rng);

        // 3. capacity-pressure reclaim
        let live_count = self.regions[ridx].live.len() as u32;
        let reclaim = self.regions[ridx].market.reclaim_count(live_count);
        if reclaim > 0 {
            let victims = self.pick_random_live(ridx, reclaim as usize);
            for id in victims {
                self.preempt(ridx, id, now, PreemptReason::CapacityReclaim, events);
            }
        }

        // 4. churn preemption (thin hazard, sampled as a Poisson count)
        let live_count = self.regions[ridx].live.len();
        if live_count > 0 {
            let p = self.regions[ridx].market.churn_probability(dt_s);
            let expected = live_count as f64 * p;
            let k = (self.rng.poisson(expected) as usize).min(live_count);
            if k > 0 {
                let victims = self.pick_random_live(ridx, k);
                for id in victims {
                    self.preempt(ridx, id, now, PreemptReason::Churn, events);
                }
            }
        }

        // 5. group reconcile (maintain target within market headroom)
        let live = self.regions[ridx].live.len() as u32;
        let target = self.regions[ridx].target;
        let headroom = self.regions[ridx].market.headroom(live);
        let plan = plan_reconcile(live, target, headroom);
        for _ in 0..plan.launch {
            let id = self.launch(ridx, now);
            events.push(CloudEvent::Launched(id));
        }
        if plan.terminate > 0 {
            let region = &self.regions[ridx];
            let launched: Vec<u64> = region
                .live
                .iter()
                .map(|id| self.instances[id.0 as usize].launched_at)
                .collect();
            let victims = choose_scale_in_victims(
                &region.live.clone(),
                &launched,
                plan.terminate as usize,
            );
            for id in victims {
                self.terminate(ridx, id, now);
                events.push(CloudEvent::Terminated(id));
            }
        }
    }

    fn launch(&mut self, ridx: usize, now: SimTime) -> InstanceId {
        let id = InstanceId(self.instances.len() as u64);
        let (lo, hi) = self.regions[ridx].spec().boot_time_s;
        let boot = lo + self.rng.below(hi - lo + 1);
        self.instances.push(Instance {
            id,
            region: RegionId(ridx as u32),
            state: InstanceState::Booting,
            launched_at: now,
            running_at: now + boot,
            stopped_at: None,
            preempt_reason: None,
        });
        self.regions[ridx].live.push(id);
        self.launches[ridx] += 1;
        id
    }

    fn preempt(
        &mut self,
        ridx: usize,
        id: InstanceId,
        now: SimTime,
        reason: PreemptReason,
        events: &mut Vec<CloudEvent>,
    ) {
        let inst = &mut self.instances[id.0 as usize];
        debug_assert!(inst.state.billable());
        inst.state = InstanceState::Preempted;
        inst.stopped_at = Some(now);
        inst.preempt_reason = Some(reason);
        self.regions[ridx].live.retain(|x| *x != id);
        self.preemptions[ridx] += 1;
        events.push(CloudEvent::Preempted(id, reason));
    }

    fn terminate(&mut self, ridx: usize, id: InstanceId, now: SimTime) {
        let inst = &mut self.instances[id.0 as usize];
        debug_assert!(inst.state.billable());
        inst.state = InstanceState::Terminated;
        inst.stopped_at = Some(now);
        self.regions[ridx].live.retain(|x| *x != id);
    }

    fn pick_random_live(&mut self, ridx: usize, k: usize) -> Vec<InstanceId> {
        let mut pool = self.regions[ridx].live.clone();
        self.rng.shuffle(&mut pool);
        pool.truncate(k);
        pool
    }

    // ---- invariant checks (used by property tests) -------------------------

    /// Verify internal consistency; returns an error description on breach.
    pub fn check_invariants(&self, now: SimTime) -> Result<(), String> {
        for (ridx, region) in self.regions.iter().enumerate() {
            for id in &region.live {
                let inst = &self.instances[id.0 as usize];
                if !inst.state.billable() {
                    return Err(format!(
                        "region {ridx}: live list contains non-billable {id:?}"
                    ));
                }
                if inst.region.0 as usize != ridx {
                    return Err(format!("instance {id:?} in wrong region list"));
                }
            }
        }
        for inst in &self.instances {
            if inst.state.billable() && inst.stopped_at.is_some() {
                return Err(format!("billable {:?} has stopped_at", inst.id));
            }
            if !inst.state.billable() && inst.stopped_at.is_none() {
                return Err(format!("stopped {:?} missing stopped_at", inst.id));
            }
            if let Some(stop) = inst.stopped_at {
                if stop > now {
                    return Err(format!("{:?} stopped in the future", inst.id));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::providers;
    use crate::sim::MINUTE;

    fn sim() -> CloudSim {
        CloudSim::new(providers::all_regions(), Rng::new(42))
    }

    fn run_ticks(s: &mut CloudSim, start: SimTime, n: u64) -> Vec<CloudEvent> {
        let mut all = Vec::new();
        for i in 0..n {
            all.extend(s.tick(start + i * MINUTE, MINUTE));
        }
        all
    }

    #[test]
    fn provisions_toward_target() {
        let mut s = sim();
        s.set_target(RegionId(0), 50);
        let events = run_ticks(&mut s, 0, 10);
        let launched = events
            .iter()
            .filter(|e| matches!(e, CloudEvent::Launched(_)))
            .count();
        assert_eq!(launched, 50);
        assert_eq!(s.counts().live(), 50);
    }

    #[test]
    fn instances_boot_then_run() {
        let mut s = sim();
        s.set_target(RegionId(0), 10);
        run_ticks(&mut s, 0, 1);
        assert_eq!(s.counts().booting, 10);
        // boot window is <= 240 s for azure/eastus: after 6 min all run
        let events = run_ticks(&mut s, MINUTE, 6);
        let running = events
            .iter()
            .filter(|e| matches!(e, CloudEvent::BecameRunning(_)))
            .count();
        assert_eq!(running, 10);
        assert_eq!(s.counts().running, 10);
    }

    #[test]
    fn market_limits_fulfilment() {
        let mut s = sim();
        let rid = RegionId(0);
        let base = s.region(rid).spec().base_capacity;
        s.set_target(rid, (base as u32) * 3); // far beyond spare capacity
        run_ticks(&mut s, 0, 30);
        let live = s.region(rid).live.len() as f64;
        assert!(live <= base * 2.0 + 1.0, "live={live} base={base}");
        assert!(live > base * 0.5, "live={live} base={base}");
    }

    #[test]
    fn scale_to_zero_terminates_everything() {
        let mut s = sim();
        s.set_target(RegionId(0), 30);
        run_ticks(&mut s, 0, 10);
        s.zero_all_targets();
        let events = run_ticks(&mut s, 10 * MINUTE, 2);
        let terminated = events
            .iter()
            .filter(|e| matches!(e, CloudEvent::Terminated(_)))
            .count();
        assert_eq!(terminated, 30);
        assert_eq!(s.counts().live(), 0);
    }

    #[test]
    fn capacity_crash_preempts_excess() {
        let mut s = sim();
        let rid = RegionId(0);
        s.set_target(rid, 100);
        run_ticks(&mut s, 0, 10);
        assert_eq!(s.region(rid).live.len(), 100);
        // capacity collapses to 20: provider must reclaim ~80
        s.regions[rid.0 as usize].market.set_available(20.0);
        s.set_target(rid, 0); // also stop replacement launches
        let events = s.tick(11 * MINUTE, MINUTE);
        let reclaimed = events
            .iter()
            .filter(|e| {
                matches!(e, CloudEvent::Preempted(_, PreemptReason::CapacityReclaim))
            })
            .count();
        assert!(reclaimed >= 70, "reclaimed={reclaimed}");
    }

    #[test]
    fn preempted_instances_are_replaced() {
        let mut s = sim();
        let rid = RegionId(0);
        s.set_target(rid, 50);
        run_ticks(&mut s, 0, 10);
        // force a reclaim of ~10 by dropping capacity, then restore
        s.regions[rid.0 as usize].market.set_available(40.0);
        s.tick(10 * MINUTE, MINUTE);
        assert!(s.region(rid).live.len() < 50);
        s.regions[rid.0 as usize].market.set_available(400.0);
        run_ticks(&mut s, 11 * MINUTE, 5);
        assert_eq!(s.region(rid).live.len(), 50, "maintain-target must replace");
    }

    #[test]
    fn azure_churns_less_than_aws() {
        let mut s = sim();
        // find one azure and one aws region, same target
        let az = s
            .regions()
            .find(|(_, r)| r.spec().provider == Provider::Azure)
            .unwrap()
            .0;
        let aw = s
            .regions()
            .find(|(_, r)| r.spec().provider == Provider::Aws)
            .unwrap()
            .0;
        s.set_target(az, 60);
        s.set_target(aw, 60);
        run_ticks(&mut s, 0, 24 * 60); // one simulated day
        let (_, pre_az) = s.region_stats(az);
        let (_, pre_aw) = s.region_stats(aw);
        assert!(
            pre_az < pre_aw,
            "azure preemptions ({pre_az}) must be below aws ({pre_aw})"
        );
    }

    #[test]
    fn spend_rate_tracks_live_instances() {
        let mut s = sim();
        assert_eq!(s.spend_rate_per_hour(), 0.0);
        s.set_target(RegionId(0), 24);
        run_ticks(&mut s, 0, 5);
        let expected = 24.0 * s.region(RegionId(0)).spec().price_per_hour;
        assert!((s.spend_rate_per_hour() - expected).abs() < 1e-9);
    }

    #[test]
    fn invariants_hold_through_chaos() {
        let mut s = sim();
        for (i, rid) in (0..s.num_regions()).enumerate() {
            s.set_target(RegionId(rid as u32), 20 + 7 * i as u32 % 40);
        }
        let mut now = 0;
        for step in 0..600u64 {
            now = step * MINUTE;
            if step == 200 {
                s.zero_all_targets();
            }
            if step == 300 {
                for rid in 0..s.num_regions() {
                    s.set_target(RegionId(rid as u32), 30);
                }
            }
            s.tick(now, MINUTE);
        }
        s.check_invariants(now).unwrap();
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut s = CloudSim::new(providers::all_regions(), Rng::new(7));
            for rid in 0..s.num_regions() {
                s.set_target(RegionId(rid as u32), 25);
            }
            let ev = run_ticks(&mut s, 0, 120);
            (ev.len(), s.counts())
        };
        assert_eq!(run(), run());
    }
}
