//! Spot-market capacity dynamics per region.
//!
//! Real spot depth is not public; the model is a mean-reverting
//! (Ornstein-Uhlenbeck-style) *available spare capacity* process.  When a
//! region's allocation exceeds the available capacity the provider
//! reclaims the excess (capacity-pressure preemption); independently each
//! instance carries a small churn hazard.  This reproduces the
//! operationally relevant shape: partial fulfilment of group targets,
//! preemption rates that grow with the allocated fraction, and
//! provider-dependent stability (Azure deep + calm, AWS/GCP shallower +
//! busier — §IV of the paper).

use super::types::RegionSpec;
use crate::util::rng::Rng;

/// Mean-reversion rate per hour of the capacity process.
const REVERSION_PER_HOUR: f64 = 0.25;

/// One region's spot market state.
#[derive(Debug, Clone)]
pub struct SpotMarket {
    pub spec: RegionSpec,
    /// Currently available spare capacity (instances, fractional state).
    available: f64,
}

impl SpotMarket {
    pub fn new(spec: RegionSpec) -> Self {
        let available = spec.base_capacity;
        SpotMarket { spec, available }
    }

    /// Available capacity as a whole instance count, rounded to
    /// nearest.  Truncating here (`as u32`) biased `headroom` and
    /// `reclaim_count` low by up to one instance: a market at 99.9
    /// spare instances reported 99 and reclaimed an allocation of 100.
    pub fn available(&self) -> u32 {
        self.clamp_capacity(self.available).round() as u32
    }

    /// The one capacity clamp shared by every write path: the process
    /// state stays in `[0, 2 × base_capacity]`.
    fn clamp_capacity(&self, v: f64) -> f64 {
        v.clamp(0.0, self.spec.base_capacity * 2.0)
    }

    /// Advance the capacity process by `dt_s` seconds.
    pub fn tick(&mut self, dt_s: u64, rng: &mut Rng) {
        let dt_h = dt_s as f64 / 3600.0;
        let drift = REVERSION_PER_HOUR
            * (self.spec.base_capacity - self.available)
            * dt_h;
        let noise = self.spec.capacity_sigma * dt_h.sqrt() * rng.normal();
        self.available = self.clamp_capacity(self.available + drift + noise);
    }

    /// How many instances can be newly provisioned given `allocated`
    /// already running from this market.
    pub fn headroom(&self, allocated: u32) -> u32 {
        self.available().saturating_sub(allocated)
    }

    /// How many of `allocated` instances the provider reclaims right now
    /// because capacity fell below the allocation.
    pub fn reclaim_count(&self, allocated: u32) -> u32 {
        allocated.saturating_sub(self.available())
    }

    /// Per-instance probability of churn preemption over `dt_s`.
    pub fn churn_probability(&self, dt_s: u64) -> f64 {
        // hazard h per hour => p = 1 - exp(-h dt)
        let h = self.spec.churn_per_hour * dt_s as f64 / 3600.0;
        1.0 - (-h).exp()
    }

    /// Force the available capacity (tests / scenario injection).
    /// Applies the same `[0, 2 × base_capacity]` clamp `tick` enforces,
    /// so injected states can never exceed what the process itself
    /// could reach.
    pub fn set_available(&mut self, v: f64) {
        self.available = self.clamp_capacity(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::providers;
    use crate::sim::HOUR;

    fn market() -> SpotMarket {
        SpotMarket::new(providers::azure_regions().remove(0))
    }

    #[test]
    fn starts_at_base_capacity() {
        let m = market();
        assert_eq!(m.available(), m.spec.base_capacity as u32);
    }

    #[test]
    fn mean_reverts_over_time() {
        let mut m = market();
        let mut rng = Rng::new(1);
        m.set_available(0.0);
        for _ in 0..200 {
            m.tick(HOUR, &mut rng);
        }
        // after many hours the process must be back near base capacity
        let frac = m.available.max(1.0) / m.spec.base_capacity;
        assert!(frac > 0.5, "available={} base={}", m.available, m.spec.base_capacity);
    }

    #[test]
    fn stays_in_bounds() {
        let mut m = market();
        let mut rng = Rng::new(2);
        for _ in 0..5000 {
            m.tick(60, &mut rng);
            assert!(m.available >= 0.0);
            assert!(m.available <= m.spec.base_capacity * 2.0);
        }
    }

    #[test]
    fn long_run_mean_near_base() {
        let mut m = market();
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            m.tick(60, &mut rng);
            sum += m.available;
        }
        let mean = sum / n as f64;
        let rel = (mean - m.spec.base_capacity).abs() / m.spec.base_capacity;
        assert!(rel < 0.15, "mean={mean}");
    }

    #[test]
    fn headroom_and_reclaim() {
        let mut m = market();
        m.set_available(100.0);
        assert_eq!(m.headroom(40), 60);
        assert_eq!(m.headroom(100), 0);
        assert_eq!(m.headroom(150), 0);
        assert_eq!(m.reclaim_count(150), 50);
        assert_eq!(m.reclaim_count(80), 0);
    }

    #[test]
    fn churn_probability_scales_with_dt() {
        let m = market();
        let p1 = m.churn_probability(60);
        let p2 = m.churn_probability(3600);
        assert!(p1 > 0.0 && p1 < p2 && p2 < 1.0);
        // for small hazard, p(1h) ~ churn_per_hour
        assert!((p2 - m.spec.churn_per_hour).abs() / m.spec.churn_per_hour < 0.01);
    }

    #[test]
    fn available_rounds_to_nearest_not_down() {
        // regression: `as u32` truncation biased headroom/reclaim low
        // by up to one instance
        let mut m = market();
        m.set_available(99.9);
        assert_eq!(m.available(), 100);
        assert_eq!(m.headroom(40), 60);
        assert_eq!(m.reclaim_count(100), 0, "no phantom reclaim at 99.9");
        m.set_available(99.4);
        assert_eq!(m.available(), 99);
        assert_eq!(m.reclaim_count(100), 1);
    }

    #[test]
    fn set_available_shares_the_tick_clamp() {
        // regression: set_available skipped the 2×base_capacity clamp
        let mut m = market();
        let cap = m.spec.base_capacity * 2.0;
        m.set_available(1e9);
        assert_eq!(m.available, cap);
        assert_eq!(m.available(), cap as u32);
        m.set_available(-5.0);
        assert_eq!(m.available, 0.0);
        assert_eq!(m.available(), 0);
    }

    #[test]
    fn deterministic_under_same_seed() {
        let mut a = market();
        let mut b = market();
        let mut ra = Rng::new(9);
        let mut rb = Rng::new(9);
        for _ in 0..100 {
            a.tick(60, &mut ra);
            b.tick(60, &mut rb);
        }
        assert_eq!(a.available, b.available);
    }
}
