//! Group-provisioning semantics (Azure VMSS / GCP MIG / AWS Spot Fleet).
//!
//! All three mechanisms share the semantics the paper relies on: *"set
//! the desired number of instances in a specific region, and they would
//! provision as many as available at that point in time; no further
//! operator intervention was needed."*  This module captures that
//! contract as pure planning functions, applied each reconcile cycle by
//! [`super::fleet::CloudSim`].

/// What a reconcile cycle should do for one group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReconcilePlan {
    /// New instances to launch now (bounded by market headroom).
    pub launch: u32,
    /// Instances to deprovision now (target shrink).
    pub terminate: u32,
}

/// Compute the reconcile action for a group.
///
/// * `live` — instances currently booting or running,
/// * `target` — desired size set by the operator/frontend,
/// * `headroom` — spare market capacity available for new launches.
///
/// Maintain-target semantics: preempted instances are automatically
/// replaced on the next cycle (all three cloud mechanisms do this), but
/// only up to what the spot market can supply.
pub fn plan_reconcile(live: u32, target: u32, headroom: u32) -> ReconcilePlan {
    if live < target {
        ReconcilePlan { launch: (target - live).min(headroom), terminate: 0 }
    } else {
        ReconcilePlan { launch: 0, terminate: live - target }
    }
}

/// Pick deprovision victims: newest-first (cheapest sunk cost — matches
/// scale-in policy `NewestVM` which is what you want for spot workers).
///
/// `launched_at` is indexed parallel to `ids`; returns the chosen ids.
pub fn choose_scale_in_victims<I: Copy>(
    ids: &[I],
    launched_at: &[u64],
    count: usize,
) -> Vec<I> {
    assert_eq!(ids.len(), launched_at.len());
    let mut order: Vec<usize> = (0..ids.len()).collect();
    // newest (largest launched_at) first; stable on ties for determinism
    order.sort_by(|&a, &b| launched_at[b].cmp(&launched_at[a]).then(a.cmp(&b)));
    order.into_iter().take(count.min(ids.len())).map(|i| ids[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_toward_target_within_headroom() {
        assert_eq!(plan_reconcile(10, 50, 100), ReconcilePlan { launch: 40, terminate: 0 });
        // market-limited fulfilment: provision "as many as available"
        assert_eq!(plan_reconcile(10, 50, 15), ReconcilePlan { launch: 15, terminate: 0 });
        assert_eq!(plan_reconcile(10, 50, 0), ReconcilePlan { launch: 0, terminate: 0 });
    }

    #[test]
    fn shrinks_to_target() {
        assert_eq!(plan_reconcile(50, 10, 100), ReconcilePlan { launch: 0, terminate: 40 });
        assert_eq!(plan_reconcile(50, 0, 0), ReconcilePlan { launch: 0, terminate: 50 });
    }

    #[test]
    fn at_target_is_a_noop() {
        assert_eq!(plan_reconcile(25, 25, 100), ReconcilePlan::default());
    }

    #[test]
    fn replaces_preempted_instances() {
        // maintain-target: after losing 5 of 20, next cycle relaunches 5
        assert_eq!(plan_reconcile(15, 20, 100).launch, 5);
    }

    #[test]
    fn victims_are_newest_first() {
        let ids = [1u32, 2, 3, 4];
        let at = [100u64, 400, 200, 300];
        assert_eq!(choose_scale_in_victims(&ids, &at, 2), vec![2, 4]);
    }

    #[test]
    fn victims_capped_at_population() {
        let ids = [7u32];
        let at = [5u64];
        assert_eq!(choose_scale_in_victims(&ids, &at, 10), vec![7]);
    }

    #[test]
    fn victims_deterministic_on_ties() {
        let ids = [1u32, 2, 3];
        let at = [100u64, 100, 100];
        assert_eq!(choose_scale_in_victims(&ids, &at, 2), vec![1, 2]);
    }
}
