//! Hand-rolled HTTP/1.1 on `std::net`: just enough protocol for a
//! deterministic decision-support service — no external crates, matching
//! the workspace rule.
//!
//! Supported: request line + headers, `Content-Length` bodies (bounded),
//! keep-alive (HTTP/1.1 default, `Connection: close` honored), and the
//! status codes the router hands back (200/202/400/404/405/413/429/500).
//! Deliberately not supported: chunked transfer encoding (rejected with
//! 400), trailers, upgrades, TLS — a fronting proxy owns those concerns
//! in any real deployment.
//!
//! The same module carries the minimal *client* used by
//! `rust/tests/server_e2e.rs` and `rust/benches/serve.rs`, so the wire
//! format is exercised from both ends in-tree.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Cap on the request line + headers of one request.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on a request body; larger gets `413 Payload Too Large`.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `true` for HTTP/1.1, `false` for HTTP/1.0.
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to keep the connection open.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Why reading a request failed.
#[derive(Debug)]
pub enum ReadError {
    /// Clean end of stream between requests (keep-alive hang-up), or an
    /// idle-timeout expiry — either way the connection just goes away.
    Closed,
    /// Body (or declared `Content-Length`) over [`MAX_BODY_BYTES`].
    TooLarge,
    /// Anything else wrong with the wire bytes.
    Malformed(String),
}

fn io_read_error(e: std::io::Error) -> ReadError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock
        | std::io::ErrorKind::TimedOut
        | std::io::ErrorKind::ConnectionReset => ReadError::Closed,
        _ => ReadError::Malformed(format!("read: {e}")),
    }
}

fn read_line_crlf(
    reader: &mut impl BufRead,
    budget: &mut usize,
) -> Result<Option<String>, ReadError> {
    let mut raw = Vec::new();
    let n = reader
        .by_ref()
        .take(*budget as u64)
        .read_until(b'\n', &mut raw)
        .map_err(io_read_error)?;
    if n == 0 {
        return Ok(None);
    }
    if !raw.ends_with(b"\n") {
        // budget exhausted or peer died mid-line
        return Err(if n >= *budget {
            ReadError::TooLarge
        } else {
            ReadError::Malformed("truncated line".into())
        });
    }
    *budget -= n;
    while raw.last() == Some(&b'\n') || raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw)
        .map(Some)
        .map_err(|_| ReadError::Malformed("non-UTF-8 header bytes".into()))
}

/// Read one request off the connection.  `Ok(None)` means the peer
/// closed cleanly before sending another request.
pub fn read_request(
    reader: &mut BufReader<TcpStream>,
) -> Result<Option<Request>, ReadError> {
    let mut budget = MAX_HEAD_BYTES;
    let request_line = match read_line_crlf(reader, &mut budget)? {
        None => return Ok(None),
        Some(line) if line.is_empty() => {
            // tolerate a stray CRLF between pipelined requests
            match read_line_crlf(reader, &mut budget)? {
                None => return Ok(None),
                Some(line) => line,
            }
        }
        Some(line) => line,
    };
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("request line has no path".into()))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| {
            ReadError::Malformed("request line has no version".into())
        })?
        .to_string();
    let http11 = match version.as_str() {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return Err(ReadError::Malformed(format!(
                "unsupported version '{other}'"
            )))
        }
    };

    let mut headers = Vec::new();
    loop {
        let line = read_line_crlf(reader, &mut budget)?.ok_or_else(|| {
            ReadError::Malformed("EOF inside headers".into())
        })?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').ok_or_else(|| {
            ReadError::Malformed(format!("header without colon: '{line}'"))
        })?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }

    let req = Request {
        method,
        path,
        http11,
        headers,
        body: Vec::new(),
    };

    // framing headers must be unambiguous: behind a fronting proxy,
    // "first value wins" on a duplicate Content-Length is the classic
    // request-smuggling desync (RFC 9112 §6.3 requires rejection)
    let te_values: Vec<&str> = req
        .headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("transfer-encoding"))
        .map(|(_, v)| v.as_str())
        .collect();
    if te_values.len() > 1
        || te_values
            .first()
            .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err(ReadError::Malformed(
            "chunked/duplicate transfer encoding not supported".into(),
        ));
    }

    let cl_values: Vec<&str> = req
        .headers
        .iter()
        .filter(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.as_str())
        .collect();
    if cl_values.len() > 1 {
        return Err(ReadError::Malformed(
            "duplicate Content-Length headers".into(),
        ));
    }
    let content_length = match cl_values.first() {
        None => 0usize,
        Some(v) => v.trim().parse::<usize>().map_err(|_| {
            ReadError::Malformed(format!("bad Content-Length '{v}'"))
        })?,
    };
    if content_length > MAX_BODY_BYTES {
        // drain a bounded amount so the peer's in-flight write is not
        // reset before it can read the 413; bigger abusers just get the
        // hang-up
        const MAX_DRAIN_BYTES: usize = 8 * 1024 * 1024;
        if content_length <= MAX_DRAIN_BYTES {
            let _ = std::io::copy(
                &mut reader.by_ref().take(content_length as u64),
                &mut std::io::sink(),
            );
        }
        return Err(ReadError::TooLarge);
    }
    let mut req = req;
    if content_length > 0 {
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).map_err(io_read_error)?;
        req.body = body;
    }
    Ok(Some(req))
}

/// One response about to be written.  The body is shared, not owned:
/// cache hits hand the stored `Arc` straight through to the socket
/// write, so the hot path the result cache exists to serve never pays
/// a per-request copy of a multi-hundred-KB sweep response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: std::sync::Arc<Vec<u8>>,
    /// Extra headers (e.g. `X-Cache`, `Allow`).
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: Vec<u8>) -> Response {
        Response::json_shared(status, std::sync::Arc::new(body))
    }

    /// JSON response over an already-shared body (cache hits).
    pub fn json_shared(
        status: u16,
        body: std::sync::Arc<Vec<u8>>,
    ) -> Response {
        Response {
            status,
            content_type: "application/json",
            body,
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: std::sync::Arc::new(body.into_bytes()),
            extra_headers: Vec::new(),
        }
    }

    /// An SVG body (`GET /dash`).
    pub fn svg(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "image/svg+xml",
            body: std::sync::Arc::new(body.into_bytes()),
            extra_headers: Vec::new(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra_headers
            .push((name.to_string(), value.to_string()));
        self
    }
}

/// The machine-readable error code for each status this service emits —
/// the stable half of the canonical error body (`detail` is prose and
/// may change wording; `error` is contract).
pub fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        413 => "payload_too_large",
        429 => "too_many_requests",
        500 => "internal",
        _ => "error",
    }
}

/// The one canonical error body of the whole API surface (DESIGN.md
/// §19): `{"error": <code>, "detail": <message>}`.  Every error site in
/// `server/*` funnels through here (or [`error_response_after`]), so no
/// handler can invent an ad-hoc shape.
pub fn error_response(status: u16, detail: &str) -> Response {
    error_body(status, detail, None)
}

/// [`error_response`] plus a `retry_after` field in the body and the
/// matching `Retry-After` header (429 admission-control responses).
pub fn error_response_after(
    status: u16,
    detail: &str,
    retry_after_s: u64,
) -> Response {
    error_body(status, detail, Some(retry_after_s))
        .with_header("Retry-After", &retry_after_s.to_string())
}

fn error_body(status: u16, detail: &str, retry_after_s: Option<u64>) -> Response {
    use crate::util::json::Json;
    let mut o = Json::obj();
    o.set("error", Json::from(error_code(status)));
    o.set("detail", Json::from(detail));
    if let Some(s) = retry_after_s {
        o.set("retry_after", Json::from(s));
    }
    let mut body = o.to_string_compact().into_bytes();
    body.push(b'\n');
    Response::json(status, body)
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write `resp`; `keep_alive` decides the `Connection` header.
pub fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

// ---- in-tree client (tests + load generator) ----------------------------

/// A response as seen by the in-tree client.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Read one response from `reader` (shared by one-shot and keep-alive
/// clients).
pub fn read_client_response(
    reader: &mut impl BufRead,
) -> Result<ClientResponse, String> {
    let mut status_line = String::new();
    reader
        .read_line(&mut status_line)
        .map_err(|e| format!("read status: {e}"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| format!("bad status line '{status_line}'"))?
        .parse()
        .map_err(|_| format!("bad status in '{status_line}'"))?;
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .map_err(|e| format!("read header: {e}"))?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim().to_string();
            let value = value.trim().to_string();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .parse()
                    .map_err(|_| format!("bad content-length '{value}'"))?;
            }
            headers.push((name, value));
        }
    }
    let mut body = vec![0u8; content_length];
    reader
        .read_exact(&mut body)
        .map_err(|e| format!("read body: {e}"))?;
    Ok(ClientResponse { status, headers, body })
}

/// One-shot request: connect, send, read the response, close.
pub fn client_request(
    addr: &str,
    method: &str,
    path: &str,
    content_type: Option<&str>,
    body: &[u8],
) -> Result<ClientResponse, String> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\nContent-Length: {}\r\n",
        body.len()
    );
    if let Some(ct) = content_type {
        head.push_str("Content-Type: ");
        head.push_str(ct);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body))
        .map_err(|e| format!("send: {e}"))?;
    let mut reader = BufReader::new(stream);
    read_client_response(&mut reader)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Feed raw bytes through a real socket pair and parse them.
    fn parse_raw(raw: &[u8]) -> Result<Option<Request>, ReadError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let result = read_request(&mut reader);
        writer.join().unwrap();
        result
    }

    #[test]
    fn parses_get_with_headers() {
        let req = parse_raw(
            b"GET /healthz HTTP/1.1\r\nHost: x\r\nX-Thing: v\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert!(req.http11);
        assert_eq!(req.header("x-thing"), Some("v"));
        assert!(req.keep_alive());
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse_raw(
            b"POST /sweep HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let req = parse_raw(
            b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert!(!req.keep_alive());
        let req =
            parse_raw(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive(), "HTTP/1.0 defaults to close");
    }

    #[test]
    fn eof_is_clean_close() {
        assert!(matches!(parse_raw(b""), Ok(None)));
    }

    #[test]
    fn garbage_is_malformed() {
        assert!(matches!(
            parse_raw(b"NOT A REQUEST\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_raw(b"GET / HTTP/2\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_raw(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_raw(b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_raw(
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"
            ),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn conflicting_framing_headers_rejected() {
        // duplicate Content-Length: first-wins parsing behind a proxy
        // that honors the last value is a CL.CL desync — reject
        assert!(matches!(
            parse_raw(
                b"POST / HTTP/1.1\r\nContent-Length: 5\r\n\
                  Content-Length: 30\r\n\r\nhello"
            ),
            Err(ReadError::Malformed(_))
        ));
        // even duplicates that agree are a smuggling tell
        assert!(matches!(
            parse_raw(
                b"POST / HTTP/1.1\r\nContent-Length: 5\r\n\
                  Content-Length: 5\r\n\r\nhello"
            ),
            Err(ReadError::Malformed(_))
        ));
        assert!(matches!(
            parse_raw(
                b"POST / HTTP/1.1\r\nTransfer-Encoding: identity\r\n\
                  Transfer-Encoding: chunked\r\n\r\n"
            ),
            Err(ReadError::Malformed(_))
        ));
    }

    #[test]
    fn oversized_declared_body_is_too_large() {
        let raw = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(matches!(
            parse_raw(raw.as_bytes()),
            Err(ReadError::TooLarge)
        ));
    }

    #[test]
    fn oversized_head_is_too_large() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend_from_slice(
            format!("X-Big: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES))
                .as_bytes(),
        );
        assert!(matches!(parse_raw(&raw), Err(ReadError::TooLarge)));
    }

    #[test]
    fn response_wire_format_round_trips() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let resp = Response::json(200, b"{\"ok\":true}".to_vec())
                .with_header("X-Cache", "hit");
            write_response(&mut stream, &resp, false).unwrap();
        });
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream);
        let resp = read_client_response(&mut reader).unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.header("x-cache"), Some("hit"));
        assert_eq!(resp.body, b"{\"ok\":true}");
    }

    #[test]
    fn error_response_is_canonical_json() {
        let r = error_response(400, "bad spec");
        assert_eq!(r.status, 400);
        let v = crate::util::json::parse(
            std::str::from_utf8(&r.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("bad_request"));
        assert_eq!(v.get("detail").unwrap().as_str(), Some("bad spec"));
        assert!(v.get("retry_after").is_none(), "only 429s carry it");
    }

    #[test]
    fn retry_after_appears_in_body_and_header() {
        let r = error_response_after(429, "queue full", 3);
        assert_eq!(r.status, 429);
        assert!(r
            .extra_headers
            .iter()
            .any(|(k, v)| k == "Retry-After" && v == "3"));
        let v = crate::util::json::parse(
            std::str::from_utf8(&r.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(
            v.get("error").unwrap().as_str(),
            Some("too_many_requests")
        );
        assert_eq!(v.get("retry_after").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn every_emitted_status_has_a_stable_code() {
        for (status, code) in [
            (400, "bad_request"),
            (404, "not_found"),
            (405, "method_not_allowed"),
            (413, "payload_too_large"),
            (429, "too_many_requests"),
            (500, "internal"),
        ] {
            assert_eq!(error_code(status), code);
        }
    }
}
