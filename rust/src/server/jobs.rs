//! Replay execution behind `POST /sweep`: the persistent worker pool
//! (sync requests) and the asynchronous job table (`?mode=async`).
//!
//! The CLI sweep spins up scoped threads per invocation and lets them
//! die; a server cannot afford thread churn per request, and — more
//! important — needs *global* admission control: however many HTTP
//! connections are asking for sweeps, at most `threads` campaign
//! replays run at once and everything else queues.  Workers execute
//! boxed closures from an mpsc channel; `run_matrix` fans a scenario
//! list out as one job per scenario and parks on a countdown latch
//! until every slot is filled, so results keep the deterministic
//! matrix order that `sweep::run_matrix` pins.
//!
//! [`JobTable`] is the async layer over the same machinery (DESIGN.md
//! §14): a bounded admission queue of sweep jobs, drained by a few
//! runner threads that execute through the shared
//! [`ResultCache::get_or_compute`] + [`ReplayPool::run_matrix`] path —
//! so an async job, a sync request and a restart-warmed disk entry all
//! produce byte-identical bodies, and concurrent duplicates
//! single-flight no matter which door they came through.  Job ids
//! *are* the sweep content address, which is what makes duplicate
//! async submissions collapse to one job for free.

use super::cache::{render_sweep_body, Outcome, ResultCache};
use super::events::{EventBus, EventKind};
use super::fleet::FleetTable;
use super::metrics::Metrics;
use crate::config::CampaignConfig;
use crate::coordinator::ScenarioConfig;
use crate::sweep::{runner, ScenarioSummary};
use crate::util::json::Json;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Poison-tolerant lock.  A job or runner that panicked mid-update
/// poisons the mutex; every subsequent `lock().unwrap()` would then
/// cascade the panic through unrelated threads and silently kill the
/// async queue.  All the states guarded here (job records, the work
/// queue, result slots, the countdown latch) stay structurally valid
/// across a panic — the panicking path at worst leaves one job stuck
/// in `Running`, which is exactly what the `Failed` bookkeeping in
/// `runner_loop` repairs — so clearing the poison flag is safe.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Fixed-size worker pool; dropped pools drain their queue and join.
pub struct ReplayPool {
    tx: Option<mpsc::Sender<Job>>,
    depth: Arc<AtomicUsize>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl ReplayPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let depth = Arc::clone(&depth);
            workers.push(std::thread::spawn(move || loop {
                let job = match lock(&rx).recv() {
                    Ok(job) => job,
                    Err(_) => break, // pool dropped, queue drained
                };
                // a raw job that panics must not take the worker thread
                // with it (a 1-thread pool would deadlock every later
                // run_matrix) nor leak the depth gauge
                let _ = catch_unwind(AssertUnwindSafe(job));
                depth.fetch_sub(1, Ordering::Relaxed);
            }));
        }
        ReplayPool { tx: Some(tx), depth, threads, workers }
    }

    /// Jobs queued or running.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Concurrent replay workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool sender lives until drop")
            .send(Box::new(job))
            .expect("replay pool workers outlive the pool handle");
    }

    /// Replay every scenario against `base` on the pool and return the
    /// rows in matrix order.  Blocks the calling (HTTP worker or job
    /// runner) thread; the replays themselves run on the pool's
    /// threads.  A panicking replay (a pathological request config)
    /// yields an error instead of poisoning the pool or hanging the
    /// caller.
    pub fn run_matrix(
        &self,
        base: &CampaignConfig,
        scenarios: &[ScenarioConfig],
    ) -> Result<Vec<ScenarioSummary>, String> {
        if scenarios.is_empty() {
            return Ok(Vec::new());
        }
        let n = scenarios.len();
        let slots: Arc<Vec<Mutex<Option<ScenarioSummary>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let latch = Arc::new((Mutex::new(n), Condvar::new()));
        // nested-parallelism budget: all pool workers together may use
        // at most the machine (workers × engine threads ≤ cores); the
        // clamp never changes rows, results are engine-thread-invariant
        let mut base = base.clone();
        base.engine
            .clamp_threads(runner::engine_thread_budget(self.threads));
        let base = Arc::new(base);

        for (i, scenario) in scenarios.iter().cloned().enumerate() {
            let slots = Arc::clone(&slots);
            let latch = Arc::clone(&latch);
            let base = Arc::clone(&base);
            self.execute(move || {
                // the latch must count down even if the replay panics,
                // or the requester would wait forever
                let row = catch_unwind(AssertUnwindSafe(|| {
                    runner::run_scenario(&base, &scenario)
                }))
                .ok();
                *lock(&slots[i]) = row;
                let (count, cv) = &*latch;
                let mut remaining = lock(count);
                *remaining -= 1;
                if *remaining == 0 {
                    cv.notify_all();
                }
            });
        }

        let (count, cv) = &*latch;
        let mut remaining = lock(count);
        while *remaining > 0 {
            remaining =
                cv.wait(remaining).unwrap_or_else(|e| e.into_inner());
        }
        drop(remaining);

        let mut rows = Vec::with_capacity(n);
        for (i, slot) in slots.iter().enumerate() {
            match lock(slot).take() {
                Some(row) => rows.push(row),
                None => {
                    return Err(format!(
                        "scenario '{}' panicked during replay",
                        scenarios[i].name
                    ))
                }
            }
        }
        Ok(rows)
    }
}

impl Drop for ReplayPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit after draining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---- the async job table -------------------------------------------------

/// Finished jobs kept for `GET /jobs` before the oldest are forgotten
/// (`[server] jobs_keep` overrides per server).
pub const DEFAULT_JOBS_KEEP: usize = 1024;

/// Everything a queued job needs to run later.
pub struct JobSpec {
    /// The sweep content address (`cache::sweep_key`) — also the job id.
    pub key: String,
    pub resolved: CampaignConfig,
    pub scenarios: Vec<ScenarioConfig>,
}

/// The job lifecycle: `queued → running → done | failed`; a failed job
/// may be resubmitted, which re-queues it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Queued,
    Running,
    Done,
    Failed,
}

impl Phase {
    fn as_str(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Running => "running",
            Phase::Done => "done",
            Phase::Failed => "failed",
        }
    }
}

struct JobRecord {
    phase: Phase,
    scenarios: usize,
    submitted: Instant,
    started: Option<Instant>,
    finished: Option<Instant>,
    error: Option<String>,
    /// Present only while queued; taken by the runner that picks it up.
    spec: Option<JobSpec>,
}

struct JobsInner {
    jobs: HashMap<String, JobRecord>,
    /// Queued job ids in admission order (front = next to run).
    pending: VecDeque<String>,
    /// Every tracked job id in submission order (front = oldest).
    order: VecDeque<String>,
}

struct Shared {
    state: Mutex<JobsInner>,
    work: Condvar,
    stop: AtomicBool,
}

/// What `submit` decided.
#[derive(Debug)]
pub enum Admission {
    /// Queued (or completed instantly off the cache).
    Accepted { id: String },
    /// An identical job already exists — single-flight dedup.
    Duplicate { id: String },
    /// The admission queue is full; retry after the hinted delay.
    Shed { retry_after_s: u64 },
}

/// One job's externally visible status snapshot.
pub struct JobView {
    pub id: String,
    pub status: &'static str,
    /// 1-based position among queued jobs (queued only).
    pub queue_position: Option<usize>,
    pub scenarios: usize,
    /// Seconds since submission.
    pub age_s: f64,
    /// Seconds spent queued before a runner picked the job up.
    pub wait_s: Option<f64>,
    /// Seconds running (so far, or total once finished).
    pub run_s: Option<f64>,
    pub error: Option<String>,
}

impl JobView {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::from(self.id.as_str()));
        o.set("status", Json::from(self.status));
        o.set("scenarios", Json::from(self.scenarios));
        o.set("age_s", Json::from(self.age_s));
        if let Some(p) = self.queue_position {
            o.set("queue_position", Json::from(p));
        }
        if let Some(w) = self.wait_s {
            o.set("wait_s", Json::from(w));
        }
        if let Some(r) = self.run_s {
            o.set("run_s", Json::from(r));
        }
        if let Some(e) = &self.error {
            o.set("error", Json::from(e.as_str()));
        }
        if self.status == "done" {
            o.set(
                "result",
                Json::from(format!("/results/{}", self.id)),
            );
        }
        o
    }
}

/// The asynchronous sweep-job subsystem: a bounded admission queue
/// drained by dedicated runner threads.  Runners — not HTTP handlers —
/// block on the replay pool, so `POST /sweep?mode=async` returns in
/// microseconds however deep the backlog is, and saturation surfaces
/// as an explicit `Shed` instead of a stalled accept loop.
pub struct JobTable {
    shared: Arc<Shared>,
    cache: Arc<ResultCache>,
    metrics: Arc<Metrics>,
    events: Arc<EventBus>,
    queue_max: usize,
    jobs_keep: usize,
    runners: Vec<JoinHandle<()>>,
}

impl JobTable {
    /// Spawn `runners` job-runner threads over the shared cache/pool.
    /// Jobs drain through the fleet when remote workers are registered
    /// and fall back to the local pool when none are (`fleet.run_matrix`
    /// makes that call per sweep).  Every lifecycle transition is
    /// published to `events`; `jobs_keep` bounds how many finished
    /// records `GET /jobs` retains.
    pub fn start(
        queue_max: usize,
        runners: usize,
        cache: Arc<ResultCache>,
        pool: Arc<ReplayPool>,
        fleet: Arc<FleetTable>,
        metrics: Arc<Metrics>,
        events: Arc<EventBus>,
        jobs_keep: usize,
    ) -> JobTable {
        let shared = Arc::new(Shared {
            state: Mutex::new(JobsInner {
                jobs: HashMap::new(),
                pending: VecDeque::new(),
                order: VecDeque::new(),
            }),
            work: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(runners.max(1));
        for _ in 0..runners.max(1) {
            let shared = Arc::clone(&shared);
            let cache = Arc::clone(&cache);
            let pool = Arc::clone(&pool);
            let fleet = Arc::clone(&fleet);
            let metrics = Arc::clone(&metrics);
            let events = Arc::clone(&events);
            handles.push(std::thread::spawn(move || {
                runner_loop(&shared, &cache, &pool, &fleet, &metrics, &events)
            }));
        }
        JobTable {
            shared,
            cache,
            metrics,
            events,
            queue_max: queue_max.max(1),
            jobs_keep: jobs_keep.max(1),
            runners: handles,
        }
    }

    /// Admit one async sweep.  Duplicates of an in-flight job join it;
    /// a spec whose result is already retrievable (either cache tier)
    /// completes instantly without taking a queue slot; terminal jobs
    /// whose result is *not* retrievable any more — failed, or done
    /// but since evicted/quarantined — re-queue like new submissions
    /// (the job API must never point at a result it cannot produce);
    /// a full queue sheds.
    pub fn submit(&self, spec: JobSpec) -> Admission {
        let id = spec.key.clone();
        {
            let st = lock(&self.shared.state);
            if in_flight(&st, &id) {
                return Admission::Duplicate { id };
            }
        }
        // absent or terminal: does the result exist right now?  Probed
        // outside the jobs lock (it may touch disk).
        let cached = match self.cache.lookup(&id) {
            Some((_, Outcome::DiskHit)) => {
                // the store-hit counter covers every disk-tier serve,
                // whichever door asked (see router::results)
                self.metrics.on_disk_hit();
                true
            }
            Some(_) => true,
            None => false,
        };
        let mut st = lock(&self.shared.state);
        if in_flight(&st, &id) {
            // lost a race with an identical submission
            return Admission::Duplicate { id };
        }
        let now = Instant::now();
        if cached {
            match st.jobs.get_mut(&id) {
                // a done job whose result still serves: plain dedup
                Some(rec) if rec.phase == Phase::Done => {
                    return Admission::Duplicate { id }
                }
                // failed earlier, but something (a sync request, a
                // restart-warmed store) has produced the result since
                Some(rec) => {
                    rec.phase = Phase::Done;
                    rec.error = None;
                    rec.submitted = now;
                    rec.started = Some(now);
                    rec.finished = Some(now);
                    let scenarios = rec.scenarios;
                    self.metrics.on_job_submitted();
                    self.metrics.on_job_finished(true);
                    self.events.publish(EventKind::JobQueued {
                        id: id.clone(),
                        scenarios,
                    });
                    self.events
                        .publish(EventKind::JobDone { id: id.clone() });
                    return Admission::Accepted { id };
                }
                None => {
                    st.jobs.insert(
                        id.clone(),
                        JobRecord {
                            phase: Phase::Done,
                            scenarios: spec.scenarios.len(),
                            submitted: now,
                            started: Some(now),
                            finished: Some(now),
                            error: None,
                            spec: None,
                        },
                    );
                    st.order.push_back(id.clone());
                    gc(&mut st, self.jobs_keep);
                    self.metrics.on_job_submitted();
                    self.metrics.on_job_finished(true);
                    self.events.publish(EventKind::JobQueued {
                        id: id.clone(),
                        scenarios: spec.scenarios.len(),
                    });
                    self.events
                        .publish(EventKind::JobDone { id: id.clone() });
                    return Admission::Accepted { id };
                }
            }
        }
        // not retrievable: queue it (fresh submission) or re-queue it
        // (failed / done-but-lost)
        if st.pending.len() >= self.queue_max {
            self.metrics.on_job_shed();
            return Admission::Shed {
                retry_after_s: retry_after(st.pending.len()),
            };
        }
        let scenarios = spec.scenarios.len();
        let record = JobRecord {
            phase: Phase::Queued,
            scenarios,
            submitted: now,
            started: None,
            finished: None,
            error: None,
            spec: Some(spec),
        };
        if st.jobs.insert(id.clone(), record).is_none() {
            st.order.push_back(id.clone());
        }
        st.pending.push_back(id.clone());
        gc(&mut st, self.jobs_keep);
        self.metrics.on_job_submitted();
        // published before the jobs lock is released, so the matching
        // job.running can never be sequenced ahead of this job.queued
        self.events
            .publish(EventKind::JobQueued { id: id.clone(), scenarios });
        self.shared.work.notify_one();
        Admission::Accepted { id }
    }

    /// Snapshot one job.
    pub fn view(&self, id: &str) -> Option<JobView> {
        let st = lock(&self.shared.state);
        let rec = st.jobs.get(id)?;
        Some(view_of(&st, id, rec))
    }

    /// Snapshot every tracked job in submission order.
    pub fn list(&self) -> Vec<JobView> {
        let st = lock(&self.shared.state);
        st.order
            .iter()
            .filter_map(|id| st.jobs.get(id).map(|r| view_of(&st, id, r)))
            .collect()
    }

    /// `(queued, running)` gauge pair for `/metrics`.
    pub fn counts(&self) -> (usize, usize) {
        let st = lock(&self.shared.state);
        let running = st
            .jobs
            .values()
            .filter(|r| r.phase == Phase::Running)
            .count();
        (st.pending.len(), running)
    }
}

impl Drop for JobTable {
    fn drop(&mut self) {
        {
            // set the flag under the state lock so a runner between its
            // stop-check and its wait cannot miss the wakeup
            let _st = lock(&self.shared.state);
            self.shared.stop.store(true, Ordering::SeqCst);
            self.shared.work.notify_all();
        }
        for h in self.runners.drain(..) {
            let _ = h.join();
        }
    }
}

/// Backlog-proportional retry hint for `Retry-After`.
fn retry_after(pending: usize) -> u64 {
    1 + pending as u64
}

/// Queued or running: a duplicate submission joins such a job; only
/// terminal (or absent) records may be (re)queued or completed.
fn in_flight(st: &JobsInner, id: &str) -> bool {
    matches!(
        st.jobs.get(id).map(|r| r.phase),
        Some(Phase::Queued | Phase::Running)
    )
}

fn view_of(st: &JobsInner, id: &str, rec: &JobRecord) -> JobView {
    let now = Instant::now();
    let run_s = rec.started.map(|t0| {
        rec.finished.unwrap_or(now).duration_since(t0).as_secs_f64()
    });
    let wait_s = rec
        .started
        .map(|t0| t0.duration_since(rec.submitted).as_secs_f64());
    JobView {
        id: id.to_string(),
        status: rec.phase.as_str(),
        queue_position: if rec.phase == Phase::Queued {
            st.pending.iter().position(|p| p == id).map(|i| i + 1)
        } else {
            None
        },
        scenarios: rec.scenarios,
        age_s: now.duration_since(rec.submitted).as_secs_f64(),
        wait_s,
        run_s,
        error: rec.error.clone(),
    }
}

/// Forget the oldest *finished* jobs once the table outgrows `keep`
/// (`[server] jobs_keep`).  Unfinished jobs are skipped, not a
/// stopping point — a long-running job at the front must not let
/// finished records behind it pile up unboundedly.  Queued and running
/// jobs are never dropped (the queue bound and the runner count cap
/// them independently), so the table stays within `keep` plus that
/// small in-flight margin.
fn gc(st: &mut JobsInner, keep: usize) {
    if st.order.len() <= keep {
        return;
    }
    let mut excess = st.order.len() - keep;
    let mut kept = VecDeque::with_capacity(st.order.len());
    while let Some(id) = st.order.pop_front() {
        let finished = !in_flight(st, &id);
        if excess > 0 && finished {
            st.jobs.remove(&id);
            excess -= 1;
        } else {
            kept.push_back(id);
        }
    }
    st.order = kept;
}

fn runner_loop(
    shared: &Shared,
    cache: &ResultCache,
    pool: &ReplayPool,
    fleet: &FleetTable,
    metrics: &Metrics,
    events: &EventBus,
) {
    loop {
        let (id, spec) = {
            let mut st = lock(&shared.state);
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = st.pending.pop_front() {
                    // a record missing its entry or spec means a
                    // previous runner panicked between popping and
                    // taking; skip the orphan instead of cascading
                    let Some(rec) = st.jobs.get_mut(&id) else {
                        continue;
                    };
                    let Some(spec) = rec.spec.take() else {
                        // never leave a spec-less record Queued — it
                        // could not run and would dedup submissions
                        // into a job that never finishes
                        rec.phase = Phase::Failed;
                        rec.finished = Some(Instant::now());
                        rec.error =
                            Some("queued job lost its spec".to_string());
                        events.publish(EventKind::JobFailed {
                            id: id.clone(),
                            error: "queued job lost its spec".to_string(),
                        });
                        continue;
                    };
                    rec.phase = Phase::Running;
                    rec.started = Some(Instant::now());
                    events.publish(EventKind::JobRunning {
                        id: id.clone(),
                    });
                    break (id, spec);
                }
                st = shared
                    .work
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            }
        };

        // the exact machinery the sync path uses: shared single-flight
        // cache over the shared fleet/pool dispatch, so async results
        // are byte-identical to sync ones by construction.  A panic
        // anywhere in the compute path must not kill this runner
        // thread — the job is marked failed and the queue keeps
        // draining.
        let replays = spec.scenarios.len();
        let computed = catch_unwind(AssertUnwindSafe(|| {
            let (result, outcome) =
                cache.get_or_compute(&spec.key, || {
                    let rows = fleet.run_matrix(
                        pool,
                        &spec.resolved,
                        &spec.scenarios,
                    )?;
                    metrics.on_sweep_computed(
                        replays,
                        rows.iter().map(|r| r.goodput_hours).sum(),
                        rows.iter().map(|r| r.wasted_hours).sum(),
                    );
                    Ok(render_sweep_body(&spec.key, &rows))
                });
            match (&result, outcome) {
                (_, Outcome::Miss) => metrics
                    .on_lookup_outcome(Outcome::Miss, cache.has_disk()),
                (Ok(_), o) => {
                    metrics.on_lookup_outcome(o, cache.has_disk())
                }
                (Err(_), _) => {} // a waiter surfacing the owner's error
            }
            result
        }));
        let result = match computed {
            Ok(result) => result.map(|_| ()),
            Err(_) => Err("job runner panicked".to_string()),
        };

        let mut st = lock(&shared.state);
        let Some(rec) = st.jobs.get_mut(&id) else {
            // gc'd mid-run (cannot happen while Running today, but a
            // missing record must not bring the runner down)
            continue;
        };
        rec.finished = Some(Instant::now());
        match result {
            Ok(()) => {
                rec.phase = Phase::Done;
                metrics.on_job_finished(true);
                events.publish(EventKind::JobDone { id: id.clone() });
            }
            Err(e) => {
                rec.phase = Phase::Failed;
                rec.error = Some(e.clone());
                metrics.on_job_finished(false);
                events.publish(EventKind::JobFailed {
                    id: id.clone(),
                    error: e,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::events::{Delivery, DEFAULT_EVENTS_RING};
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};
    use std::time::Duration;

    fn tiny_base() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * HOUR;
        c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
        c.outage = None;
        c.onprem.slots = 8;
        c.generator.min_backlog = 30;
        c
    }

    #[test]
    fn pool_matches_direct_runner_output() {
        let base = tiny_base();
        let scenarios = vec![
            ScenarioConfig::named("one"),
            {
                let mut s = ScenarioConfig::named("two");
                s.seed = Some(7);
                s
            },
        ];
        let pool = ReplayPool::new(2);
        let pooled = pool.run_matrix(&base, &scenarios).unwrap();
        let direct = crate::sweep::run_matrix(&base, &scenarios, 2);
        assert_eq!(pooled, direct);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn pool_is_reusable_across_matrices() {
        let base = tiny_base();
        let pool = ReplayPool::new(2);
        let a = pool
            .run_matrix(&base, &[ScenarioConfig::named("a")])
            .unwrap();
        let b = pool
            .run_matrix(&base, &[ScenarioConfig::named("a")])
            .unwrap();
        assert_eq!(a, b, "same pool, same request, same rows");
    }

    #[test]
    fn empty_matrix_is_empty() {
        let pool = ReplayPool::new(1);
        assert!(pool
            .run_matrix(&tiny_base(), &[])
            .unwrap()
            .is_empty());
    }

    #[test]
    fn pool_reports_thread_count() {
        assert_eq!(ReplayPool::new(3).threads(), 3);
        assert_eq!(ReplayPool::new(0).threads(), 1);
    }

    #[test]
    fn concurrent_requesters_share_the_pool() {
        let base = tiny_base();
        let pool = Arc::new(ReplayPool::new(2));
        let mut handles = Vec::new();
        for i in 0..3u64 {
            let pool = Arc::clone(&pool);
            let base = base.clone();
            handles.push(std::thread::spawn(move || {
                let mut s = ScenarioConfig::named("shared");
                s.seed = Some(i);
                pool.run_matrix(&base, &[s]).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 1);
        }
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn panicking_job_reports_error_and_pool_survives() {
        let pool = ReplayPool::new(1);
        // drive a panic through the raw job interface
        let latch = Arc::new((Mutex::new(1usize), Condvar::new()));
        {
            let latch = Arc::clone(&latch);
            pool.execute(move || {
                let result: Result<(), _> =
                    catch_unwind(|| panic!("boom"));
                assert!(result.is_err());
                let (count, cv) = &*latch;
                *count.lock().unwrap() -= 1;
                cv.notify_all();
            });
        }
        let (count, cv) = &*latch;
        let mut remaining = count.lock().unwrap();
        while *remaining > 0 {
            remaining = cv.wait(remaining).unwrap();
        }
        drop(remaining);
        // the worker survived the caught panic and still runs jobs
        let rows = pool
            .run_matrix(&tiny_base(), &[ScenarioConfig::named("after")])
            .unwrap();
        assert_eq!(rows.len(), 1);
    }

    // ---- JobTable ------------------------------------------------------

    fn idle_fleet() -> Arc<FleetTable> {
        use super::super::fleet::FleetOptions;
        Arc::new(FleetTable::new(FleetOptions::default()))
    }

    fn table(queue_max: usize, runners: usize) -> JobTable {
        table_on_bus(
            queue_max,
            runners,
            Arc::new(EventBus::new(DEFAULT_EVENTS_RING)),
        )
    }

    fn table_on_bus(
        queue_max: usize,
        runners: usize,
        events: Arc<EventBus>,
    ) -> JobTable {
        JobTable::start(
            queue_max,
            runners,
            Arc::new(ResultCache::new(1 << 20)),
            Arc::new(ReplayPool::new(1)),
            idle_fleet(),
            Arc::new(Metrics::new()),
            events,
            DEFAULT_JOBS_KEEP,
        )
    }

    fn spec(name: &str, seed: u64) -> JobSpec {
        let base = tiny_base();
        let mut s = ScenarioConfig::named(name);
        s.seed = Some(seed);
        let scenarios = vec![s];
        JobSpec {
            key: super::super::cache::sweep_key(&base, &scenarios),
            resolved: base,
            scenarios,
        }
    }

    fn wait_done(t: &JobTable, id: &str) -> JobView {
        for _ in 0..1000 {
            let v = t.view(id).expect("job exists");
            if v.status == "done" || v.status == "failed" {
                return v;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("job {id} did not finish");
    }

    #[test]
    fn lifecycle_queued_to_done() {
        let t = table(8, 1);
        let id = match t.submit(spec("a", 1)) {
            Admission::Accepted { id } => id,
            other => panic!("expected Accepted, got {other:?}"),
        };
        let v = wait_done(&t, &id);
        assert_eq!(v.status, "done");
        assert!(v.run_s.is_some());
        assert!(v.wait_s.is_some());
        assert!(v.error.is_none());
        assert_eq!(t.counts(), (0, 0));
    }

    #[test]
    fn duplicates_collapse_to_one_job() {
        let t = table(8, 1);
        let id = match t.submit(spec("a", 2)) {
            Admission::Accepted { id } => id,
            other => panic!("{other:?}"),
        };
        for _ in 0..4 {
            match t.submit(spec("a", 2)) {
                Admission::Duplicate { id: d } => assert_eq!(d, id),
                // the first duplicate may race job completion and land
                // on the instant-done path — still the same id
                Admission::Accepted { id: d } => assert_eq!(d, id),
                other => panic!("{other:?}"),
            }
        }
        wait_done(&t, &id);
        assert_eq!(t.list().len(), 1, "one job for N identical submits");
    }

    #[test]
    fn full_queue_sheds() {
        // no runners draining: occupy the queue with distinct jobs
        let t = JobTable::start(
            2,
            1,
            Arc::new(ResultCache::new(1 << 20)),
            Arc::new(ReplayPool::new(1)),
            idle_fleet(),
            Arc::new(Metrics::new()),
            Arc::new(EventBus::new(DEFAULT_EVENTS_RING)),
            DEFAULT_JOBS_KEEP,
        );
        // first job goes to the runner; make it slow enough to hold the
        // runner by using a real (if tiny) replay, then fill the queue
        let mut accepted = 0;
        let mut shed = 0;
        for i in 0..20u64 {
            match t.submit(spec("flood", i)) {
                Admission::Accepted { .. } => accepted += 1,
                Admission::Shed { retry_after_s } => {
                    assert!(retry_after_s >= 1);
                    shed += 1;
                }
                Admission::Duplicate { .. } => {}
            }
        }
        assert!(accepted >= 1);
        assert!(shed >= 1, "20 rapid distinct submits must shed");
    }

    #[test]
    fn cached_result_completes_instantly() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let s = spec("warm", 9);
        let key = s.key.clone();
        cache
            .get_or_compute(&key, || Ok(b"already here".to_vec()))
            .0
            .unwrap();
        let t = JobTable::start(
            4,
            1,
            Arc::clone(&cache),
            Arc::new(ReplayPool::new(1)),
            idle_fleet(),
            Arc::new(Metrics::new()),
            Arc::new(EventBus::new(DEFAULT_EVENTS_RING)),
            DEFAULT_JOBS_KEEP,
        );
        match t.submit(s) {
            Admission::Accepted { id } => {
                let v = t.view(&id).unwrap();
                assert_eq!(v.status, "done", "no queue slot needed");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn view_reports_queue_positions() {
        // a runnerless table would be ideal; approximate by flooding a
        // 1-runner table and checking positions are 1-based and ordered
        let t = table(8, 1);
        let ids: Vec<String> = (0..4u64)
            .filter_map(|i| match t.submit(spec("pos", i)) {
                Admission::Accepted { id } => Some(id),
                _ => None,
            })
            .collect();
        // one list() call snapshots every position under a single lock
        // acquisition, so the live runner cannot shift the queue
        // between per-id reads
        let positions: Vec<usize> = t
            .list()
            .iter()
            .filter_map(|v| v.queue_position)
            .collect();
        for w in positions.windows(2) {
            assert!(w[0] < w[1], "queue positions must be ordered");
        }
        for id in &ids {
            wait_done(&t, id);
        }
    }

    #[test]
    fn done_but_evicted_result_requeues() {
        // memory-only cache with a 1-byte budget: only the newest
        // entry survives, so a finished job's result can vanish
        let cache = Arc::new(ResultCache::new(1));
        let t = JobTable::start(
            4,
            1,
            Arc::clone(&cache),
            Arc::new(ReplayPool::new(1)),
            idle_fleet(),
            Arc::new(Metrics::new()),
            Arc::new(EventBus::new(DEFAULT_EVENTS_RING)),
            DEFAULT_JOBS_KEEP,
        );
        let s = spec("evict", 1);
        let key = s.key.clone();
        let id = match t.submit(s) {
            Admission::Accepted { id } => id,
            other => panic!("{other:?}"),
        };
        wait_done(&t, &id);
        // evict the job's result by inserting another entry
        let other_key = "0".repeat(64);
        cache
            .get_or_compute(&other_key, || Ok(vec![0u8; 8]))
            .0
            .unwrap();
        assert!(cache.lookup(&key).is_none(), "result evicted");
        // resubmission must requeue and recompute, never dedup into a
        // done job whose result cannot be fetched any more
        match t.submit(spec("evict", 1)) {
            Admission::Accepted { id: requeued } => {
                assert_eq!(requeued, id)
            }
            other => panic!("expected a requeue, got {other:?}"),
        }
        let v = wait_done(&t, &id);
        assert_eq!(v.status, "done");
        assert!(cache.lookup(&key).is_some(), "result recomputed");
    }

    #[test]
    fn gc_skips_unfinished_front_entries() {
        let mut st = JobsInner {
            jobs: HashMap::new(),
            pending: VecDeque::new(),
            order: VecDeque::new(),
        };
        let now = Instant::now();
        let mk = |phase: Phase| JobRecord {
            phase,
            scenarios: 1,
            submitted: now,
            started: None,
            finished: None,
            error: None,
            spec: None,
        };
        // a long-running job sits at the very front of the order...
        st.jobs.insert("running".into(), mk(Phase::Running));
        st.order.push_back("running".into());
        // ...followed by more finished records than the cap allows
        for i in 0..(DEFAULT_JOBS_KEEP + 10) {
            let id = format!("done-{i}");
            st.jobs.insert(id.clone(), mk(Phase::Done));
            st.order.push_back(id);
        }
        gc(&mut st, DEFAULT_JOBS_KEEP);
        assert_eq!(
            st.order.len(),
            DEFAULT_JOBS_KEEP,
            "gc must reclaim past an unfinished front entry"
        );
        assert!(
            st.jobs.contains_key("running"),
            "in-flight jobs survive gc"
        );
        assert!(!st.jobs.contains_key("done-0"), "oldest finished go");
        assert!(st
            .jobs
            .contains_key(&format!("done-{}", DEFAULT_JOBS_KEEP + 9)));
    }

    #[test]
    fn gc_honors_a_small_jobs_keep() {
        let mut st = JobsInner {
            jobs: HashMap::new(),
            pending: VecDeque::new(),
            order: VecDeque::new(),
        };
        let now = Instant::now();
        for i in 0..10 {
            let id = format!("done-{i}");
            st.jobs.insert(
                id.clone(),
                JobRecord {
                    phase: Phase::Done,
                    scenarios: 1,
                    submitted: now,
                    started: None,
                    finished: None,
                    error: None,
                    spec: None,
                },
            );
            st.order.push_back(id);
        }
        gc(&mut st, 2);
        assert_eq!(st.order.len(), 2);
        assert!(!st.jobs.contains_key("done-0"));
        assert!(st.jobs.contains_key("done-8"));
        assert!(st.jobs.contains_key("done-9"));
    }

    #[test]
    fn lifecycle_publishes_typed_events_in_order() {
        let bus = Arc::new(EventBus::new(64));
        let mut sub = bus.subscribe(None);
        let t = table_on_bus(8, 1, Arc::clone(&bus));
        let id = match t.submit(spec("evented", 4)) {
            Admission::Accepted { id } => id,
            other => panic!("{other:?}"),
        };
        wait_done(&t, &id);
        let mut names = Vec::new();
        while names.len() < 3 {
            match sub.next(Duration::from_secs(5)) {
                Delivery::Batch { events, dropped, .. } => {
                    assert_eq!(dropped, 0, "64-slot ring cannot wrap");
                    names.extend(
                        events.iter().map(|e| e.kind.name().to_string()),
                    );
                }
                other => panic!("missing events: {names:?} ({other:?})"),
            }
        }
        assert_eq!(
            names,
            vec!["job.queued", "job.running", "job.done"],
            "exact lifecycle, in sequence order, exactly once"
        );
        // nothing further arrives for a finished job
        assert!(matches!(
            sub.next(Duration::from_millis(50)),
            Delivery::Idle
        ));
    }

    #[test]
    fn job_view_renders_json() {
        let v = JobView {
            id: "abc".into(),
            status: "done",
            queue_position: None,
            scenarios: 3,
            age_s: 1.5,
            wait_s: Some(0.1),
            run_s: Some(1.0),
            error: None,
        };
        let j = v.to_json();
        assert_eq!(j.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("scenarios").unwrap().as_u64(), Some(3));
        assert_eq!(
            j.get("result").unwrap().as_str(),
            Some("/results/abc")
        );
        let v = JobView {
            id: "def".into(),
            status: "queued",
            queue_position: Some(2),
            scenarios: 1,
            age_s: 0.0,
            wait_s: None,
            run_s: None,
            error: None,
        };
        let j = v.to_json();
        assert_eq!(j.get("queue_position").unwrap().as_u64(), Some(2));
        assert!(j.get("result").is_none());
    }

    // ---- panic/poison regressions --------------------------------------

    #[test]
    fn uncaught_panicking_job_does_not_kill_the_pool_worker() {
        // unlike panicking_job_reports_error_and_pool_survives, this
        // job does NOT catch its own panic: the unwind reaches the
        // worker loop.  Before the worker-side catch_unwind, the sole
        // worker thread died here, the depth gauge leaked, and every
        // later run_matrix on the pool hung forever.
        let pool = ReplayPool::new(1);
        pool.execute(|| panic!("uncaught boom"));
        let rows = pool
            .run_matrix(&tiny_base(), &[ScenarioConfig::named("after")])
            .unwrap();
        assert_eq!(rows.len(), 1, "worker survived the unwind");
        for _ in 0..1000 {
            if pool.queue_depth() == 0 {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("queue depth leaked after a panicking job");
    }

    #[test]
    fn poisoned_jobs_mutex_still_drains_the_queue() {
        // a thread panicking while holding the job-table mutex poisons
        // it; every lock().unwrap() after that cascaded the panic
        // through submit/view/runner threads and silently killed the
        // async queue.  The poison-tolerant lock() keeps it draining.
        let t = table(8, 1);
        let shared = Arc::clone(&t.shared);
        let _ = std::thread::spawn(move || {
            let _guard = shared.state.lock().unwrap();
            panic!("poison the jobs mutex");
        })
        .join();
        assert!(t.shared.state.is_poisoned(), "mutex must be poisoned");
        let id = match t.submit(spec("poisoned", 3)) {
            Admission::Accepted { id } => id,
            other => panic!("expected Accepted, got {other:?}"),
        };
        let v = wait_done(&t, &id);
        assert_eq!(v.status, "done", "queue drains past the poison");
        assert_eq!(t.counts(), (0, 0));
    }
}
