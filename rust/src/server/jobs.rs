//! The persistent replay worker pool behind `POST /sweep`.
//!
//! The CLI sweep spins up scoped threads per invocation and lets them
//! die; a server cannot afford thread churn per request, and — more
//! important — needs *global* admission control: however many HTTP
//! connections are asking for sweeps, at most `threads` campaign
//! replays run at once and everything else queues.  Workers execute
//! boxed closures from an mpsc channel; `run_matrix` fans a scenario
//! list out as one job per scenario and parks on a countdown latch
//! until every slot is filled, so results keep the deterministic
//! matrix order that `sweep::run_matrix` pins.

use crate::config::CampaignConfig;
use crate::coordinator::ScenarioConfig;
use crate::sweep::{runner, ScenarioSummary};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool; dropped pools drain their queue and join.
pub struct ReplayPool {
    tx: Option<mpsc::Sender<Job>>,
    depth: Arc<AtomicUsize>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl ReplayPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let depth = Arc::clone(&depth);
            workers.push(std::thread::spawn(move || loop {
                let job = match rx.lock().unwrap().recv() {
                    Ok(job) => job,
                    Err(_) => break, // pool dropped, queue drained
                };
                job();
                depth.fetch_sub(1, Ordering::Relaxed);
            }));
        }
        ReplayPool { tx: Some(tx), depth, threads, workers }
    }

    /// Jobs queued or running.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Concurrent replay workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool sender lives until drop")
            .send(Box::new(job))
            .expect("replay pool workers outlive the pool handle");
    }

    /// Replay every scenario against `base` on the pool and return the
    /// rows in matrix order.  Blocks the calling (HTTP worker) thread;
    /// the replays themselves run on the pool's threads.  A panicking
    /// replay (a pathological request config) yields an error instead
    /// of poisoning the pool or hanging the caller.
    pub fn run_matrix(
        &self,
        base: &CampaignConfig,
        scenarios: &[ScenarioConfig],
    ) -> Result<Vec<ScenarioSummary>, String> {
        if scenarios.is_empty() {
            return Ok(Vec::new());
        }
        let n = scenarios.len();
        let slots: Arc<Vec<Mutex<Option<ScenarioSummary>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let latch = Arc::new((Mutex::new(n), Condvar::new()));
        // nested-parallelism budget: all pool workers together may use
        // at most the machine (workers × engine threads ≤ cores); the
        // clamp never changes rows, results are engine-thread-invariant
        let mut base = base.clone();
        base.engine
            .clamp_threads(runner::engine_thread_budget(self.threads));
        let base = Arc::new(base);

        for (i, scenario) in scenarios.iter().cloned().enumerate() {
            let slots = Arc::clone(&slots);
            let latch = Arc::clone(&latch);
            let base = Arc::clone(&base);
            self.execute(move || {
                // the latch must count down even if the replay panics,
                // or the requester would wait forever
                let row = catch_unwind(AssertUnwindSafe(|| {
                    runner::run_scenario(&base, &scenario)
                }))
                .ok();
                *slots[i].lock().unwrap() = row;
                let (count, cv) = &*latch;
                let mut remaining = count.lock().unwrap();
                *remaining -= 1;
                if *remaining == 0 {
                    cv.notify_all();
                }
            });
        }

        let (count, cv) = &*latch;
        let mut remaining = count.lock().unwrap();
        while *remaining > 0 {
            remaining = cv.wait(remaining).unwrap();
        }
        drop(remaining);

        let mut rows = Vec::with_capacity(n);
        for (i, slot) in slots.iter().enumerate() {
            match slot.lock().unwrap().take() {
                Some(row) => rows.push(row),
                None => {
                    return Err(format!(
                        "scenario '{}' panicked during replay",
                        scenarios[i].name
                    ))
                }
            }
        }
        Ok(rows)
    }
}

impl Drop for ReplayPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit after draining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};

    fn tiny_base() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * HOUR;
        c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
        c.outage = None;
        c.onprem.slots = 8;
        c.generator.min_backlog = 30;
        c
    }

    #[test]
    fn pool_matches_direct_runner_output() {
        let base = tiny_base();
        let scenarios = vec![
            ScenarioConfig::named("one"),
            {
                let mut s = ScenarioConfig::named("two");
                s.seed = Some(7);
                s
            },
        ];
        let pool = ReplayPool::new(2);
        let pooled = pool.run_matrix(&base, &scenarios).unwrap();
        let direct = crate::sweep::run_matrix(&base, &scenarios, 2);
        assert_eq!(pooled, direct);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn pool_is_reusable_across_matrices() {
        let base = tiny_base();
        let pool = ReplayPool::new(2);
        let a = pool
            .run_matrix(&base, &[ScenarioConfig::named("a")])
            .unwrap();
        let b = pool
            .run_matrix(&base, &[ScenarioConfig::named("a")])
            .unwrap();
        assert_eq!(a, b, "same pool, same request, same rows");
    }

    #[test]
    fn empty_matrix_is_empty() {
        let pool = ReplayPool::new(1);
        assert!(pool
            .run_matrix(&tiny_base(), &[])
            .unwrap()
            .is_empty());
    }

    #[test]
    fn pool_reports_thread_count() {
        assert_eq!(ReplayPool::new(3).threads(), 3);
        assert_eq!(ReplayPool::new(0).threads(), 1);
    }

    #[test]
    fn concurrent_requesters_share_the_pool() {
        let base = tiny_base();
        let pool = Arc::new(ReplayPool::new(2));
        let mut handles = Vec::new();
        for i in 0..3u64 {
            let pool = Arc::clone(&pool);
            let base = base.clone();
            handles.push(std::thread::spawn(move || {
                let mut s = ScenarioConfig::named("shared");
                s.seed = Some(i);
                pool.run_matrix(&base, &[s]).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 1);
        }
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn panicking_job_reports_error_and_pool_survives() {
        let pool = ReplayPool::new(1);
        // drive a panic through the raw job interface
        let latch = Arc::new((Mutex::new(1usize), Condvar::new()));
        {
            let latch = Arc::clone(&latch);
            pool.execute(move || {
                let result: Result<(), _> =
                    catch_unwind(|| panic!("boom"));
                assert!(result.is_err());
                let (count, cv) = &*latch;
                *count.lock().unwrap() -= 1;
                cv.notify_all();
            });
        }
        let (count, cv) = &*latch;
        let mut remaining = count.lock().unwrap();
        while *remaining > 0 {
            remaining = cv.wait(remaining).unwrap();
        }
        drop(remaining);
        // the worker survived the caught panic and still runs jobs
        let rows = pool
            .run_matrix(&tiny_base(), &[ScenarioConfig::named("after")])
            .unwrap();
        assert_eq!(rows.len(), 1);
    }
}
