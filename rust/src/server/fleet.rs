//! Distributed worker fleet: lease/heartbeat work distribution.
//!
//! The paper's production story is a pool stitched together from
//! preemptible cloud instances behind OSG/HTCondor: workers join a
//! central pool, pull work, and vanish without notice when the spot
//! market reclaims them.  This module reproduces that shape for the
//! sweep service.  `icecloud serve` becomes a coordinator that leases
//! scenario units to pull-based `icecloud worker` processes over the
//! in-tree HTTP stack:
//!
//! ```text
//!   worker                         coordinator
//!     | POST /fleet/register         |  upsert worker (id, slots)
//!     | POST /fleet/lease            |  pending unit -> lease(deadline)
//!     | POST /fleet/heartbeat        |  deadline = now + lease_ttl
//!     | POST /fleet/complete         |  sha256 check -> spot check
//!     |                              |    -> deliver into SweepFlight
//! ```
//!
//! A lease whose deadline passes without a heartbeat is *expired*: the
//! unit goes back on the pending queue exactly like a preempted job in
//! the checkpoint lifecycle, and the next worker to ask gets it.  The
//! same determinism that makes the result cache content-addressable
//! makes fleet validation a hash compare: any worker replaying a unit
//! produces byte-identical wire bytes, so the coordinator can (a)
//! verify the declared sha256 against its own re-rendering of the row
//! and (b) for a sampled fraction of units, recompute the unit locally
//! and require the bytes to match before admitting the result.
//! Admitted rows flow into the SAME `ResultCache::get_or_compute`
//! single-flight path as locally-computed sweeps, so fleet-computed
//! and locally-computed responses are indistinguishable.
//!
//! Conservation invariant (pinned by `tests/prop_fleet.rs`): at every
//! step `granted == completed + expired + rejected + outstanding`, no
//! live unit is ever granted to two workers, and no unit is ever lost
//! — every unit is pending, leased, or delivered into its flight.

use super::events::{EventBus, EventKind, DEFAULT_EVENTS_RING};
use super::http::client_request;
use super::jobs::ReplayPool;
use crate::config::CampaignConfig;
use crate::coordinator::ScenarioConfig;
use crate::sweep::runner;
use crate::util::json::{self, Json};
use crate::util::sha256;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Poison-tolerant lock: a panicking holder (a worker thread that died
/// mid-update) must not cascade into every other thread that touches
/// the table.  The data is counters and queues — the worst a panicked
/// writer leaves behind is a stale `last_seen`, which the expiry sweep
/// repairs.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Coordinator-side fleet knobs (strict `[fleet]` TOML via
/// `config::FleetConfig`, flags via `icecloud serve`).
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// How long a lease lives without a heartbeat before the unit is
    /// requeued.
    pub lease_ttl: Duration,
    /// Heartbeat cadence advertised to workers at registration.
    pub heartbeat_every: Duration,
    /// Fraction of units the coordinator recomputes locally and
    /// byte-compares before admitting the worker's result (0 = trust,
    /// 1 = verify everything).
    pub spot_check_rate: f64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            lease_ttl: Duration::from_secs(30),
            heartbeat_every: Duration::from_secs(10),
            spot_check_rate: 0.1,
        }
    }
}

/// Point-in-time fleet accounting, sampled for `/metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FleetStats {
    pub workers_registered: usize,
    pub workers_alive: usize,
    pub units_pending: usize,
    pub leases_granted: u64,
    pub leases_completed: u64,
    pub leases_expired: u64,
    pub leases_rejected: u64,
    pub leases_outstanding: usize,
    pub spot_checks_pass: u64,
    pub spot_checks_fail: u64,
}

/// One scenario's worth of work: the *applied* config (base + scenario
/// overrides already resolved), so a worker needs no scenario-merge
/// logic — it replays exactly the config the coordinator would have.
#[derive(Clone)]
struct Unit {
    unit_id: u64,
    name: String,
    cfg: Arc<CampaignConfig>,
    flight: Arc<SweepFlight>,
    slot: usize,
}

struct Lease {
    unit: Unit,
    worker_id: String,
    deadline: Instant,
    spot_check: bool,
}

/// What `POST /fleet/lease` hands to a worker.
pub struct LeaseGrant {
    pub lease_id: u64,
    pub unit_id: u64,
    pub name: String,
    pub config: Arc<CampaignConfig>,
}

/// Outcome of `POST /fleet/complete`.
#[derive(Debug, PartialEq)]
pub enum CompleteOutcome {
    /// Row admitted and delivered into its sweep.
    Accepted,
    /// No such live lease (expired, already completed, or never
    /// granted) — the lease table is untouched.
    Unknown,
    /// Row failed validation (bad sha, wrong scenario, spot-check
    /// divergence); the lease is revoked and the unit requeued.
    Rejected(String),
}

struct WorkerInfo {
    #[allow(dead_code)]
    slots: u32,
    last_seen: Instant,
}

struct FleetInner {
    workers: HashMap<String, WorkerInfo>,
    pending: VecDeque<Unit>,
    leases: HashMap<u64, Lease>,
    next_unit_id: u64,
    next_lease_id: u64,
    granted: u64,
    completed: u64,
    expired: u64,
    rejected: u64,
    spot_pass: u64,
    spot_fail: u64,
}

/// One in-flight sweep: a slot per scenario, filled as workers (or the
/// local fallback) deliver rows.  Slot order is scenario order, so the
/// assembled row vector is position-identical to `pool.run_matrix`.
pub struct SweepFlight {
    inner: Mutex<FlightInner>,
    done: Condvar,
}

struct FlightInner {
    slots: Vec<Option<runner::ScenarioSummary>>,
    remaining: usize,
}

impl SweepFlight {
    fn new(n: usize) -> Arc<SweepFlight> {
        Arc::new(SweepFlight {
            inner: Mutex::new(FlightInner {
                slots: vec![None; n],
                remaining: n,
            }),
            done: Condvar::new(),
        })
    }

    /// Fill a slot; returns false if it was already filled (a late
    /// duplicate from a worker that raced lease expiry — dropped).
    fn deliver(&self, slot: usize, row: runner::ScenarioSummary) -> bool {
        let mut g = lock(&self.inner);
        if g.slots[slot].is_some() {
            return false;
        }
        g.slots[slot] = Some(row);
        g.remaining -= 1;
        if g.remaining == 0 {
            self.done.notify_all();
        }
        true
    }

    fn rows_if_done(&self) -> Option<Vec<runner::ScenarioSummary>> {
        let g = lock(&self.inner);
        if g.remaining != 0 {
            return None;
        }
        Some(g.slots.iter().map(|s| s.clone().expect("slot filled")).collect())
    }

    /// Slots already delivered (for invariant checks in tests).
    pub fn filled_slots(&self) -> Vec<usize> {
        let g = lock(&self.inner);
        g.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i))
            .collect()
    }

    fn wait_some(&self, timeout: Duration) {
        let g = lock(&self.inner);
        if g.remaining == 0 {
            return;
        }
        let _ = self
            .done
            .wait_timeout(g, timeout)
            .unwrap_or_else(|e| e.into_inner());
    }
}

/// The coordinator's lease table.
pub struct FleetTable {
    opts: FleetOptions,
    events: Arc<EventBus>,
    inner: Mutex<FleetInner>,
}

impl FleetTable {
    /// A table on its own private bus (tests, workers' local tables);
    /// nothing subscribes, so publishes are counter bumps.
    pub fn new(opts: FleetOptions) -> FleetTable {
        Self::with_events(
            opts,
            Arc::new(EventBus::new(DEFAULT_EVENTS_RING)),
        )
    }

    /// The serving constructor: lease transitions are published to the
    /// shared ops bus.
    pub fn with_events(
        opts: FleetOptions,
        events: Arc<EventBus>,
    ) -> FleetTable {
        FleetTable {
            opts,
            events,
            inner: Mutex::new(FleetInner {
                workers: HashMap::new(),
                pending: VecDeque::new(),
                leases: HashMap::new(),
                next_unit_id: 0,
                next_lease_id: 0,
                granted: 0,
                completed: 0,
                expired: 0,
                rejected: 0,
                spot_pass: 0,
                spot_fail: 0,
            }),
        }
    }

    pub fn options(&self) -> &FleetOptions {
        &self.opts
    }

    /// Upsert a worker.  Re-registering (a restarted worker keeping
    /// its id) just refreshes liveness.
    pub fn register(&self, worker_id: &str, slots: u32) {
        let mut g = lock(&self.inner);
        g.workers.insert(
            worker_id.to_string(),
            WorkerInfo { slots, last_seen: Instant::now() },
        );
    }

    /// Workers seen within one lease TTL — the signal `run_matrix`
    /// uses to decide fleet vs local execution.
    pub fn alive_workers(&self) -> usize {
        let g = lock(&self.inner);
        let now = Instant::now();
        g.workers
            .values()
            .filter(|w| now.duration_since(w.last_seen) <= self.opts.lease_ttl)
            .count()
    }

    /// Grant the oldest pending unit to `worker_id`.  `Ok(None)` means
    /// no work right now; `Err` means the worker never registered.
    pub fn lease(&self, worker_id: &str) -> Result<Option<LeaseGrant>, String> {
        let mut g = lock(&self.inner);
        let now = Instant::now();
        match g.workers.get_mut(worker_id) {
            Some(w) => w.last_seen = now,
            None => return Err(format!("unknown worker '{worker_id}'")),
        }
        let Some(unit) = g.pending.pop_front() else {
            return Ok(None);
        };
        let lease_id = g.next_lease_id;
        g.next_lease_id += 1;
        g.granted += 1;
        let grant = LeaseGrant {
            lease_id,
            unit_id: unit.unit_id,
            name: unit.name.clone(),
            config: Arc::clone(&unit.cfg),
        };
        let spot_check = spot_check_sampled(unit.unit_id, self.opts.spot_check_rate);
        g.leases.insert(
            lease_id,
            Lease {
                unit,
                worker_id: worker_id.to_string(),
                deadline: now + self.opts.lease_ttl,
                spot_check,
            },
        );
        drop(g);
        self.events.publish(EventKind::LeaseGranted {
            lease_id,
            unit_id: grant.unit_id,
            scenario: grant.name.clone(),
            worker: worker_id.to_string(),
        });
        Ok(Some(grant))
    }

    /// Extend a live lease.  `None` (unknown lease id) leaves the
    /// table untouched — the caller maps it to 404.
    pub fn heartbeat(&self, lease_id: u64) -> Option<Duration> {
        let mut g = lock(&self.inner);
        let now = Instant::now();
        let ttl = self.opts.lease_ttl;
        let worker_id = {
            let lease = g.leases.get_mut(&lease_id)?;
            lease.deadline = now + ttl;
            lease.worker_id.clone()
        };
        if let Some(w) = g.workers.get_mut(&worker_id) {
            w.last_seen = now;
        }
        Some(ttl)
    }

    /// Validate and admit a completed unit.
    ///
    /// Validation layers, cheapest first:
    /// 1. the row must decode (`summary_from_wire`);
    /// 2. the declared sha256 must match the coordinator's own
    ///    re-rendering of the decoded row (transport integrity);
    /// 3. the row's scenario name must match the leased unit;
    /// 4. for sampled units, a local replay of the same config must
    ///    produce byte-identical wire bytes (worker integrity).
    ///
    /// Any failure revokes the lease and requeues the unit; an unknown
    /// lease id (expired while the worker computed) drops the result —
    /// the requeued unit is someone else's job now.
    pub fn complete(
        &self,
        lease_id: u64,
        declared_sha: &str,
        row_wire: &Json,
    ) -> CompleteOutcome {
        let row = match runner::summary_from_wire(row_wire) {
            Ok(row) => row,
            Err(e) => return self.reject(lease_id, format!("undecodable row: {e}")),
        };
        let canonical = runner::summary_to_wire(&row).to_string_compact();
        let actual_sha = sha256::hex_digest(canonical.as_bytes());
        if actual_sha != declared_sha.to_ascii_lowercase() {
            return self.reject(
                lease_id,
                format!("sha256 mismatch: declared {declared_sha}, body is {actual_sha}"),
            );
        }

        // Read the lease without removing it: the (possibly slow) spot
        // check must not hold the table lock, and a lease that expires
        // during the check must win — its unit already belongs to the
        // requeue.
        let (name, cfg, spot_check) = {
            let g = lock(&self.inner);
            match g.leases.get(&lease_id) {
                None => return CompleteOutcome::Unknown,
                Some(l) => (
                    l.unit.name.clone(),
                    Arc::clone(&l.unit.cfg),
                    l.spot_check,
                ),
            }
        };
        if row.name != name {
            return self.reject(
                lease_id,
                format!("row is for scenario '{}', lease is for '{}'", row.name, name),
            );
        }
        if spot_check {
            let local = catch_unwind(AssertUnwindSafe(|| runner::run_unit(&name, &cfg)));
            let verdict = match local {
                Ok(local_row) => {
                    runner::summary_to_wire(&local_row).to_string_compact() == canonical
                }
                Err(_) => false,
            };
            let mut g = lock(&self.inner);
            if verdict {
                g.spot_pass += 1;
            } else {
                g.spot_fail += 1;
                drop(g);
                return self.reject(
                    lease_id,
                    format!("spot check diverged for scenario '{name}'"),
                );
            }
        }

        let unit = {
            let mut g = lock(&self.inner);
            let Some(lease) = g.leases.remove(&lease_id) else {
                // expired while we validated; the requeued unit wins
                return CompleteOutcome::Unknown;
            };
            g.completed += 1;
            let now = Instant::now();
            if let Some(w) = g.workers.get_mut(&lease.worker_id) {
                w.last_seen = now;
            }
            lease.unit
        };
        self.events.publish(EventKind::LeaseCompleted {
            lease_id,
            scenario: name,
        });
        unit.flight.deliver(unit.slot, row);
        CompleteOutcome::Accepted
    }

    fn reject(&self, lease_id: u64, msg: String) -> CompleteOutcome {
        let mut g = lock(&self.inner);
        match g.leases.remove(&lease_id) {
            Some(lease) => {
                g.rejected += 1;
                g.pending.push_back(lease.unit);
                drop(g);
                self.events.publish(EventKind::LeaseRejected {
                    lease_id,
                    reason: msg.clone(),
                });
                CompleteOutcome::Rejected(msg)
            }
            None => CompleteOutcome::Unknown,
        }
    }

    /// Expire every lease whose deadline has passed; their units go
    /// back on the pending queue.  Returns how many expired.
    pub fn expire_stale(&self) -> usize {
        let now = Instant::now();
        let mut g = lock(&self.inner);
        let stale: Vec<u64> = g
            .leases
            .iter()
            .filter(|(_, l)| l.deadline <= now)
            .map(|(id, _)| *id)
            .collect();
        for id in &stale {
            if let Some(lease) = g.leases.remove(id) {
                g.expired += 1;
                g.pending.push_back(lease.unit);
            }
        }
        drop(g);
        for id in &stale {
            self.events
                .publish(EventKind::LeaseExpired { lease_id: *id });
        }
        stale.len()
    }

    /// Force-expire one lease regardless of wall clock — the property
    /// test drives expiry deterministically through this.
    pub fn expire_lease(&self, lease_id: u64) -> bool {
        let mut g = lock(&self.inner);
        match g.leases.remove(&lease_id) {
            Some(lease) => {
                g.expired += 1;
                g.pending.push_back(lease.unit);
                drop(g);
                self.events
                    .publish(EventKind::LeaseExpired { lease_id });
                true
            }
            None => false,
        }
    }

    pub fn stats(&self) -> FleetStats {
        let g = lock(&self.inner);
        let now = Instant::now();
        FleetStats {
            workers_registered: g.workers.len(),
            workers_alive: g
                .workers
                .values()
                .filter(|w| now.duration_since(w.last_seen) <= self.opts.lease_ttl)
                .count(),
            units_pending: g.pending.len(),
            leases_granted: g.granted,
            leases_completed: g.completed,
            leases_expired: g.expired,
            leases_rejected: g.rejected,
            leases_outstanding: g.leases.len(),
            spot_checks_pass: g.spot_pass,
            spot_checks_fail: g.spot_fail,
        }
    }

    /// Unit ids currently waiting for a worker (tests).
    pub fn pending_unit_ids(&self) -> Vec<u64> {
        lock(&self.inner).pending.iter().map(|u| u.unit_id).collect()
    }

    /// Unit ids currently under a live lease (tests).
    pub fn leased_unit_ids(&self) -> Vec<u64> {
        lock(&self.inner).leases.values().map(|l| l.unit.unit_id).collect()
    }

    /// Queue one unit per scenario (config already applied) and return
    /// the flight that collects their rows.
    pub fn begin_sweep(
        &self,
        base: &CampaignConfig,
        scenarios: &[ScenarioConfig],
    ) -> Arc<SweepFlight> {
        let flight = SweepFlight::new(scenarios.len());
        let mut g = lock(&self.inner);
        for (slot, s) in scenarios.iter().enumerate() {
            let unit_id = g.next_unit_id;
            g.next_unit_id += 1;
            g.pending.push_back(Unit {
                unit_id,
                name: s.name.clone(),
                cfg: Arc::new(s.apply(base)),
                flight: Arc::clone(&flight),
                slot,
            });
        }
        flight
    }

    fn take_pending(&self) -> Option<Unit> {
        lock(&self.inner).pending.pop_front()
    }

    /// Run a sweep through the fleet when workers are alive, through
    /// the local replay pool when none are.
    ///
    /// The fleet path queues one unit per scenario and blocks until
    /// every slot is delivered, expiring stale leases as it waits.  If
    /// the whole fleet dies mid-sweep, the caller's thread drains the
    /// pending queue inline — slower than the pool (sequential), but
    /// the sweep always terminates with the same bytes.
    pub fn run_matrix(
        &self,
        pool: &ReplayPool,
        base: &CampaignConfig,
        scenarios: &[ScenarioConfig],
    ) -> Result<Vec<runner::ScenarioSummary>, String> {
        if scenarios.is_empty() {
            return Ok(Vec::new());
        }
        if self.alive_workers() == 0 {
            return pool.run_matrix(base, scenarios);
        }
        let flight = self.begin_sweep(base, scenarios);
        loop {
            if let Some(rows) = flight.rows_if_done() {
                return Ok(rows);
            }
            self.expire_stale();
            if self.alive_workers() == 0 {
                while let Some(unit) = self.take_pending() {
                    let row = catch_unwind(AssertUnwindSafe(|| {
                        runner::run_unit(&unit.name, &unit.cfg)
                    }))
                    .map_err(|_| {
                        format!("scenario '{}' panicked during replay", unit.name)
                    })?;
                    unit.flight.deliver(unit.slot, row);
                }
            }
            flight.wait_some(Duration::from_millis(25));
        }
    }
}

/// Deterministic per-unit sampling: hash the unit id so the decision
/// survives requeues (an expired-and-regranted unit keeps its fate)
/// and needs no RNG state on the serve path.
fn spot_check_sampled(unit_id: u64, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    if rate >= 1.0 {
        return true;
    }
    let h = sha256::hex_digest(format!("spot-check:{unit_id}").as_bytes());
    let v = u64::from_str_radix(&h[..8], 16).expect("hex digest") as f64;
    v / (u32::MAX as f64 + 1.0) < rate
}

// ---------------------------------------------------------------------------
// Worker client
// ---------------------------------------------------------------------------

/// `icecloud worker` knobs (also driven directly by the e2e tests).
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Coordinator address, `host:port`.
    pub coordinator: String,
    pub worker_id: String,
    /// Advertised concurrency (informational for now — the client
    /// computes one unit at a time).
    pub slots: u32,
    /// Idle poll interval when the coordinator has no work.
    pub poll: Duration,
    /// Fault injection: after this many lease grants, vanish mid-lease
    /// without heartbeating or completing — exactly how a preempted
    /// spot instance dies.
    pub fail_after_leases: Option<u64>,
    /// Local segment-sweep implementation for leased replays.  The
    /// canonical config on the wire deliberately omits engine knobs
    /// (they cannot change results), so each worker picks its own.
    pub engine_simd: crate::runtime::SimdMode,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerReport {
    pub leases: u64,
    pub completed: u64,
}

/// How many consecutive transport failures the worker tolerates before
/// concluding the coordinator is gone.
const MAX_TRANSPORT_FAILURES: u32 = 20;

/// Pull-based worker loop: register, then lease/compute/heartbeat/
/// complete until `stop` is set.  Runs the replay on a helper thread so
/// the heartbeat cadence is independent of scenario runtime.
pub fn run_worker(opts: &WorkerOptions, stop: &AtomicBool) -> Result<WorkerReport, String> {
    let mut body = Json::obj();
    body.set("worker_id", Json::from(opts.worker_id.as_str()));
    body.set("slots", Json::from(u64::from(opts.slots)));
    let resp = post_json(&opts.coordinator, "/fleet/register", &body)?;
    if resp.0 != 200 {
        return Err(format!("register failed: {} {}", resp.0, resp.1));
    }
    let doc = json::parse(resp.1.trim()).map_err(|e| format!("register response: {e}"))?;
    let heartbeat_every = Duration::from_millis(
        doc.get("heartbeat_every_ms")
            .and_then(Json::as_u64)
            .ok_or("register response missing heartbeat_every_ms")?,
    );

    let mut report = WorkerReport::default();
    let mut failures = 0u32;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(report);
        }
        let mut ask = Json::obj();
        ask.set("worker_id", Json::from(opts.worker_id.as_str()));
        let (status, body) = match post_json(&opts.coordinator, "/fleet/lease", &ask) {
            Ok(r) => {
                failures = 0;
                r
            }
            Err(e) => {
                failures += 1;
                if failures >= MAX_TRANSPORT_FAILURES {
                    return Err(format!("coordinator unreachable: {e}"));
                }
                std::thread::sleep(opts.poll);
                continue;
            }
        };
        if status != 200 {
            return Err(format!("lease request refused: {status} {body}"));
        }
        let doc = json::parse(body.trim()).map_err(|e| format!("lease response: {e}"))?;
        if doc.get("idle").is_some() {
            std::thread::sleep(opts.poll);
            continue;
        }
        report.leases += 1;
        if opts.fail_after_leases.is_some_and(|n| report.leases >= n) {
            // vanish mid-lease: no heartbeat, no complete, no goodbye
            return Ok(report);
        }
        let lease_id = doc
            .get("lease_id")
            .and_then(Json::as_u64)
            .ok_or("lease response missing lease_id")?;
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .ok_or("lease response missing name")?
            .to_string();
        let mut cfg = CampaignConfig::from_canonical_json(
            doc.get("config").ok_or("lease response missing config")?,
        )?;
        cfg.engine.simd = opts.engine_simd;

        let (tx, rx) = mpsc::channel();
        let compute_name = name.clone();
        let handle = std::thread::spawn(move || {
            let row = catch_unwind(AssertUnwindSafe(|| {
                runner::run_unit(&compute_name, &cfg)
            }));
            let _ = tx.send(row.ok());
        });
        let mut abandoned = false;
        let row = loop {
            match rx.recv_timeout(heartbeat_every) {
                Ok(row) => break row,
                Err(mpsc::RecvTimeoutError::Disconnected) => break None,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let mut hb = Json::obj();
                    hb.set("lease_id", Json::from(lease_id));
                    match post_json(&opts.coordinator, "/fleet/heartbeat", &hb) {
                        Ok((200, _)) => {}
                        // lease expired under us, or the coordinator is
                        // unreachable: abandon this unit
                        _ => {
                            abandoned = true;
                            break None;
                        }
                    }
                }
            }
        };
        let _ = handle.join();
        let Some(row) = row else {
            if !abandoned {
                // the replay itself panicked; let the lease expire so
                // the coordinator requeues the unit elsewhere
                std::thread::sleep(opts.poll);
            }
            continue;
        };

        let wire = runner::summary_to_wire(&row);
        let bytes = wire.to_string_compact();
        let mut done = Json::obj();
        done.set("lease_id", Json::from(lease_id));
        done.set("sha256", Json::from(sha256::hex_digest(bytes.as_bytes())));
        done.set("row", wire);
        match post_json(&opts.coordinator, "/fleet/complete", &done) {
            Ok((200, _)) => report.completed += 1,
            // 404: lease expired while we computed; 400: rejected.
            // Either way the coordinator owns the requeue — move on.
            Ok(_) => {}
            Err(e) => {
                failures += 1;
                if failures >= MAX_TRANSPORT_FAILURES {
                    return Err(format!("coordinator unreachable: {e}"));
                }
            }
        }
    }
}

fn post_json(addr: &str, path: &str, body: &Json) -> Result<(u16, String), String> {
    let resp = client_request(
        addr,
        "POST",
        path,
        Some("application/json"),
        body.to_string_compact().as_bytes(),
    )?;
    Ok((resp.status, resp.body_str()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudbank::BudgetSnapshot;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};
    use crate::sweep::ScenarioSummary;

    fn tiny_base() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * HOUR;
        c.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
        c.outage = None;
        c.onprem.slots = 8;
        c.generator.min_backlog = 30;
        c
    }

    fn opts(ttl_ms: u64, rate: f64) -> FleetOptions {
        FleetOptions {
            lease_ttl: Duration::from_millis(ttl_ms),
            heartbeat_every: Duration::from_millis(ttl_ms / 3 + 1),
            spot_check_rate: rate,
        }
    }

    fn fake_row(name: &str) -> ScenarioSummary {
        ScenarioSummary {
            name: name.to_string(),
            seed: 7,
            duration_days: 0.25,
            snapshot: BudgetSnapshot {
                at: 900,
                budget_usd: 58_000.0,
                spent_usd: 12.5,
                aws_usd: 4.0,
                gcp_usd: 4.0,
                azure_usd: 4.5,
            },
            gpu_days: 1.5,
            eflop_hours: 0.002,
            cost_per_eflop_hour: 6_250.0,
            peak_gpus: 10.0,
            mean_gpus: 8.0,
            completed: 120,
            interrupted: 3,
            goodput_fraction: 0.97,
            nat_drops: 0,
            preemptions: 2,
            resumes: 2,
            goodput_hours: 36.0,
            wasted_hours: 1.0,
            expansion_factor: 1.1,
            alerts: 1,
        }
    }

    fn wire_and_sha(row: &ScenarioSummary) -> (Json, String) {
        let wire = runner::summary_to_wire(row);
        let sha = sha256::hex_digest(wire.to_string_compact().as_bytes());
        (wire, sha)
    }

    fn scens(names: &[&str]) -> Vec<ScenarioConfig> {
        names.iter().map(|n| ScenarioConfig::named(n)).collect()
    }

    #[test]
    fn lease_lifecycle_conserves_units() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        fleet.register("w1", 1);
        let _flight = fleet.begin_sweep(&tiny_base(), &scens(&["a", "b", "c"]));
        assert_eq!(fleet.pending_unit_ids(), vec![0, 1, 2]);

        let g0 = fleet.lease("w1").unwrap().unwrap();
        let g1 = fleet.lease("w1").unwrap().unwrap();
        let g2 = fleet.lease("w1").unwrap().unwrap();
        let mut granted_units = vec![g0.unit_id, g1.unit_id, g2.unit_id];
        granted_units.sort_unstable();
        assert_eq!(granted_units, vec![0, 1, 2], "each unit granted once");
        assert!(fleet.lease("w1").unwrap().is_none(), "queue is drained");

        // complete one, expire one, leave one outstanding
        let row = fake_row(&g0.name);
        let (wire, sha) = wire_and_sha(&row);
        assert_eq!(fleet.complete(g0.lease_id, &sha, &wire), CompleteOutcome::Accepted);
        assert!(fleet.expire_lease(g1.lease_id));

        let s = fleet.stats();
        assert_eq!(s.leases_granted, 3);
        assert_eq!(s.leases_completed, 1);
        assert_eq!(s.leases_expired, 1);
        assert_eq!(s.leases_outstanding, 1);
        assert_eq!(
            s.leases_granted,
            s.leases_completed + s.leases_expired + s.leases_rejected
                + s.leases_outstanding as u64
        );
        assert_eq!(fleet.pending_unit_ids(), vec![g1.unit_id], "expired unit requeued");
        assert_eq!(fleet.leased_unit_ids(), vec![g2.unit_id]);
    }

    #[test]
    fn unknown_worker_cannot_lease() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        let _flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));
        assert!(fleet.lease("ghost").is_err());
        assert_eq!(fleet.stats().leases_granted, 0);
        assert_eq!(fleet.pending_unit_ids(), vec![0], "unit untouched");
    }

    #[test]
    fn heartbeat_extends_and_unknown_heartbeat_is_a_noop() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        fleet.register("w1", 1);
        let _flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));
        let g = fleet.lease("w1").unwrap().unwrap();
        assert_eq!(fleet.heartbeat(g.lease_id), Some(Duration::from_millis(60_000)));
        let before = fleet.stats();
        assert_eq!(fleet.heartbeat(9_999), None);
        assert_eq!(fleet.stats(), before, "unknown heartbeat changes nothing");
    }

    #[test]
    fn wrong_sha_rejects_and_requeues() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        fleet.register("w1", 1);
        let flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));
        let g = fleet.lease("w1").unwrap().unwrap();
        let (wire, _) = wire_and_sha(&fake_row(&g.name));
        let out = fleet.complete(g.lease_id, "deadbeef", &wire);
        assert!(matches!(out, CompleteOutcome::Rejected(_)), "{out:?}");
        assert_eq!(fleet.stats().leases_rejected, 1);
        assert_eq!(fleet.pending_unit_ids(), vec![g.unit_id], "unit requeued");
        assert!(flight.filled_slots().is_empty(), "nothing delivered");
    }

    #[test]
    fn wrong_scenario_name_rejects() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        fleet.register("w1", 1);
        let _flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));
        let g = fleet.lease("w1").unwrap().unwrap();
        let (wire, sha) = wire_and_sha(&fake_row("not-a"));
        let out = fleet.complete(g.lease_id, &sha, &wire);
        assert!(matches!(out, CompleteOutcome::Rejected(_)), "{out:?}");
        assert_eq!(fleet.pending_unit_ids(), vec![g.unit_id]);
    }

    #[test]
    fn complete_after_expiry_is_unknown_and_drops_the_row() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        fleet.register("w1", 1);
        let flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));
        let g = fleet.lease("w1").unwrap().unwrap();
        assert!(fleet.expire_lease(g.lease_id));
        let (wire, sha) = wire_and_sha(&fake_row(&g.name));
        assert_eq!(fleet.complete(g.lease_id, &sha, &wire), CompleteOutcome::Unknown);
        assert_eq!(fleet.stats().leases_completed, 0);
        assert!(flight.filled_slots().is_empty());
        assert_eq!(fleet.pending_unit_ids(), vec![g.unit_id], "requeue wins");
    }

    #[test]
    fn spot_check_rejects_fabricated_rows_and_admits_honest_ones() {
        let fleet = FleetTable::new(opts(60_000, 1.0));
        fleet.register("w1", 1);
        let flight = fleet.begin_sweep(&tiny_base(), &scens(&["a"]));

        // a well-formed but fabricated row sails through the sha check
        // and dies on the local replay comparison
        let g = fleet.lease("w1").unwrap().unwrap();
        let (wire, sha) = wire_and_sha(&fake_row(&g.name));
        let out = fleet.complete(g.lease_id, &sha, &wire);
        assert!(matches!(out, CompleteOutcome::Rejected(_)), "{out:?}");
        assert_eq!(fleet.stats().spot_checks_fail, 1);

        // the honest bytes are admitted
        let g = fleet.lease("w1").unwrap().unwrap();
        let honest = runner::run_unit(&g.name, &g.config);
        let (wire, sha) = wire_and_sha(&honest);
        assert_eq!(fleet.complete(g.lease_id, &sha, &wire), CompleteOutcome::Accepted);
        let s = fleet.stats();
        assert_eq!(s.spot_checks_pass, 1);
        assert_eq!(flight.filled_slots(), vec![0]);
    }

    #[test]
    fn run_matrix_without_workers_uses_the_pool() {
        let fleet = FleetTable::new(opts(60_000, 0.0));
        let pool = ReplayPool::new(2);
        let base = tiny_base();
        let scenarios = scens(&["a", "b"]);
        let via_fleet = fleet.run_matrix(&pool, &base, &scenarios).unwrap();
        let via_pool = pool.run_matrix(&base, &scenarios).unwrap();
        assert_eq!(via_fleet, via_pool);
        assert_eq!(fleet.stats().leases_granted, 0, "no fleet involvement");
    }

    #[test]
    fn run_matrix_drains_locally_when_the_whole_fleet_dies() {
        // a worker registers and then never leases: once it goes stale
        // (short TTL), the sweep must finish on the caller's thread
        let fleet = FleetTable::new(opts(50, 0.0));
        fleet.register("doomed", 1);
        let pool = ReplayPool::new(2);
        let base = tiny_base();
        let scenarios = scens(&["a", "b"]);
        let rows = fleet.run_matrix(&pool, &base, &scenarios).unwrap();
        let reference = pool.run_matrix(&base, &scenarios).unwrap();
        assert_eq!(rows, reference, "local drain is byte-identical");
    }

    #[test]
    fn spot_check_sampling_is_deterministic_and_respects_bounds() {
        assert!(!spot_check_sampled(42, 0.0));
        assert!(spot_check_sampled(42, 1.0));
        for id in 0..64 {
            assert_eq!(
                spot_check_sampled(id, 0.3),
                spot_check_sampled(id, 0.3),
                "sampling must be stable across requeues"
            );
        }
        let hits = (0..1000).filter(|&id| spot_check_sampled(id, 0.3)).count();
        assert!((200..=400).contains(&hits), "rate 0.3 sampled {hits}/1000");
    }
}
