//! Wall-clock operations history: the `/timeseries` and `/dash` data
//! plane (DESIGN.md §17).
//!
//! The campaign simulator already has a monitoring database
//! (`monitoring::timeseries::Monitor`) keyed by *sim* time; this module
//! reuses it for the *server's own* life, keyed by seconds since
//! startup.  A sampler thread (see `server::mod`) records queue depth,
//! running jobs, fleet lease counts and the goodput/wasted-hour
//! counters every `[ops] sample_every_s`; the router renders the
//! result three ways:
//!
//! * `GET /timeseries` — an index of every series with summary stats;
//! * `GET /timeseries/<name>` — one series, downsampled to a bounded
//!   point budget (`TimeSeries::downsample`);
//! * `GET /dash` (+ `/dash.json`) — a server-rendered SVG burn-down
//!   board, one panel per series, in the spirit of the paper's fig. 3
//!   completed-units-over-time views.
//!
//! Everything here is read-side only: sampling takes one mutex briefly
//! and the renderers copy what they need out, so a slow dashboard
//! scrape never holds up the sampler or any request handler.

use crate::monitoring::timeseries::Monitor;
use crate::sim::SimTime;
use crate::util::json::Json;
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Default sampling cadence in seconds (`[ops] sample_every_s`).
pub const DEFAULT_SAMPLE_EVERY_S: u64 = 5;

/// Point budget for `/timeseries/<name>` and the dash polylines: keeps
/// a day of 5-second samples (17k points) to a bounded payload.
const MAX_POINTS: usize = 512;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The server's own monitoring database, keyed by uptime seconds.
pub struct OpsMonitor {
    start: Instant,
    inner: Mutex<Monitor>,
}

impl OpsMonitor {
    pub fn new() -> OpsMonitor {
        OpsMonitor { start: Instant::now(), inner: Mutex::new(Monitor::new()) }
    }

    /// Seconds since the server started (the series time axis).
    pub fn uptime_s(&self) -> u64 {
        self.start.elapsed().as_secs()
    }

    /// Record one sample at the current uptime.
    pub fn record(&self, name: &str, value: f64) {
        let t = self.uptime_s();
        lock(&self.inner).sample(name, t, value);
    }

    /// Record several samples on one shared tick (one lock, aligned
    /// timestamps — what the sampler thread uses).
    pub fn record_all(&self, samples: &[(&str, f64)]) {
        let t = self.uptime_s();
        let mut g = lock(&self.inner);
        for (name, value) in samples {
            g.sample(name, t, *value);
        }
    }

    /// `GET /timeseries`: every series with its summary stats.
    pub fn index_json(&self) -> Json {
        let g = lock(&self.inner);
        let mut series = Vec::new();
        for name in g.names() {
            let s = g.get(name).expect("listed series exists");
            let sum = s.summary();
            let mut o = Json::obj();
            o.set("name", Json::from(name));
            o.set("samples", Json::from(sum.samples));
            o.set("min", Json::from(sum.min));
            o.set("max", Json::from(sum.max));
            o.set("mean", Json::from(sum.mean));
            o.set("last", Json::from(sum.last));
            series.push(o);
        }
        let mut out = Json::obj();
        out.set("uptime_s", Json::from(self.uptime_s()));
        out.set("count", Json::from(series.len()));
        out.set("series", Json::Arr(series));
        out
    }

    /// `GET /timeseries/<name>`: one series, downsampled.  `None` when
    /// the series does not exist (the router's 404).
    pub fn series_json(&self, name: &str) -> Option<Json> {
        let g = lock(&self.inner);
        let s = g.get(name)?;
        let points = s.downsample(MAX_POINTS);
        let mut o = Json::obj();
        o.set("name", Json::from(name));
        o.set("samples", Json::from(s.len()));
        o.set("returned", Json::from(points.len()));
        o.set(
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|(t, v)| {
                        Json::Arr(vec![Json::from(*t), Json::from(*v)])
                    })
                    .collect(),
            ),
        );
        Some(o)
    }

    /// Copy out every series' downsampled points (dash rendering).
    fn snapshot(&self, budget: usize) -> Vec<(String, Vec<(SimTime, f64)>)> {
        let g = lock(&self.inner);
        let mut out = Vec::new();
        for name in g.names() {
            let s = g.get(name).expect("listed series exists");
            out.push((name.to_string(), s.downsample(budget)));
        }
        out
    }

    /// `GET /dash.json`: the machine-readable twin of the SVG board.
    pub fn dash_json(&self) -> Json {
        let mut series = Vec::new();
        for (name, points) in self.snapshot(DASH_POINTS) {
            let last = points.last().map(|(_, v)| *v).unwrap_or(0.0);
            let mut o = Json::obj();
            o.set("name", Json::from(name));
            o.set("last", Json::from(last));
            o.set(
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|(t, v)| {
                            Json::Arr(vec![Json::from(*t), Json::from(*v)])
                        })
                        .collect(),
                ),
            );
            series.push(o);
        }
        let mut out = Json::obj();
        out.set("uptime_s", Json::from(self.uptime_s()));
        out.set("series", Json::Arr(series));
        out
    }

    /// `GET /dash`: the SVG burn-down board.
    pub fn dash_svg(&self) -> String {
        render_svg(self.uptime_s(), &self.snapshot(DASH_POINTS))
    }
}

impl Default for OpsMonitor {
    fn default() -> Self {
        Self::new()
    }
}

/// Polyline point budget per dash panel.
const DASH_POINTS: usize = 128;

/// Panel geometry: two columns of fixed-size panels.
const PANEL_W: u64 = 380;
const PANEL_H: u64 = 120;
const PANEL_PAD: u64 = 10;
const HEADER_H: u64 = 40;
const COLS: u64 = 2;

/// Render the board: one bordered panel per series, each polyline
/// scaled to its own [min, max] so every shape is readable regardless
/// of units (GPU counts vs accumulated hours).
fn render_svg(uptime_s: u64, series: &[(String, Vec<(SimTime, f64)>)]) -> String {
    let rows = (series.len() as u64).div_ceil(COLS).max(1);
    let width = COLS * (PANEL_W + PANEL_PAD) + PANEL_PAD;
    let height = HEADER_H + rows * (PANEL_H + PANEL_PAD) + PANEL_PAD;
    let mut out = String::with_capacity(4096);
    out.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{width}\" \
         height=\"{height}\" viewBox=\"0 0 {width} {height}\" \
         font-family=\"monospace\" font-size=\"12\">\n"
    ));
    out.push_str(&format!(
        "<rect width=\"{width}\" height=\"{height}\" fill=\"#0d1117\"/>\n\
         <text x=\"{PANEL_PAD}\" y=\"24\" fill=\"#e6edf3\" \
         font-size=\"15\">icecloud ops — uptime {uptime_s} s</text>\n"
    ));
    if series.is_empty() {
        out.push_str(&format!(
            "<text x=\"{PANEL_PAD}\" y=\"{}\" fill=\"#8b949e\">\
             (no samples yet)</text>\n",
            HEADER_H + 20
        ));
    }
    for (i, (name, points)) in series.iter().enumerate() {
        let col = i as u64 % COLS;
        let row = i as u64 / COLS;
        let x0 = PANEL_PAD + col * (PANEL_W + PANEL_PAD);
        let y0 = HEADER_H + row * (PANEL_H + PANEL_PAD);
        out.push_str(&render_panel(name, points, x0, y0));
    }
    out.push_str("</svg>\n");
    out
}

fn render_panel(
    name: &str,
    points: &[(SimTime, f64)],
    x0: u64,
    y0: u64,
) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str(&format!(
        "<rect x=\"{x0}\" y=\"{y0}\" width=\"{PANEL_W}\" \
         height=\"{PANEL_H}\" fill=\"#161b22\" stroke=\"#30363d\"/>\n"
    ));
    let last = points.last().map(|(_, v)| *v).unwrap_or(0.0);
    out.push_str(&format!(
        "<text x=\"{}\" y=\"{}\" fill=\"#e6edf3\">{name} = {last}</text>\n",
        x0 + 8,
        y0 + 16,
    ));
    if points.len() < 2 {
        return out;
    }
    let (t_min, t_max) = (points[0].0, points[points.len() - 1].0);
    let mut v_min = f64::INFINITY;
    let mut v_max = f64::NEG_INFINITY;
    for (_, v) in points {
        v_min = v_min.min(*v);
        v_max = v_max.max(*v);
    }
    // plot area inside the panel, below the title
    let (px, py) = (x0 as f64 + 8.0, y0 as f64 + 26.0);
    let (pw, ph) = (PANEL_W as f64 - 16.0, PANEL_H as f64 - 36.0);
    let t_span = (t_max - t_min).max(1) as f64;
    let v_span = v_max - v_min;
    let mut poly = String::new();
    for (t, v) in points {
        let x = px + (t - t_min) as f64 / t_span * pw;
        // a flat series draws mid-panel instead of dividing by zero
        let frac =
            if v_span > 0.0 { (v - v_min) / v_span } else { 0.5 };
        let y = py + (1.0 - frac) * ph;
        poly.push_str(&format!("{x:.1},{y:.1} "));
    }
    out.push_str(&format!(
        "<polyline points=\"{}\" fill=\"none\" stroke=\"#58a6ff\" \
         stroke-width=\"1.5\"/>\n",
        poly.trim_end()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn index_lists_series_with_finite_summaries() {
        let m = OpsMonitor::new();
        m.record("jobs.queued", 3.0);
        m.record("jobs.queued", 5.0);
        m.record("jobs.running", 1.0);
        let idx = m.index_json();
        assert_eq!(idx.get("count").unwrap().as_u64(), Some(2));
        let text = idx.to_string_compact();
        // NaN/−inf would serialize as null / fail strict reparse
        assert!(json::parse(&text).is_ok(), "{text}");
        assert!(!text.contains("null"), "{text}");
        let series = idx.get("series").unwrap().as_arr().unwrap();
        let queued = series
            .iter()
            .find(|s| s.get("name").unwrap().as_str() == Some("jobs.queued"))
            .unwrap();
        assert_eq!(queued.get("samples").unwrap().as_u64(), Some(2));
        assert_eq!(queued.get("max").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn record_all_shares_one_timestamp() {
        let m = OpsMonitor::new();
        m.record_all(&[("a", 1.0), ("b", 2.0)]);
        let a = m.series_json("a").unwrap();
        let b = m.series_json("b").unwrap();
        let ta = a.get("points").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap()[0]
            .as_u64();
        let tb = b.get("points").unwrap().as_arr().unwrap()[0]
            .as_arr()
            .unwrap()[0]
            .as_u64();
        assert_eq!(ta, tb);
    }

    #[test]
    fn series_json_downsamples_to_the_budget() {
        let m = OpsMonitor::new();
        {
            // drive the inner monitor directly so 2000 points don't
            // need 2000 wall seconds
            let mut g = m.inner.lock().unwrap();
            for t in 0..2000u64 {
                g.sample("busy", t, t as f64);
            }
        }
        let s = m.series_json("busy").unwrap();
        assert_eq!(s.get("samples").unwrap().as_u64(), Some(2000));
        let returned = s.get("returned").unwrap().as_u64().unwrap();
        assert!(returned <= MAX_POINTS as u64, "{returned}");
        let pts = s.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len() as u64, returned);
        // ends survive downsampling
        assert_eq!(pts[0].as_arr().unwrap()[0].as_u64(), Some(0));
        assert_eq!(
            pts[pts.len() - 1].as_arr().unwrap()[0].as_u64(),
            Some(1999)
        );
    }

    #[test]
    fn unknown_series_is_none() {
        assert!(OpsMonitor::new().series_json("nope").is_none());
    }

    #[test]
    fn empty_dash_renders_placeholder() {
        let svg = OpsMonitor::new().dash_svg();
        assert!(svg.starts_with("<svg "), "{svg}");
        assert!(svg.contains("(no samples yet)"), "{svg}");
        assert!(svg.ends_with("</svg>\n"), "{svg}");
    }

    #[test]
    fn dash_svg_draws_a_polyline_per_series() {
        let m = OpsMonitor::new();
        {
            let mut g = m.inner.lock().unwrap();
            for t in 0..50u64 {
                g.sample("jobs.done", t, t as f64);
                g.sample("jobs.queued", t, (50 - t) as f64);
            }
        }
        let svg = m.dash_svg();
        assert_eq!(svg.matches("<polyline").count(), 2, "{svg}");
        assert!(svg.contains("jobs.done"), "{svg}");
        assert!(svg.contains("jobs.queued"), "{svg}");
    }

    #[test]
    fn flat_series_still_renders() {
        let m = OpsMonitor::new();
        {
            let mut g = m.inner.lock().unwrap();
            for t in 0..10u64 {
                g.sample("steady", t, 4.0);
            }
        }
        let svg = m.dash_svg();
        assert_eq!(svg.matches("<polyline").count(), 1, "{svg}");
        assert!(!svg.contains("NaN"), "{svg}");
        assert!(!svg.contains("inf"), "{svg}");
    }

    #[test]
    fn dash_json_matches_the_board() {
        let m = OpsMonitor::new();
        m.record("goodput.hours", 1.5);
        let d = m.dash_json();
        let series = d.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 1);
        assert_eq!(
            series[0].get("name").unwrap().as_str(),
            Some("goodput.hours")
        );
        assert_eq!(series[0].get("last").unwrap().as_f64(), Some(1.5));
        assert!(json::parse(&d.to_string_compact()).is_ok());
    }
}
