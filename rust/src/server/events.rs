//! The live-operations event bus: typed transitions from the job
//! table, fleet lease table and result store, fanned out to `/events`
//! SSE subscribers (DESIGN.md §17).
//!
//! The paper's operational story was *watched*, not polled — the team
//! steered the 2-week run off live dashboards.  This bus is the push
//! half of that plane, with three invariants the rest of the server
//! relies on:
//!
//! 1. **Publishers never block.**  `publish` takes one mutex, appends
//!    to a bounded ring, and returns; no subscriber — slow, stalled or
//!    absent — can wedge a job runner or a fleet completion.  With zero
//!    subscribers a publish is just a counter bump and a ring append.
//! 2. **Memory is bounded.**  The ring holds at most `capacity` events
//!    (`[ops] events_ring`); older events fall off the front.
//! 3. **A slow reader loses *its own* backlog, explicitly.**  Each
//!    subscriber keeps a private cursor.  When the cursor falls behind
//!    the ring, the next delivery reports how many events that reader
//!    missed (rendered as an SSE `gap` event) and resumes from the
//!    oldest retained event.  Other subscribers are unaffected.
//!
//! Sequence numbers are monotonic from 1 and double as SSE `id:`
//! values, so `Last-Event-ID` resume is exact whenever the requested
//! range is still in the ring and an honest `gap` when it is not.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Default ring capacity (`[ops] events_ring`).
pub const DEFAULT_EVENTS_RING: usize = 1024;

/// Poison-tolerant lock: a panicking publisher must not take the bus
/// (and with it every subscriber stream) down with it.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A typed transition published into the bus.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Async job admitted to the queue.
    JobQueued { id: String, scenarios: usize },
    /// Job picked up by a runner.
    JobRunning { id: String },
    /// Job finished; its result is fetchable.
    JobDone { id: String },
    /// Job failed; the error is what `GET /jobs/<id>` reports.
    JobFailed { id: String, error: String },
    /// Fleet lease granted to a worker.
    LeaseGranted { lease_id: u64, unit_id: u64, scenario: String, worker: String },
    /// Worker delivered a valid row; the lease retired.
    LeaseCompleted { lease_id: u64, scenario: String },
    /// Completion failed validation; unit requeued.
    LeaseRejected { lease_id: u64, reason: String },
    /// Lease deadline passed; unit requeued.
    LeaseExpired { lease_id: u64 },
    /// Result served from a cache tier ("memory" or "disk").
    CacheHit { key: String, tier: &'static str },
    /// Store entry failed verification and was quarantined.
    StoreQuarantine { name: String, reason: String },
}

impl EventKind {
    /// The SSE `event:` field.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::JobQueued { .. } => "job.queued",
            EventKind::JobRunning { .. } => "job.running",
            EventKind::JobDone { .. } => "job.done",
            EventKind::JobFailed { .. } => "job.failed",
            EventKind::LeaseGranted { .. } => "lease.granted",
            EventKind::LeaseCompleted { .. } => "lease.completed",
            EventKind::LeaseRejected { .. } => "lease.rejected",
            EventKind::LeaseExpired { .. } => "lease.expired",
            EventKind::CacheHit { .. } => "cache.hit",
            EventKind::StoreQuarantine { .. } => "store.quarantine",
        }
    }

    /// The SSE `data:` payload (always a compact single-line object).
    pub fn data(&self) -> Json {
        let mut o = Json::obj();
        match self {
            EventKind::JobQueued { id, scenarios } => {
                o.set("id", Json::from(id.as_str()));
                o.set("scenarios", Json::from(*scenarios));
            }
            EventKind::JobRunning { id }
            | EventKind::JobDone { id } => {
                o.set("id", Json::from(id.as_str()));
            }
            EventKind::JobFailed { id, error } => {
                o.set("id", Json::from(id.as_str()));
                o.set("error", Json::from(error.as_str()));
            }
            EventKind::LeaseGranted { lease_id, unit_id, scenario, worker } => {
                o.set("lease_id", Json::from(*lease_id));
                o.set("unit_id", Json::from(*unit_id));
                o.set("scenario", Json::from(scenario.as_str()));
                o.set("worker", Json::from(worker.as_str()));
            }
            EventKind::LeaseCompleted { lease_id, scenario } => {
                o.set("lease_id", Json::from(*lease_id));
                o.set("scenario", Json::from(scenario.as_str()));
            }
            EventKind::LeaseRejected { lease_id, reason } => {
                o.set("lease_id", Json::from(*lease_id));
                o.set("reason", Json::from(reason.as_str()));
            }
            EventKind::LeaseExpired { lease_id } => {
                o.set("lease_id", Json::from(*lease_id));
            }
            EventKind::CacheHit { key, tier } => {
                o.set("key", Json::from(key.as_str()));
                o.set("tier", Json::from(*tier));
            }
            EventKind::StoreQuarantine { name, reason } => {
                o.set("name", Json::from(name.as_str()));
                o.set("reason", Json::from(reason.as_str()));
            }
        }
        o
    }
}

/// One published event: a sequence number plus its typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub kind: EventKind,
}

impl Event {
    /// Render as one SSE frame (`id` / `event` / `data` + blank line).
    pub fn sse_frame(&self) -> String {
        format!(
            "id: {}\nevent: {}\ndata: {}\n\n",
            self.seq,
            self.kind.name(),
            self.kind.data().to_string_compact()
        )
    }
}

/// Render the synthetic per-subscriber `gap` frame.  Its `id` is the
/// sequence number *before* the oldest event the subscriber will see
/// next, so a client that reconnects with the gap's id as
/// `Last-Event-ID` resumes exactly where the stream left off.
pub fn gap_frame(resume: u64, dropped: u64) -> String {
    let mut d = Json::obj();
    d.set("dropped", Json::from(dropped));
    format!(
        "id: {}\nevent: gap\ndata: {}\n\n",
        resume.saturating_sub(1),
        d.to_string_compact()
    )
}

struct BusInner {
    ring: VecDeque<Arc<Event>>,
    /// Sequence number the *next* publish will take (first is 1).
    next_seq: u64,
    closed: bool,
}

/// The bounded broadcast bus.  See the module docs for the invariants.
pub struct EventBus {
    inner: Mutex<BusInner>,
    wake: Condvar,
    capacity: usize,
    published: AtomicU64,
    dropped: AtomicU64,
    subscribers: AtomicU64,
}

impl EventBus {
    pub fn new(capacity: usize) -> EventBus {
        EventBus {
            inner: Mutex::new(BusInner {
                ring: VecDeque::new(),
                next_seq: 1,
                closed: false,
            }),
            wake: Condvar::new(),
            capacity: capacity.max(1),
            published: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            subscribers: AtomicU64::new(0),
        }
    }

    /// Publish one event; returns its sequence number.  Never blocks on
    /// subscribers: one short critical section, then a wakeup.
    pub fn publish(&self, kind: EventKind) -> u64 {
        let seq;
        {
            let mut g = lock(&self.inner);
            seq = g.next_seq;
            g.next_seq += 1;
            g.ring.push_back(Arc::new(Event { seq, kind }));
            while g.ring.len() > self.capacity {
                g.ring.pop_front();
            }
        }
        self.published.fetch_add(1, Ordering::Relaxed);
        self.wake.notify_all();
        seq
    }

    /// Open a cursor.  `resume = Some(id)` continues after `id`
    /// (`Last-Event-ID` semantics); `None` subscribes from *now* —
    /// history already in the ring is not replayed.  An id from the
    /// future is clamped to the live edge.
    pub fn subscribe(&self, resume: Option<u64>) -> Subscription<'_> {
        self.subscribers.fetch_add(1, Ordering::Relaxed);
        let next_seq = lock(&self.inner).next_seq;
        let cursor = match resume {
            Some(id) => id.saturating_add(1).min(next_seq),
            None => next_seq,
        };
        Subscription { bus: self, cursor }
    }

    /// Wake every waiting subscriber for shutdown; subsequent waits
    /// return [`Delivery::Closed`] once drained.
    pub fn close(&self) {
        lock(&self.inner).closed = true;
        self.wake.notify_all();
    }

    /// Total events ever published (`icecloud_events_published_total`).
    pub fn published_total(&self) -> u64 {
        self.published.load(Ordering::Relaxed)
    }

    /// Total events subscribers missed to ring wrap
    /// (`icecloud_events_dropped_total`), summed across subscribers.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Currently open subscriptions (`icecloud_events_subscribers`).
    pub fn subscriber_count(&self) -> u64 {
        self.subscribers.load(Ordering::Relaxed)
    }

    /// Ring capacity (diagnostics / tests).
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// What one wait on a subscription yielded.
#[derive(Debug)]
pub enum Delivery {
    /// New events (possibly preceded by a gap: `dropped` events fell
    /// off the ring before this reader caught up; `resume` is the
    /// sequence the batch resumes from, for rendering the gap frame).
    Batch { dropped: u64, resume: u64, events: Vec<Arc<Event>> },
    /// Nothing within the timeout (render a heartbeat comment).
    Idle,
    /// The bus shut down and the cursor is fully drained.
    Closed,
}

/// A per-subscriber cursor into the bus.  Dropping it releases the
/// subscriber gauge.
pub struct Subscription<'a> {
    bus: &'a EventBus,
    cursor: u64,
}

impl Subscription<'_> {
    /// Block until events arrive, the timeout lapses, or the bus
    /// closes.  Detects this reader's gap (cursor behind the ring) and
    /// charges it to the shared dropped counter.
    pub fn next(&mut self, timeout: Duration) -> Delivery {
        let deadline = Instant::now() + timeout;
        let mut g = lock(&self.bus.inner);
        loop {
            if self.cursor < g.next_seq {
                let oldest =
                    g.ring.front().map(|e| e.seq).unwrap_or(g.next_seq);
                let dropped = oldest.saturating_sub(self.cursor);
                if dropped > 0 {
                    self.bus.dropped.fetch_add(dropped, Ordering::Relaxed);
                    self.cursor = oldest;
                }
                let events: Vec<_> = g
                    .ring
                    .iter()
                    .filter(|e| e.seq >= self.cursor)
                    .cloned()
                    .collect();
                let resume = self.cursor;
                self.cursor = g.next_seq;
                return Delivery::Batch { dropped, resume, events };
            }
            if g.closed {
                return Delivery::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Delivery::Idle;
            }
            g = self
                .bus
                .wake
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
    }
}

impl Drop for Subscription<'_> {
    fn drop(&mut self) {
        self.bus.subscribers.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job_done(n: u64) -> EventKind {
        EventKind::JobDone { id: format!("job-{n}") }
    }

    fn batch(d: Delivery) -> (u64, u64, Vec<Arc<Event>>) {
        match d {
            Delivery::Batch { dropped, resume, events } => {
                (dropped, resume, events)
            }
            other => panic!("expected a batch, got {other:?}"),
        }
    }

    #[test]
    fn sequences_are_monotonic_from_one() {
        let bus = EventBus::new(8);
        assert_eq!(bus.publish(job_done(0)), 1);
        assert_eq!(bus.publish(job_done(1)), 2);
        assert_eq!(bus.publish(job_done(2)), 3);
        assert_eq!(bus.published_total(), 3);
    }

    #[test]
    fn zero_subscriber_publish_is_a_counter_bump() {
        let bus = EventBus::new(4);
        for i in 0..100 {
            bus.publish(job_done(i));
        }
        assert_eq!(bus.published_total(), 100);
        // nobody was reading, so nobody *dropped* anything
        assert_eq!(bus.dropped_total(), 0);
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn live_subscriber_sees_every_event_in_order_once() {
        let bus = EventBus::new(64);
        let mut sub = bus.subscribe(None);
        for i in 0..10 {
            bus.publish(job_done(i));
        }
        let (dropped, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 0);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (1..=10).collect::<Vec<u64>>());
        // drained: the next wait is Idle, not a replay
        assert!(matches!(
            sub.next(Duration::from_millis(10)),
            Delivery::Idle
        ));
    }

    #[test]
    fn subscribe_is_future_only() {
        let bus = EventBus::new(64);
        bus.publish(job_done(0));
        bus.publish(job_done(1));
        let mut sub = bus.subscribe(None);
        bus.publish(job_done(2));
        let (_, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].seq, 3);
    }

    #[test]
    fn resume_replays_only_missed_events() {
        let bus = EventBus::new(64);
        for i in 0..5 {
            bus.publish(job_done(i));
        }
        let mut sub = bus.subscribe(Some(2)); // saw 1 and 2 already
        let (dropped, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 0);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![3, 4, 5]);
    }

    #[test]
    fn slow_reader_gets_an_explicit_gap_and_the_tail() {
        let bus = EventBus::new(4);
        let mut sub = bus.subscribe(None);
        for i in 0..10 {
            bus.publish(job_done(i));
        }
        // ring holds 7..=10; 1..=6 fell off before this reader woke
        let (dropped, resume, events) =
            batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 6);
        assert_eq!(resume, 7);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10]);
        assert_eq!(bus.dropped_total(), 6);
    }

    #[test]
    fn gap_is_per_subscriber_not_global() {
        let bus = EventBus::new(4);
        let mut slow = bus.subscribe(None);
        for i in 0..10 {
            bus.publish(job_done(i));
        }
        // a reader that joins *now* starts at the live edge: no gap
        let mut fresh = bus.subscribe(None);
        bus.publish(job_done(10));
        let (dropped, _, events) =
            batch(fresh.next(Duration::from_secs(1)));
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 1);
        // the slow reader pays its own gap
        let (dropped, _, _) = batch(slow.next(Duration::from_secs(1)));
        assert!(dropped > 0);
    }

    #[test]
    fn resume_past_the_ring_counts_everything_missed() {
        let bus = EventBus::new(2);
        for i in 0..10 {
            bus.publish(job_done(i));
        }
        // client claims it saw event 1; 2..=8 are gone, 9..=10 remain
        let mut sub = bus.subscribe(Some(1));
        let (dropped, resume, events) =
            batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 7);
        assert_eq!(resume, 9);
        assert_eq!(events.len(), 2);
    }

    #[test]
    fn future_resume_id_clamps_to_live_edge() {
        let bus = EventBus::new(8);
        bus.publish(job_done(0));
        let mut sub = bus.subscribe(Some(u64::MAX));
        bus.publish(job_done(1));
        let (dropped, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].seq, 2);
    }

    #[test]
    fn idle_times_out_and_close_wakes() {
        let bus = Arc::new(EventBus::new(8));
        let mut sub = bus.subscribe(None);
        assert!(matches!(
            sub.next(Duration::from_millis(20)),
            Delivery::Idle
        ));
        let closer = Arc::clone(&bus);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            closer.close();
        });
        // a long wait returns promptly once the bus closes
        assert!(matches!(
            sub.next(Duration::from_secs(30)),
            Delivery::Closed
        ));
        t.join().unwrap();
    }

    #[test]
    fn close_delivers_pending_events_before_closed() {
        let bus = EventBus::new(8);
        let mut sub = bus.subscribe(None);
        bus.publish(job_done(0));
        bus.close();
        let (_, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(events.len(), 1);
        assert!(matches!(
            sub.next(Duration::from_millis(10)),
            Delivery::Closed
        ));
    }

    #[test]
    fn subscriber_gauge_tracks_lifetimes() {
        let bus = EventBus::new(8);
        assert_eq!(bus.subscriber_count(), 0);
        {
            let _a = bus.subscribe(None);
            let _b = bus.subscribe(None);
            assert_eq!(bus.subscriber_count(), 2);
        }
        assert_eq!(bus.subscriber_count(), 0);
    }

    #[test]
    fn publisher_never_blocks_on_a_stalled_subscriber() {
        // a subscriber that never calls next() must not slow the
        // publish path: 10k publishes into a 16-slot ring finish fast
        let bus = EventBus::new(16);
        let _stalled = bus.subscribe(None);
        let t0 = Instant::now();
        for i in 0..10_000 {
            bus.publish(job_done(i));
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "publish stalled behind a dead subscriber"
        );
        assert_eq!(bus.published_total(), 10_000);
    }

    #[test]
    fn sse_frame_shape() {
        let e = Event {
            seq: 7,
            kind: EventKind::CacheHit { key: "abc".into(), tier: "disk" },
        };
        let f = e.sse_frame();
        assert!(f.starts_with("id: 7\nevent: cache.hit\ndata: {"), "{f}");
        assert!(f.ends_with("\n\n"), "{f}");
        assert!(f.contains("\"tier\":\"disk\""), "{f}");
        // data stays on one line (SSE frames are newline-delimited)
        assert_eq!(f.trim_end().lines().count(), 3, "{f}");
    }

    #[test]
    fn gap_frame_resumes_cleanly() {
        let f = gap_frame(7, 6);
        // reconnecting with the gap's id (6) resumes at event 7
        assert!(f.starts_with("id: 6\nevent: gap\n"), "{f}");
        assert!(f.contains("{\"dropped\":6}"), "{f}");
    }

    #[test]
    fn concurrent_publishers_never_duplicate_or_skip_seqs() {
        let bus = Arc::new(EventBus::new(4096));
        let mut sub = bus.subscribe(None);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let bus = Arc::clone(&bus);
                std::thread::spawn(move || {
                    for i in 0..250 {
                        bus.publish(job_done(t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let (dropped, _, events) = batch(sub.next(Duration::from_secs(1)));
        assert_eq!(dropped, 0);
        let mut seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (1..=1000).collect::<Vec<u64>>());
    }
}
