//! `icecloud serve` — the scenario-sweep decision-support service.
//!
//! The paper's §III–§IV analyses answer operator questions ("what would
//! this campaign cost under half the budget? busier spot markets? a
//! different NAT timeout?").  PR 1 made those answers a deterministic
//! one-shot CLI; this subsystem makes them a *service*: a zero-
//! dependency HTTP/1.1 server (`http`) in front of the sweep engine,
//! with a shared replay worker pool and async job table (`jobs`), a
//! two-tier content-addressed result cache — in-memory LRU with
//! single-flight deduplication (`cache`) over a persistent disk store
//! (`store`) — request routing (`router`), a `/metrics` exposition
//! (`metrics`), and a live operations plane: a bounded broadcast bus
//! of typed transitions (`events`) streamed over `GET /events` SSE,
//! plus a wall-clock monitoring database (`ops`) behind `/timeseries`
//! and the `/dash` burn-down board.
//!
//! Determinism is the scaling story: identical scenario → byte-
//! identical summary, so the cache turns heavy identical-request
//! traffic into a handful of actual replays, and the disk tier makes
//! those replays survive restarts — the same durability concern that
//! drove IceCube's GPU workflows onto XRootD Origins (Schultz et al.,
//! PNRP 2023) and HEPCloud's elastic-admission design (arXiv:1710.00100).
//!
//! Thread model (see DESIGN.md §12 and §14):
//!
//! ```text
//! accept thread ──sync_channel(64)──▶ N connection handlers ──┐
//!        (bounded handoff)               parse / route / write │
//!                                                              ▼
//!    POST /sweep ──────────────▶ two-tier cache (single-flight) ─▶
//!    POST /sweep?mode=async ─▶ job queue ─▶ K job runners ──▶ │
//!        (bounded; 429 on overflow)     replay pool: M workers
//! ```

pub mod cache;
pub mod events;
pub mod fleet;
pub mod http;
pub mod jobs;
pub mod metrics;
pub mod ops;
pub mod router;
pub mod store;

pub use cache::ResultCache;
pub use events::{Event, EventBus, EventKind};
pub use fleet::{FleetOptions, FleetTable, WorkerOptions, WorkerReport};
pub use jobs::{JobTable, ReplayPool};
pub use metrics::Metrics;
pub use ops::OpsMonitor;
pub use router::AppState;
pub use store::DiskStore;

use crate::config::CampaignConfig;
use events::Delivery;
use http::{error_response, read_request, write_response, ReadError};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// How long an idle keep-alive connection may sit before we close it.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);
/// Bounded accept→handler handoff: connections beyond this queue up in
/// the kernel backlog instead of unbounded process memory.
const ACCEPT_QUEUE: usize = 64;
/// How often an idle SSE stream emits a comment, so clients and proxies
/// can tell a quiet bus from a dead connection.
const SSE_HEARTBEAT: Duration = Duration::from_secs(2);
/// Longest one stalled subscriber socket may pin a handler thread; a
/// write that cannot finish within this abandons the stream (the client
/// reconnects with `Last-Event-ID` and gets an honest gap).
const SSE_WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Server configuration.
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// HTTP connection-handler threads.
    pub http_threads: usize,
    /// Campaign-replay worker threads.
    pub replay_threads: usize,
    /// Result-cache (memory tier) byte budget.
    pub cache_bytes: usize,
    /// Bounded async-job admission queue; submissions beyond this are
    /// shed with `429 + Retry-After`.
    pub queue_max: usize,
    /// Async job-runner threads draining the admission queue.
    pub job_runners: usize,
    /// Persistent result-store root; `None` = memory-only (results do
    /// not survive restarts).
    pub store_dir: Option<PathBuf>,
    /// Lease/heartbeat knobs for the remote worker fleet.
    pub fleet: FleetOptions,
    /// Event-bus ring capacity (`[ops] events_ring`).
    pub events_ring: usize,
    /// Ops sampler cadence in seconds (`[ops] sample_every_s`).
    pub sample_every_s: u64,
    /// Finished job records `GET /jobs` retains (`[server] jobs_keep`).
    pub jobs_keep: usize,
    /// Base campaign every request's scenario spec resolves against.
    pub base: CampaignConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8080".to_string(),
            http_threads: 8,
            replay_threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            cache_bytes: 64 << 20,
            queue_max: 32,
            job_runners: 2,
            store_dir: None,
            fleet: FleetOptions::default(),
            events_ring: events::DEFAULT_EVENTS_RING,
            sample_every_s: ops::DEFAULT_SAMPLE_EVERY_S,
            jobs_keep: jobs::DEFAULT_JOBS_KEEP,
            base: CampaignConfig::default(),
        }
    }
}

/// A bound (but not yet serving) server.
pub struct Server {
    listener: TcpListener,
    http_threads: usize,
    sample_every_s: u64,
    state: Arc<AppState>,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> Result<Server, String> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        // one bus, attached to every producer before anything is shared
        let events = Arc::new(EventBus::new(cfg.events_ring));
        let disk = match &cfg.store_dir {
            Some(dir) => {
                let mut d = DiskStore::open(dir)?;
                d.set_events(Arc::clone(&events));
                Some(d)
            }
            None => None,
        };
        let mut cache = ResultCache::with_disk(cfg.cache_bytes, disk);
        cache.set_events(Arc::clone(&events));
        let cache = Arc::new(cache);
        let pool = Arc::new(ReplayPool::new(cfg.replay_threads));
        let fleet = Arc::new(FleetTable::with_events(
            cfg.fleet,
            Arc::clone(&events),
        ));
        let metrics = Arc::new(Metrics::new());
        let jobs = JobTable::start(
            cfg.queue_max,
            cfg.job_runners,
            Arc::clone(&cache),
            Arc::clone(&pool),
            Arc::clone(&fleet),
            Arc::clone(&metrics),
            Arc::clone(&events),
            cfg.jobs_keep,
        );
        let state = Arc::new(AppState {
            base: cfg.base,
            cache,
            pool,
            fleet,
            metrics,
            jobs,
            events,
            ops: Arc::new(OpsMonitor::new()),
        });
        Ok(Server {
            listener,
            http_threads: cfg.http_threads.max(1),
            sample_every_s: cfg.sample_every_s.max(1),
            state,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener.local_addr().map_err(|e| e.to_string())
    }

    /// Serve forever on the calling thread (the CLI path).
    pub fn run(self) -> Result<(), String> {
        let stop = Arc::new(AtomicBool::new(false));
        self.serve_until(&stop)
    }

    /// Serve in background threads; the handle stops and joins on
    /// [`ServerHandle::shutdown`] (the test / bench path).
    pub fn spawn(self) -> Result<ServerHandle, String> {
        let addr = self.local_addr()?;
        let state = Arc::clone(&self.state);
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            let _ = self.serve_until(&stop_accept);
        });
        Ok(ServerHandle { addr, state, stop, accept_thread })
    }

    fn serve_until(self, stop: &AtomicBool) -> Result<(), String> {
        // ops sampler: one thread feeding the /timeseries and /dash
        // burn-down series.  It has its own stop flag so it can be
        // joined here regardless of how the caller's flag is shared.
        let sampler_stop = Arc::new(AtomicBool::new(false));
        let sampler = {
            let state = Arc::clone(&self.state);
            let stop = Arc::clone(&sampler_stop);
            let every = Duration::from_secs(self.sample_every_s);
            std::thread::spawn(move || {
                sample_ops(&state);
                while !stop.load(Ordering::SeqCst) {
                    // sleep in short slices so shutdown never waits out
                    // a full sampling period
                    let mut slept = Duration::ZERO;
                    while slept < every && !stop.load(Ordering::SeqCst) {
                        let slice = Duration::from_millis(50);
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    sample_ops(&state);
                }
            })
        };

        let (tx, rx) = mpsc::sync_channel::<TcpStream>(ACCEPT_QUEUE);
        let rx = Arc::new(Mutex::new(rx));
        let mut handlers = Vec::with_capacity(self.http_threads);
        for _ in 0..self.http_threads {
            let rx = Arc::clone(&rx);
            let state = Arc::clone(&self.state);
            handlers.push(std::thread::spawn(move || loop {
                // tolerate a poisoned handoff mutex: a handler that
                // panicked mid-recv must not wedge the whole accept
                // pipeline behind a poison error
                let stream = match rx
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .recv()
                {
                    Ok(s) => s,
                    Err(_) => break, // accept loop gone; drain and exit
                };
                // one pathological request must not cost a handler
                // thread for the rest of the process lifetime
                let _ = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        handle_connection(&state, stream)
                    }),
                );
            }));
        }

        for stream in self.listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let _ = s.set_read_timeout(Some(IDLE_TIMEOUT));
                    let _ = s.set_nodelay(true);
                    if tx.send(s).is_err() {
                        break;
                    }
                }
                Err(_) => continue, // transient accept error
            }
        }
        drop(tx);
        // in-flight SSE streams are parked in Subscription::next; close
        // the bus so they observe Closed instead of waiting out a
        // heartbeat each
        self.state.events.close();
        for h in handlers {
            let _ = h.join();
        }
        sampler_stop.store(true, Ordering::SeqCst);
        let _ = sampler.join();
        Ok(())
    }
}

/// One sampler tick: the wall-clock burn-down series (DESIGN.md §17).
fn sample_ops(state: &AppState) {
    let (jobs_queued, jobs_running) = state.jobs.counts();
    let fleet = state.fleet.stats();
    state.ops.record_all(&[
        ("jobs.queued", jobs_queued as f64),
        ("jobs.running", jobs_running as f64),
        ("replay.queue_depth", state.pool.queue_depth() as f64),
        ("fleet.leases_outstanding", fleet.leases_outstanding as f64),
        ("fleet.units_pending", fleet.units_pending as f64),
        ("goodput.hours", state.metrics.goodput_hours()),
        ("wasted.hours", state.metrics.wasted_hours()),
        ("events.published", state.events.published_total() as f64),
    ]);
}

/// Handle to a background server (tests and the load generator).
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<AppState>,
    stop: Arc<AtomicBool>,
    accept_thread: std::thread::JoinHandle<()>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared state, so tests can assert on metrics directly.
    pub fn state(&self) -> &AppState {
        &self.state
    }

    /// Stop accepting, drain handler threads, and join.  Dropping the
    /// last `AppState` reference afterwards joins the job runners too
    /// (`JobTable::drop`), so a shut-down server leaves no threads.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake parked SSE streams now rather than at handler join
        self.state.events.close();
        // unblock the accept loop with one last connection
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
    }
}

/// Serve one connection: requests until close, error, or idle timeout.
fn handle_connection(state: &AppState, stream: TcpStream) {
    let mut write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            // clean close, peer reset, or idle-timeout expiry
            Ok(None) | Err(ReadError::Closed) => return,
            Err(ReadError::TooLarge) => {
                state.metrics.on_request();
                let resp = error_response(413, "request too large")
                    .with_header("X-Api-Version", "1");
                state.metrics.on_early_reject(resp.status);
                let _ = write_response(&mut write_half, &resp, false);
                return;
            }
            Err(ReadError::Malformed(msg)) => {
                state.metrics.on_request();
                let resp = error_response(400, &msg)
                    .with_header("X-Api-Version", "1");
                state.metrics.on_early_reject(resp.status);
                let _ = write_response(&mut write_half, &resp, false);
                return;
            }
        };
        let keep_alive = req.keep_alive();
        let t0 = Instant::now();
        state.metrics.on_request();
        let resp = match router::dispatch(state, &req) {
            router::Routed::Response(resp) => resp,
            router::Routed::Events { resume } => {
                // the stream owns the connection from here; count the
                // hand-off as the response
                state
                    .metrics
                    .on_response(200, t0.elapsed().as_secs_f64());
                serve_sse(state, &mut write_half, resume);
                return;
            }
        };
        state
            .metrics
            .on_response(resp.status, t0.elapsed().as_secs_f64());
        if write_response(&mut write_half, &resp, keep_alive).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

/// Stream the event bus over one connection until the client hangs up,
/// a write stalls past [`SSE_WRITE_TIMEOUT`], or the bus closes.  The
/// head is written by hand: SSE bodies are unbounded, so the
/// `Content-Length` framing in `write_response` cannot apply.
fn serve_sse(
    state: &AppState,
    stream: &mut TcpStream,
    resume: Option<u64>,
) {
    let _ = stream.set_write_timeout(Some(SSE_WRITE_TIMEOUT));
    let head = "HTTP/1.1 200 OK\r\n\
                Content-Type: text/event-stream\r\n\
                Cache-Control: no-cache\r\n\
                X-Api-Version: 1\r\n\
                Connection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    let mut sub = state.events.subscribe(resume);
    loop {
        let mut out = String::new();
        match sub.next(SSE_HEARTBEAT) {
            Delivery::Batch { dropped, resume, events: batch } => {
                if dropped > 0 {
                    out.push_str(&events::gap_frame(resume, dropped));
                }
                for ev in &batch {
                    out.push_str(&ev.sse_frame());
                }
            }
            Delivery::Idle => out.push_str(": heartbeat\n\n"),
            Delivery::Closed => return,
        }
        if stream.write_all(out.as_bytes()).is_err()
            || stream.flush().is_err()
        {
            return;
        }
    }
}
