//! Server counters and the `/metrics` text exposition.
//!
//! Lock-free atomic counters for everything on the request hot path,
//! plus a small mutex-guarded ring of recent request latencies that is
//! reduced to percentiles (`util::stats`) only when `/metrics` is
//! scraped.  The exposition format is the Prometheus text format —
//! `name{label="v"} value` lines — so any off-the-shelf scraper can
//! consume it, without this crate growing a client-library dependency.

use super::cache::Outcome;
use super::fleet::FleetStats;
use crate::util::stats;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// How many recent request latencies the percentile window keeps.
const LATENCY_WINDOW: usize = 4096;

/// Fixed-capacity overwrite-oldest ring of latency samples.
struct LatencyRing {
    buf: Vec<f64>,
    next: usize,
}

impl LatencyRing {
    fn new() -> Self {
        LatencyRing { buf: Vec::with_capacity(LATENCY_WINDOW), next: 0 }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }
}

/// Point-in-time gauges owned by other components (replay pool, cache
/// tiers, job table), sampled by the router at scrape time so this
/// module stays dependency-free.
#[derive(Debug, Clone, Copy, Default)]
pub struct Gauges {
    pub replay_queue_depth: usize,
    pub cache_entries: usize,
    pub cache_bytes: usize,
    pub store_entries: usize,
    pub store_bytes: u64,
    pub jobs_queued: usize,
    pub jobs_running: usize,
    /// Worker-fleet accounting, sampled from the lease table.
    pub fleet: FleetStats,
    /// Event-bus accounting, sampled from `server::events::EventBus`
    /// (the bus owns its own atomics; scrapes read them like any other
    /// component gauge).
    pub events_published: u64,
    pub events_dropped: u64,
    pub events_subscribers: u64,
}

/// One server's counter set.  All methods take `&self`; the struct is
/// shared across connection-handler threads behind an `Arc`.
pub struct Metrics {
    requests_total: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    store_hits: AtomicU64,
    store_misses: AtomicU64,
    sweep_computations: AtomicU64,
    scenario_replays: AtomicU64,
    /// Wall-hour split of every replayed scenario, accumulated in
    /// milli-hours so the counter stays a lock-free integer (the
    /// exposition renders hours).
    replay_goodput_millihours: AtomicU64,
    replay_wasted_millihours: AtomicU64,
    jobs_submitted: AtomicU64,
    jobs_done: AtomicU64,
    jobs_failed: AtomicU64,
    jobs_shed: AtomicU64,
    latency: Mutex<LatencyRing>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests_total: AtomicU64::new(0),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            store_misses: AtomicU64::new(0),
            sweep_computations: AtomicU64::new(0),
            scenario_replays: AtomicU64::new(0),
            replay_goodput_millihours: AtomicU64::new(0),
            replay_wasted_millihours: AtomicU64::new(0),
            jobs_submitted: AtomicU64::new(0),
            jobs_done: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            jobs_shed: AtomicU64::new(0),
            latency: Mutex::new(LatencyRing::new()),
        }
    }

    pub fn on_request(&self) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_response(&self, status: u16, latency_s: f64) {
        self.count_response_class(status);
        self.latency
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(latency_s);
    }

    /// A request rejected before routing (malformed bytes, oversized
    /// body).  Counted by status class but kept out of the latency
    /// window: its "latency" is dominated by the attacker's send rate
    /// (or the idle timeout), and a burst of zeros/timeouts would mask
    /// real percentile regressions on legitimate requests.
    pub fn on_early_reject(&self, status: u16) {
        self.count_response_class(status);
    }

    fn count_response_class(&self, status: u16) {
        let counter = match status {
            200..=299 => &self.responses_2xx,
            500..=599 => &self.responses_5xx,
            _ => &self.responses_4xx,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// A result served from the persistent disk tier.
    pub fn on_disk_hit(&self) {
        self.store_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A miss that also consulted (and missed) the disk tier.
    pub fn on_disk_miss(&self) {
        self.store_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Shared accounting for a delivered cache outcome: memory hits,
    /// disk hits, and misses (which also count a disk miss when a disk
    /// tier was consulted).  Callers that surface an owner's error to a
    /// waiter must not call this — nothing was served.
    pub fn on_lookup_outcome(&self, outcome: Outcome, disk_enabled: bool) {
        match outcome {
            Outcome::Hit => self.on_cache_hit(),
            Outcome::DiskHit => self.on_disk_hit(),
            Outcome::Miss => {
                self.on_cache_miss();
                if disk_enabled {
                    self.on_disk_miss();
                }
            }
        }
    }

    /// One underlying sweep actually replayed (`replays` scenarios,
    /// whose rows summed to the given goodput/wasted instance-hour
    /// split — the preemption-loss accounting of DESIGN.md §15).
    pub fn on_sweep_computed(
        &self,
        replays: usize,
        goodput_hours: f64,
        wasted_hours: f64,
    ) {
        self.sweep_computations.fetch_add(1, Ordering::Relaxed);
        self.scenario_replays
            .fetch_add(replays as u64, Ordering::Relaxed);
        self.replay_goodput_millihours.fetch_add(
            (goodput_hours.max(0.0) * 1000.0).round() as u64,
            Ordering::Relaxed,
        );
        self.replay_wasted_millihours.fetch_add(
            (wasted_hours.max(0.0) * 1000.0).round() as u64,
            Ordering::Relaxed,
        );
    }

    /// An async job admitted (queued or instantly completed).
    pub fn on_job_submitted(&self) {
        self.jobs_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// An async job reached a terminal state.
    pub fn on_job_finished(&self, ok: bool) {
        let counter =
            if ok { &self.jobs_done } else { &self.jobs_failed };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// An async submission shed by the bounded admission queue (429).
    pub fn on_job_shed(&self) {
        self.jobs_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_hit_count(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn disk_hit_count(&self) -> u64 {
        self.store_hits.load(Ordering::Relaxed)
    }

    pub fn sweep_computation_count(&self) -> u64 {
        self.sweep_computations.load(Ordering::Relaxed)
    }

    pub fn jobs_shed_count(&self) -> u64 {
        self.jobs_shed.load(Ordering::Relaxed)
    }

    pub fn jobs_submitted_count(&self) -> u64 {
        self.jobs_submitted.load(Ordering::Relaxed)
    }

    /// Accumulated replay goodput in hours (the ops monitor samples
    /// this into the `goodput.hours` time series).
    pub fn goodput_hours(&self) -> f64 {
        self.replay_goodput_millihours.load(Ordering::Relaxed) as f64
            / 1000.0
    }

    /// Accumulated replay badput in hours.
    pub fn wasted_hours(&self) -> f64 {
        self.replay_wasted_millihours.load(Ordering::Relaxed) as f64
            / 1000.0
    }

    /// Render the text exposition over the sampled gauges.
    pub fn render(&self, g: &Gauges) -> String {
        let mut out = String::with_capacity(1536);
        let mut line = |name: &str, value: String| {
            let _ = writeln!(out, "{name} {value}");
        };
        line(
            "icecloud_http_requests_total",
            self.requests_total.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"2xx\"}",
            self.responses_2xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"4xx\"}",
            self.responses_4xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"5xx\"}",
            self.responses_5xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_cache_hits_total",
            self.cache_hits.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_cache_misses_total",
            self.cache_misses.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_store_hits_total",
            self.store_hits.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_store_misses_total",
            self.store_misses.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_computations_total",
            self.sweep_computations.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_scenario_replays_total",
            self.scenario_replays.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_replay_goodput_hours_total",
            format!(
                "{:.3}",
                self.replay_goodput_millihours.load(Ordering::Relaxed)
                    as f64
                    / 1000.0
            ),
        );
        line(
            "icecloud_replay_wasted_hours_total",
            format!(
                "{:.3}",
                self.replay_wasted_millihours.load(Ordering::Relaxed)
                    as f64
                    / 1000.0
            ),
        );
        line(
            "icecloud_jobs_submitted_total",
            self.jobs_submitted.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_jobs_finished_total{status=\"done\"}",
            self.jobs_done.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_jobs_finished_total{status=\"failed\"}",
            self.jobs_failed.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_jobs_shed_total",
            self.jobs_shed.load(Ordering::Relaxed).to_string(),
        );
        line("icecloud_jobs_queued", g.jobs_queued.to_string());
        line("icecloud_jobs_running", g.jobs_running.to_string());
        line(
            "icecloud_replay_queue_depth",
            g.replay_queue_depth.to_string(),
        );
        line(
            "icecloud_result_cache_entries",
            g.cache_entries.to_string(),
        );
        line("icecloud_result_cache_bytes", g.cache_bytes.to_string());
        line(
            "icecloud_result_store_entries",
            g.store_entries.to_string(),
        );
        line("icecloud_result_store_bytes", g.store_bytes.to_string());
        line(
            "icecloud_fleet_workers_registered",
            g.fleet.workers_registered.to_string(),
        );
        line(
            "icecloud_fleet_workers_alive",
            g.fleet.workers_alive.to_string(),
        );
        line(
            "icecloud_fleet_units_pending",
            g.fleet.units_pending.to_string(),
        );
        line(
            "icecloud_fleet_leases_granted_total",
            g.fleet.leases_granted.to_string(),
        );
        line(
            "icecloud_fleet_leases_completed_total",
            g.fleet.leases_completed.to_string(),
        );
        line(
            "icecloud_fleet_leases_expired_total",
            g.fleet.leases_expired.to_string(),
        );
        line(
            "icecloud_fleet_leases_rejected_total",
            g.fleet.leases_rejected.to_string(),
        );
        // every expiry or rejection requeues its unit
        line(
            "icecloud_fleet_leases_requeued_total",
            (g.fleet.leases_expired + g.fleet.leases_rejected).to_string(),
        );
        line(
            "icecloud_fleet_leases_outstanding",
            g.fleet.leases_outstanding.to_string(),
        );
        line(
            "icecloud_fleet_spot_checks_total{verdict=\"pass\"}",
            g.fleet.spot_checks_pass.to_string(),
        );
        line(
            "icecloud_fleet_spot_checks_total{verdict=\"fail\"}",
            g.fleet.spot_checks_fail.to_string(),
        );
        line(
            "icecloud_events_published_total",
            g.events_published.to_string(),
        );
        line(
            "icecloud_events_dropped_total",
            g.events_dropped.to_string(),
        );
        line(
            "icecloud_events_subscribers",
            g.events_subscribers.to_string(),
        );
        let samples = self
            .latency
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .buf
            .clone();
        let ps = stats::percentiles(&samples, &[0.5, 0.9, 0.99]);
        for (q, p) in [("0.5", ps[0]), ("0.9", ps[1]), ("0.99", ps[2])] {
            let v = if p.is_nan() {
                "NaN".to_string()
            } else {
                format!("{p:.6}")
            };
            line(
                &format!(
                    "icecloud_request_latency_seconds{{quantile=\"{q}\"}}"
                ),
                v,
            );
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauges() -> Gauges {
        Gauges {
            replay_queue_depth: 2,
            cache_entries: 1,
            cache_bytes: 512,
            store_entries: 3,
            store_bytes: 2048,
            jobs_queued: 4,
            jobs_running: 1,
            fleet: FleetStats {
                workers_registered: 3,
                workers_alive: 2,
                units_pending: 5,
                leases_granted: 9,
                leases_completed: 6,
                leases_expired: 1,
                leases_rejected: 1,
                leases_outstanding: 1,
                spot_checks_pass: 4,
                spot_checks_fail: 1,
            },
            events_published: 12,
            events_dropped: 3,
            events_subscribers: 2,
        }
    }

    #[test]
    fn counters_appear_in_exposition() {
        let m = Metrics::new();
        m.on_request();
        m.on_request();
        m.on_response(200, 0.002);
        m.on_response(404, 0.001);
        m.on_cache_hit();
        m.on_cache_miss();
        m.on_disk_hit();
        m.on_sweep_computed(3, 12.25, 1.5);
        m.on_job_submitted();
        m.on_job_finished(true);
        m.on_job_shed();
        let text = m.render(&gauges());
        assert!(text.contains("icecloud_http_requests_total 2"), "{text}");
        assert!(
            text.contains("icecloud_http_responses_total{class=\"2xx\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_http_responses_total{class=\"4xx\"} 1"),
            "{text}"
        );
        assert!(text.contains("icecloud_sweep_cache_hits_total 1"), "{text}");
        assert!(
            text.contains("icecloud_sweep_cache_misses_total 1"),
            "{text}"
        );
        assert!(text.contains("icecloud_store_hits_total 1"), "{text}");
        assert!(
            text.contains("icecloud_sweep_computations_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_scenario_replays_total 3"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_replay_goodput_hours_total 12.250"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_replay_wasted_hours_total 1.500"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_jobs_submitted_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_jobs_finished_total{status=\"done\"} 1"),
            "{text}"
        );
        assert!(text.contains("icecloud_jobs_shed_total 1"), "{text}");
        assert!(text.contains("icecloud_jobs_queued 4"), "{text}");
        assert!(text.contains("icecloud_jobs_running 1"), "{text}");
        assert!(text.contains("icecloud_replay_queue_depth 2"), "{text}");
        assert!(text.contains("icecloud_result_cache_bytes 512"), "{text}");
        assert!(text.contains("icecloud_fleet_workers_registered 3"), "{text}");
        assert!(text.contains("icecloud_fleet_workers_alive 2"), "{text}");
        assert!(text.contains("icecloud_fleet_units_pending 5"), "{text}");
        assert!(text.contains("icecloud_fleet_leases_granted_total 9"), "{text}");
        assert!(text.contains("icecloud_fleet_leases_expired_total 1"), "{text}");
        assert!(text.contains("icecloud_fleet_leases_requeued_total 2"), "{text}");
        assert!(text.contains("icecloud_fleet_leases_outstanding 1"), "{text}");
        assert!(
            text.contains("icecloud_fleet_spot_checks_total{verdict=\"pass\"} 4"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_fleet_spot_checks_total{verdict=\"fail\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_result_store_entries 3"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_result_store_bytes 2048"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_events_published_total 12"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_events_dropped_total 3"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_events_subscribers 2"),
            "{text}"
        );
    }

    #[test]
    fn goodput_accessors_mirror_the_exposition() {
        let m = Metrics::new();
        m.on_sweep_computed(2, 3.5, 0.25);
        assert!((m.goodput_hours() - 3.5).abs() < 1e-9);
        assert!((m.wasted_hours() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn lookup_outcome_accounting() {
        let m = Metrics::new();
        m.on_lookup_outcome(Outcome::Hit, true);
        m.on_lookup_outcome(Outcome::DiskHit, true);
        m.on_lookup_outcome(Outcome::Miss, true);
        m.on_lookup_outcome(Outcome::Miss, false);
        let text = m.render(&Gauges::default());
        assert!(text.contains("icecloud_sweep_cache_hits_total 1"), "{text}");
        assert!(
            text.contains("icecloud_sweep_cache_misses_total 2"),
            "{text}"
        );
        assert!(text.contains("icecloud_store_hits_total 1"), "{text}");
        assert!(
            text.contains("icecloud_store_misses_total 1"),
            "{text}"
        );
    }

    #[test]
    fn latency_percentiles_render() {
        let m = Metrics::new();
        for i in 0..100 {
            m.on_response(200, i as f64 / 1000.0);
        }
        let text = m.render(&Gauges::default());
        assert!(
            text.contains("icecloud_request_latency_seconds{quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn early_rejects_count_by_class_but_skip_latency_window() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.on_early_reject(413);
        }
        let text = m.render(&Gauges::default());
        assert!(
            text.contains("icecloud_http_responses_total{class=\"4xx\"} 5"),
            "{text}"
        );
        // the latency window saw nothing: percentiles still NaN
        assert!(text.contains("quantile=\"0.5\"} NaN"), "{text}");
    }

    #[test]
    fn empty_latency_window_renders_nan() {
        let text = Metrics::new().render(&Gauges::default());
        assert!(
            text.contains("quantile=\"0.99\"} NaN"),
            "{text}"
        );
    }

    #[test]
    fn latency_ring_overwrites_oldest() {
        let mut r = LatencyRing::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            r.push(i as f64);
        }
        assert_eq!(r.buf.len(), LATENCY_WINDOW);
        // the oldest 10 samples were overwritten
        assert!(!r.buf.contains(&0.0));
        assert!(r.buf.contains(&(LATENCY_WINDOW as f64)));
    }
}
