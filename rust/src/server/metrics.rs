//! Server counters and the `/metrics` text exposition.
//!
//! Lock-free atomic counters for everything on the request hot path,
//! plus a small mutex-guarded ring of recent request latencies that is
//! reduced to percentiles (`util::stats`) only when `/metrics` is
//! scraped.  The exposition format is the Prometheus text format —
//! `name{label="v"} value` lines — so any off-the-shelf scraper can
//! consume it, without this crate growing a client-library dependency.

use crate::util::stats;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// How many recent request latencies the percentile window keeps.
const LATENCY_WINDOW: usize = 4096;

/// Fixed-capacity overwrite-oldest ring of latency samples.
struct LatencyRing {
    buf: Vec<f64>,
    next: usize,
}

impl LatencyRing {
    fn new() -> Self {
        LatencyRing { buf: Vec::with_capacity(LATENCY_WINDOW), next: 0 }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
    }
}

/// One server's counter set.  All methods take `&self`; the struct is
/// shared across connection-handler threads behind an `Arc`.
pub struct Metrics {
    requests_total: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    sweep_computations: AtomicU64,
    scenario_replays: AtomicU64,
    latency: Mutex<LatencyRing>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests_total: AtomicU64::new(0),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            sweep_computations: AtomicU64::new(0),
            scenario_replays: AtomicU64::new(0),
            latency: Mutex::new(LatencyRing::new()),
        }
    }

    pub fn on_request(&self) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_response(&self, status: u16, latency_s: f64) {
        self.count_response_class(status);
        self.latency.lock().unwrap().push(latency_s);
    }

    /// A request rejected before routing (malformed bytes, oversized
    /// body).  Counted by status class but kept out of the latency
    /// window: its "latency" is dominated by the attacker's send rate
    /// (or the idle timeout), and a burst of zeros/timeouts would mask
    /// real percentile regressions on legitimate requests.
    pub fn on_early_reject(&self, status: u16) {
        self.count_response_class(status);
    }

    fn count_response_class(&self, status: u16) {
        let counter = match status {
            200..=299 => &self.responses_2xx,
            500..=599 => &self.responses_5xx,
            _ => &self.responses_4xx,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// One underlying sweep actually replayed (`replays` scenarios).
    pub fn on_sweep_computed(&self, replays: usize) {
        self.sweep_computations.fetch_add(1, Ordering::Relaxed);
        self.scenario_replays
            .fetch_add(replays as u64, Ordering::Relaxed);
    }

    pub fn cache_hit_count(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn sweep_computation_count(&self) -> u64 {
        self.sweep_computations.load(Ordering::Relaxed)
    }

    /// Render the text exposition.  Gauges owned by other components
    /// (replay queue depth, cache occupancy) are passed in by the
    /// router so this module stays dependency-free.
    pub fn render(
        &self,
        queue_depth: usize,
        cache_entries: usize,
        cache_bytes: usize,
    ) -> String {
        let mut out = String::with_capacity(1024);
        let mut line = |name: &str, value: String| {
            let _ = writeln!(out, "{name} {value}");
        };
        line(
            "icecloud_http_requests_total",
            self.requests_total.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"2xx\"}",
            self.responses_2xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"4xx\"}",
            self.responses_4xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_http_responses_total{class=\"5xx\"}",
            self.responses_5xx.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_cache_hits_total",
            self.cache_hits.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_cache_misses_total",
            self.cache_misses.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_sweep_computations_total",
            self.sweep_computations.load(Ordering::Relaxed).to_string(),
        );
        line(
            "icecloud_scenario_replays_total",
            self.scenario_replays.load(Ordering::Relaxed).to_string(),
        );
        line("icecloud_replay_queue_depth", queue_depth.to_string());
        line("icecloud_result_cache_entries", cache_entries.to_string());
        line("icecloud_result_cache_bytes", cache_bytes.to_string());
        let samples = self.latency.lock().unwrap().buf.clone();
        let ps = stats::percentiles(&samples, &[0.5, 0.9, 0.99]);
        for (q, p) in [("0.5", ps[0]), ("0.9", ps[1]), ("0.99", ps[2])] {
            let v = if p.is_nan() {
                "NaN".to_string()
            } else {
                format!("{p:.6}")
            };
            line(
                &format!(
                    "icecloud_request_latency_seconds{{quantile=\"{q}\"}}"
                ),
                v,
            );
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_appear_in_exposition() {
        let m = Metrics::new();
        m.on_request();
        m.on_request();
        m.on_response(200, 0.002);
        m.on_response(404, 0.001);
        m.on_cache_hit();
        m.on_cache_miss();
        m.on_sweep_computed(3);
        let text = m.render(2, 1, 512);
        assert!(text.contains("icecloud_http_requests_total 2"), "{text}");
        assert!(
            text.contains("icecloud_http_responses_total{class=\"2xx\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_http_responses_total{class=\"4xx\"} 1"),
            "{text}"
        );
        assert!(text.contains("icecloud_sweep_cache_hits_total 1"), "{text}");
        assert!(
            text.contains("icecloud_sweep_cache_misses_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_sweep_computations_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_scenario_replays_total 3"),
            "{text}"
        );
        assert!(text.contains("icecloud_replay_queue_depth 2"), "{text}");
        assert!(text.contains("icecloud_result_cache_bytes 512"), "{text}");
    }

    #[test]
    fn latency_percentiles_render() {
        let m = Metrics::new();
        for i in 0..100 {
            m.on_response(200, i as f64 / 1000.0);
        }
        let text = m.render(0, 0, 0);
        assert!(
            text.contains("icecloud_request_latency_seconds{quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(!text.contains("NaN"), "{text}");
    }

    #[test]
    fn early_rejects_count_by_class_but_skip_latency_window() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.on_early_reject(413);
        }
        let text = m.render(0, 0, 0);
        assert!(
            text.contains("icecloud_http_responses_total{class=\"4xx\"} 5"),
            "{text}"
        );
        // the latency window saw nothing: percentiles still NaN
        assert!(text.contains("quantile=\"0.5\"} NaN"), "{text}");
    }

    #[test]
    fn empty_latency_window_renders_nan() {
        let text = Metrics::new().render(0, 0, 0);
        assert!(
            text.contains("quantile=\"0.99\"} NaN"),
            "{text}"
        );
    }

    #[test]
    fn latency_ring_overwrites_oldest() {
        let mut r = LatencyRing::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            r.push(i as f64);
        }
        assert_eq!(r.buf.len(), LATENCY_WINDOW);
        // the oldest 10 samples were overwritten
        assert!(!r.buf.contains(&0.0));
        assert!(r.buf.contains(&(LATENCY_WINDOW as f64)));
    }
}
