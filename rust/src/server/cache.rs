//! Content-addressed result cache: an in-memory LRU tier with
//! single-flight deduplication, over an optional persistent disk tier
//! (`store::DiskStore`).
//!
//! Determinism is what makes this cache *correct*, not merely fast: a
//! resolved scenario request replays to a byte-identical summary every
//! time (`rust/tests/sweep_determinism.rs`), so a response may be stored
//! forever under the SHA-256 of its canonically-serialized request
//! (`CampaignConfig::canonical_json` + `ScenarioConfig::canonical_json`)
//! and served to any future identical request without revalidation.
//!
//! Two tiers: the memory LRU bounds *hot* bytes; the disk store (when
//! configured) holds every result ever computed, so results survive
//! restarts and eviction from memory never loses anything — a miss in
//! memory falls through to disk and promotes back on hit.  Writes go
//! through to disk on compute; a disk-write failure degrades to
//! memory-only behaviour rather than failing the request.
//!
//! Single-flight: when N identical requests arrive concurrently, the
//! first becomes the *owner* and runs the replay; the other N-1 park on
//! a condvar and receive the owner's bytes.  The flights table is
//! checked under the same lock that re-checks the cache, and the owner
//! inserts into the cache *before* removing its flight entry, so there
//! is no window in which a second owner can start the same computation.
//! The disk probe happens on the owner's side of the flight, so a
//! thundering herd does at most one disk read per key.

use super::events::{EventBus, EventKind};
use super::store::DiskStore;
use crate::util::logger::{self, Level};
use crate::util::sha256;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Stored bodies are shared, not copied, between waiters and the cache.
pub type Body = Arc<Vec<u8>>;

/// What a lookup did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Served from the memory tier, or joined an in-flight computation.
    Hit,
    /// Served from the disk tier (and promoted into memory).
    DiskHit,
    /// This call ran the computation.
    Miss,
}

struct Flight {
    result: Mutex<Option<Result<Body, String>>>,
    done: Condvar,
}

struct Store {
    map: HashMap<String, Body>,
    /// Keys from least- to most-recently used.  Linear touch/remove is
    /// fine at result-cache scale (entries are whole sweep responses).
    order: Vec<String>,
    bytes: usize,
    budget: usize,
}

impl Store {
    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    fn get(&mut self, key: &str) -> Option<Body> {
        let body = self.map.get(key).cloned()?;
        self.touch(key);
        Some(body)
    }

    fn insert(&mut self, key: String, body: Body) {
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.len();
            self.order.retain(|k| k != &key);
        }
        self.bytes += body.len();
        self.map.insert(key.clone(), body);
        self.order.push(key);
        // evict least-recently-used entries over budget, but always keep
        // the newest one so a fresh result stays addressable via
        // GET /results/<key> even if it alone exceeds the budget
        while self.bytes > self.budget && self.order.len() > 1 {
            let victim = self.order.remove(0);
            if let Some(old) = self.map.remove(&victim) {
                self.bytes -= old.len();
            }
        }
    }
}

/// The cache: a byte-budgeted memory tier over an optional disk tier.
pub struct ResultCache {
    store: Mutex<Store>,
    disk: Option<DiskStore>,
    flights: Mutex<HashMap<String, Arc<Flight>>>,
    /// Ops bus for `cache.hit` events; `None` outside a server (CLI
    /// sweeps, unit tests) — hits then go unannounced, nothing else
    /// changes.
    events: Option<Arc<EventBus>>,
}

impl ResultCache {
    /// Memory-only cache (tests; `--store-dir ""`).
    pub fn new(byte_budget: usize) -> Self {
        ResultCache::with_disk(byte_budget, None)
    }

    /// Memory tier over an already-opened disk store.
    pub fn with_disk(byte_budget: usize, disk: Option<DiskStore>) -> Self {
        ResultCache {
            store: Mutex::new(Store {
                map: HashMap::new(),
                order: Vec::new(),
                bytes: 0,
                budget: byte_budget.max(1),
            }),
            disk,
            flights: Mutex::new(HashMap::new()),
            events: None,
        }
    }

    /// Attach the ops bus (called once by `Server::bind` before the
    /// cache is shared).
    pub fn set_events(&mut self, events: Arc<EventBus>) {
        self.events = Some(events);
    }

    fn publish_hit(&self, key: &str, tier: &'static str) {
        if let Some(bus) = &self.events {
            bus.publish(EventKind::CacheHit {
                key: key.to_string(),
                tier,
            });
        }
    }

    /// Whether a disk tier is configured (metrics accounting).
    pub fn has_disk(&self) -> bool {
        self.disk.is_some()
    }

    /// Look up `key` in the memory tier only (tests, fast probes).
    pub fn get(&self, key: &str) -> Option<Body> {
        self.store.lock().unwrap().get(key)
    }

    /// Look up `key` across both tiers without computing (the
    /// `GET /results/<key>` path).  A disk hit is promoted into the
    /// memory LRU so subsequent fetches are pure memory.
    pub fn lookup(&self, key: &str) -> Option<(Body, Outcome)> {
        if let Some(body) = self.store.lock().unwrap().get(key) {
            self.publish_hit(key, "memory");
            return Some((body, Outcome::Hit));
        }
        let body: Body = Arc::new(self.disk.as_ref()?.get(key)?);
        self.store
            .lock()
            .unwrap()
            .insert(key.to_string(), Arc::clone(&body));
        self.publish_hit(key, "disk");
        Some((body, Outcome::DiskHit))
    }

    /// `(entries, bytes)` currently held in the memory tier.
    pub fn stats(&self) -> (usize, usize) {
        let s = self.store.lock().unwrap();
        (s.map.len(), s.bytes)
    }

    /// `(entries, bytes)` on disk; `(0, 0)` when no disk tier.
    pub fn disk_stats(&self) -> (usize, u64) {
        self.disk.as_ref().map(|d| d.stats()).unwrap_or((0, 0))
    }

    /// Drop every memory-tier entry, leaving disk untouched (benches
    /// and tests force the disk path this way; never on a serve path).
    pub fn clear_memory(&self) {
        let mut s = self.store.lock().unwrap();
        s.map.clear();
        s.order.clear();
        s.bytes = 0;
    }

    /// Return the cached body for `key`, or run `compute` exactly once
    /// across all concurrent callers with the same key.  The owner
    /// probes the disk tier before computing, so a restart-warm store
    /// turns would-be replays into `DiskHit`s.  Errors are not cached:
    /// every waiter of a failed flight gets the error, and the next
    /// request retries.
    pub fn get_or_compute(
        &self,
        key: &str,
        compute: impl FnOnce() -> Result<Vec<u8>, String>,
    ) -> (Result<Body, String>, Outcome) {
        let flight = {
            let mut flights = self.flights.lock().unwrap();
            // cache check under the flights lock: a finished owner holds
            // this lock to deregister, and it inserts into the cache
            // first, so "no cache entry and no flight" implies we must
            // become the owner
            if let Some(body) = self.store.lock().unwrap().get(key) {
                self.publish_hit(key, "memory");
                return (Ok(body), Outcome::Hit);
            }
            match flights.get(key).cloned() {
                Some(f) => {
                    drop(flights);
                    // join the in-flight computation
                    let mut slot = f.result.lock().unwrap();
                    while slot.is_none() {
                        slot = f.done.wait(slot).unwrap();
                    }
                    let result = slot.clone().unwrap();
                    return (result, Outcome::Hit);
                }
                None => {
                    let f = Arc::new(Flight {
                        result: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    flights.insert(key.to_string(), Arc::clone(&f));
                    f
                }
            }
        };

        // owner path: disk probe, then compute, all outside every lock
        let (result, outcome) =
            match self.disk.as_ref().and_then(|d| d.get(key)) {
                Some(body) => {
                    self.publish_hit(key, "disk");
                    (Ok(Arc::new(body)), Outcome::DiskHit)
                }
                None => {
                    let result = compute().map(Arc::new);
                    if let Ok(body) = &result {
                        if let Some(disk) = &self.disk {
                            if let Err(e) = disk.put(key, body) {
                                logger::log(
                                    Level::Warn,
                                    0,
                                    "server",
                                    &format!(
                                        "result store put failed \
                                         (serving from memory): {e}"
                                    ),
                                );
                            }
                        }
                    }
                    (result, Outcome::Miss)
                }
            };
        if let Ok(body) = &result {
            self.store
                .lock()
                .unwrap()
                .insert(key.to_string(), Arc::clone(body));
        }
        {
            // publish before deregistering (see invariant above)
            let mut flights = self.flights.lock().unwrap();
            *flight.result.lock().unwrap() = Some(result.clone());
            flight.done.notify_all();
            flights.remove(key);
        }
        (result, outcome)
    }
}

/// The content address of one sweep request: SHA-256 over the canonical
/// serialization of the fully-resolved base campaign plus the ordered
/// scenario override list.
pub fn sweep_key(
    base: &crate::config::CampaignConfig,
    scenarios: &[crate::coordinator::ScenarioConfig],
) -> String {
    use crate::util::json::Json;
    let mut doc = Json::obj();
    doc.set("base", base.canonical_json());
    doc.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(|s| s.canonical_json()).collect()),
    );
    sha256::hex_digest(doc.to_string_compact().as_bytes())
}

/// The cached response body: content key + summary rows.  Everything in
/// it is a pure function of the resolved request, so byte-identical
/// requests get byte-identical bodies whether replayed, served from
/// either cache tier, or fetched through the async job API.
pub fn render_sweep_body(
    key: &str,
    rows: &[crate::sweep::ScenarioSummary],
) -> Vec<u8> {
    use crate::util::json::Json;
    let mut o = Json::obj();
    o.set("key", Json::from(key));
    o.set("rows", crate::experiments::sweep::to_json(rows));
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    body
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CampaignConfig;
    use crate::coordinator::ScenarioConfig;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    fn scratch() -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "icecloud-cache-unit-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn key(i: u8) -> String {
        format!("{i:064x}")
    }

    #[test]
    fn miss_then_hit() {
        let cache = ResultCache::new(1 << 20);
        let (r, o) = cache.get_or_compute("k", || Ok(b"body".to_vec()));
        assert_eq!(o, Outcome::Miss);
        assert_eq!(r.unwrap().as_slice(), b"body");
        let (r, o) = cache.get_or_compute("k", || {
            panic!("must not recompute a cached key")
        });
        assert_eq!(o, Outcome::Hit);
        assert_eq!(r.unwrap().as_slice(), b"body");
        assert_eq!(cache.get("k").unwrap().as_slice(), b"body");
        assert!(cache.get("other").is_none());
        assert!(!cache.has_disk());
        assert_eq!(cache.disk_stats(), (0, 0));
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = ResultCache::new(1 << 20);
        let (r, o) = cache.get_or_compute("k", || Err("boom".into()));
        assert_eq!(o, Outcome::Miss);
        assert!(r.is_err());
        assert!(cache.get("k").is_none());
        let (r, o) = cache.get_or_compute("k", || Ok(b"ok".to_vec()));
        assert_eq!(o, Outcome::Miss, "failed flights must retry");
        assert!(r.is_ok());
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let cache = ResultCache::new(10);
        cache.get_or_compute("a", || Ok(vec![0u8; 4])).0.unwrap();
        cache.get_or_compute("b", || Ok(vec![0u8; 4])).0.unwrap();
        // touch `a` so `b` is the LRU victim
        assert!(cache.get("a").is_some());
        cache.get_or_compute("c", || Ok(vec![0u8; 4])).0.unwrap();
        assert!(cache.get("b").is_none(), "LRU entry should be evicted");
        assert!(cache.get("a").is_some());
        assert!(cache.get("c").is_some());
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 2);
        assert_eq!(bytes, 8);
    }

    #[test]
    fn oversized_single_entry_is_kept() {
        let cache = ResultCache::new(4);
        cache.get_or_compute("big", || Ok(vec![0u8; 100])).0.unwrap();
        assert!(cache.get("big").is_some());
        // the next insert evicts it
        cache.get_or_compute("next", || Ok(vec![0u8; 2])).0.unwrap();
        assert!(cache.get("big").is_none());
        assert!(cache.get("next").is_some());
    }

    #[test]
    fn reinsert_same_key_replaces_bytes() {
        let cache = ResultCache::new(100);
        cache.get_or_compute("k", || Ok(vec![0u8; 10])).0.unwrap();
        // direct store insert models a re-publish after eviction races;
        // byte accounting must not double-count
        cache
            .store
            .lock()
            .unwrap()
            .insert("k".into(), Arc::new(vec![0u8; 20]));
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 1);
        assert_eq!(bytes, 20);
    }

    #[test]
    fn concurrent_identical_keys_compute_once() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let computations = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let barrier = Arc::new(std::sync::Barrier::new(8));
        for _ in 0..8 {
            let cache = Arc::clone(&cache);
            let computations = Arc::clone(&computations);
            let barrier = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                let (r, o) = cache.get_or_compute("same", || {
                    computations.fetch_add(1, Ordering::SeqCst);
                    // widen the race window
                    std::thread::sleep(
                        std::time::Duration::from_millis(30),
                    );
                    Ok(b"result".to_vec())
                });
                (r.unwrap().to_vec(), o)
            }));
        }
        let results: Vec<(Vec<u8>, Outcome)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(computations.load(Ordering::SeqCst), 1);
        let misses =
            results.iter().filter(|(_, o)| *o == Outcome::Miss).count();
        assert_eq!(misses, 1, "exactly one owner");
        for (body, _) in &results {
            assert_eq!(body.as_slice(), b"result");
        }
    }

    #[test]
    fn disk_tier_survives_memory_eviction() {
        let root = scratch();
        let disk = DiskStore::open(&root).unwrap();
        let cache = ResultCache::with_disk(10, Some(disk));
        assert!(cache.has_disk());
        let (ka, kb) = (key(1), key(2));
        cache.get_or_compute(&ka, || Ok(vec![7u8; 8])).0.unwrap();
        cache.get_or_compute(&kb, || Ok(vec![9u8; 8])).0.unwrap();
        // `ka` was evicted from memory by `kb`...
        assert!(cache.get(&ka).is_none());
        // ...but the disk tier still serves it, and promotes it back
        let (body, o) = cache.lookup(&ka).unwrap();
        assert_eq!(o, Outcome::DiskHit);
        assert_eq!(body.as_slice(), &[7u8; 8]);
        let (_, o) = cache.lookup(&ka).unwrap();
        assert_eq!(o, Outcome::Hit, "promoted entry is a memory hit");
        assert_eq!(cache.disk_stats(), (2, 16));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn owner_probes_disk_before_computing() {
        let root = scratch();
        let k = key(3);
        {
            let disk = DiskStore::open(&root).unwrap();
            let cache = ResultCache::with_disk(1 << 20, Some(disk));
            cache.get_or_compute(&k, || Ok(b"persisted".to_vec())).0.unwrap();
        }
        // a fresh cache over the same directory: no replay needed
        let disk = DiskStore::open(&root).unwrap();
        let cache = ResultCache::with_disk(1 << 20, Some(disk));
        let (r, o) = cache.get_or_compute(&k, || {
            panic!("disk-resident key must not recompute")
        });
        assert_eq!(o, Outcome::DiskHit);
        assert_eq!(r.unwrap().as_slice(), b"persisted");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn clear_memory_leaves_disk_intact() {
        let root = scratch();
        let disk = DiskStore::open(&root).unwrap();
        let cache = ResultCache::with_disk(1 << 20, Some(disk));
        let k = key(4);
        cache.get_or_compute(&k, || Ok(b"kept".to_vec())).0.unwrap();
        cache.clear_memory();
        assert_eq!(cache.stats(), (0, 0));
        let (body, o) = cache.lookup(&k).unwrap();
        assert_eq!(o, Outcome::DiskHit);
        assert_eq!(body.as_slice(), b"kept");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn disk_write_failure_degrades_to_memory_only() {
        // a non-hex key cannot be persisted; the request must still be
        // served from memory
        let root = scratch();
        let disk = DiskStore::open(&root).unwrap();
        let cache = ResultCache::with_disk(1 << 20, Some(disk));
        let (r, o) =
            cache.get_or_compute("not-a-key", || Ok(b"served".to_vec()));
        assert_eq!(o, Outcome::Miss);
        assert_eq!(r.unwrap().as_slice(), b"served");
        assert_eq!(cache.get("not-a-key").unwrap().as_slice(), b"served");
        assert_eq!(cache.disk_stats(), (0, 0));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn hits_publish_tier_events_when_a_bus_is_attached() {
        use super::super::events::{Delivery, EventBus};
        let root = scratch();
        let disk = DiskStore::open(&root).unwrap();
        let mut cache = ResultCache::with_disk(10, Some(disk));
        let bus = Arc::new(EventBus::new(64));
        cache.set_events(Arc::clone(&bus));
        let (ka, kb) = (key(5), key(6));
        // two computes: misses publish nothing
        cache.get_or_compute(&ka, || Ok(vec![1u8; 8])).0.unwrap();
        cache.get_or_compute(&kb, || Ok(vec![2u8; 8])).0.unwrap();
        assert_eq!(bus.published_total(), 0, "misses are not hits");
        // `ka` was evicted from memory: first lookup is a disk hit,
        // the promoted second one a memory hit
        let mut sub = bus.subscribe(None);
        cache.lookup(&ka).unwrap();
        cache.lookup(&ka).unwrap();
        let tiers: Vec<String> =
            match sub.next(std::time::Duration::from_secs(1)) {
                Delivery::Batch { events, .. } => events
                    .iter()
                    .map(|e| {
                        e.kind
                            .data()
                            .get("tier")
                            .and_then(|t| t.as_str())
                            .unwrap()
                            .to_string()
                    })
                    .collect(),
                other => panic!("{other:?}"),
            };
        assert_eq!(tiers, vec!["disk", "memory"]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sweep_key_is_stable_and_sensitive() {
        let base = CampaignConfig::default();
        let scenarios =
            vec![ScenarioConfig::named("a"), ScenarioConfig::named("b")];
        let k1 = sweep_key(&base, &scenarios);
        let k2 = sweep_key(&base, &scenarios);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 64);

        let mut other_base = CampaignConfig::default();
        other_base.seed += 1;
        assert_ne!(k1, sweep_key(&other_base, &scenarios));

        let mut tweaked = scenarios.clone();
        tweaked[1].budget_usd = Some(1.0);
        assert_ne!(k1, sweep_key(&base, &tweaked));

        let reordered =
            vec![ScenarioConfig::named("b"), ScenarioConfig::named("a")];
        assert_ne!(
            k1,
            sweep_key(&base, &reordered),
            "row order is part of the response, so it is part of the key"
        );
    }
}
