//! Content-addressed result cache with LRU eviction and single-flight
//! deduplication.
//!
//! Determinism is what makes this cache *correct*, not merely fast: a
//! resolved scenario request replays to a byte-identical summary every
//! time (`rust/tests/sweep_determinism.rs`), so a response may be stored
//! forever under the SHA-256 of its canonically-serialized request
//! (`CampaignConfig::canonical_json` + `ScenarioConfig::canonical_json`)
//! and served to any future identical request without revalidation.
//!
//! Single-flight: when N identical requests arrive concurrently, the
//! first becomes the *owner* and runs the replay; the other N-1 park on
//! a condvar and receive the owner's bytes.  The flights table is
//! checked under the same lock that re-checks the cache, and the owner
//! inserts into the cache *before* removing its flight entry, so there
//! is no window in which a second owner can start the same computation.

use crate::util::sha256;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Stored bodies are shared, not copied, between waiters and the cache.
pub type Body = Arc<Vec<u8>>;

/// What a lookup did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Served from the cache, or joined an in-flight computation.
    Hit,
    /// This call ran the computation.
    Miss,
}

struct Flight {
    result: Mutex<Option<Result<Body, String>>>,
    done: Condvar,
}

struct Store {
    map: HashMap<String, Body>,
    /// Keys from least- to most-recently used.  Linear touch/remove is
    /// fine at result-cache scale (entries are whole sweep responses).
    order: Vec<String>,
    bytes: usize,
    budget: usize,
}

impl Store {
    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    fn get(&mut self, key: &str) -> Option<Body> {
        let body = self.map.get(key).cloned()?;
        self.touch(key);
        Some(body)
    }

    fn insert(&mut self, key: String, body: Body) {
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= old.len();
            self.order.retain(|k| k != &key);
        }
        self.bytes += body.len();
        self.map.insert(key.clone(), body);
        self.order.push(key);
        // evict least-recently-used entries over budget, but always keep
        // the newest one so a fresh result stays addressable via
        // GET /results/<key> even if it alone exceeds the budget
        while self.bytes > self.budget && self.order.len() > 1 {
            let victim = self.order.remove(0);
            if let Some(old) = self.map.remove(&victim) {
                self.bytes -= old.len();
            }
        }
    }
}

/// The cache: bounded by a byte budget over the stored response bodies.
pub struct ResultCache {
    store: Mutex<Store>,
    flights: Mutex<HashMap<String, Arc<Flight>>>,
}

impl ResultCache {
    pub fn new(byte_budget: usize) -> Self {
        ResultCache {
            store: Mutex::new(Store {
                map: HashMap::new(),
                order: Vec::new(),
                bytes: 0,
                budget: byte_budget.max(1),
            }),
            flights: Mutex::new(HashMap::new()),
        }
    }

    /// Look up `key` without computing (the `GET /results/<key>` path).
    pub fn get(&self, key: &str) -> Option<Body> {
        self.store.lock().unwrap().get(key)
    }

    /// `(entries, bytes)` currently held.
    pub fn stats(&self) -> (usize, usize) {
        let s = self.store.lock().unwrap();
        (s.map.len(), s.bytes)
    }

    /// Return the cached body for `key`, or run `compute` exactly once
    /// across all concurrent callers with the same key.  Errors are not
    /// cached: every waiter of a failed flight gets the error, and the
    /// next request retries.
    pub fn get_or_compute(
        &self,
        key: &str,
        compute: impl FnOnce() -> Result<Vec<u8>, String>,
    ) -> (Result<Body, String>, Outcome) {
        let flight = {
            let mut flights = self.flights.lock().unwrap();
            // cache check under the flights lock: a finished owner holds
            // this lock to deregister, and it inserts into the cache
            // first, so "no cache entry and no flight" implies we must
            // become the owner
            if let Some(body) = self.store.lock().unwrap().get(key) {
                return (Ok(body), Outcome::Hit);
            }
            match flights.get(key).cloned() {
                Some(f) => {
                    drop(flights);
                    // join the in-flight computation
                    let mut slot = f.result.lock().unwrap();
                    while slot.is_none() {
                        slot = f.done.wait(slot).unwrap();
                    }
                    let result = slot.clone().unwrap();
                    return (result, Outcome::Hit);
                }
                None => {
                    let f = Arc::new(Flight {
                        result: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    flights.insert(key.to_string(), Arc::clone(&f));
                    f
                }
            }
        };

        // owner path: compute outside every lock
        let result = compute().map(Arc::new);
        if let Ok(body) = &result {
            self.store
                .lock()
                .unwrap()
                .insert(key.to_string(), Arc::clone(body));
        }
        {
            // publish before deregistering (see invariant above)
            let mut flights = self.flights.lock().unwrap();
            *flight.result.lock().unwrap() = Some(result.clone());
            flight.done.notify_all();
            flights.remove(key);
        }
        (result, Outcome::Miss)
    }
}

/// The content address of one sweep request: SHA-256 over the canonical
/// serialization of the fully-resolved base campaign plus the ordered
/// scenario override list.
pub fn sweep_key(
    base: &crate::config::CampaignConfig,
    scenarios: &[crate::coordinator::ScenarioConfig],
) -> String {
    use crate::util::json::Json;
    let mut doc = Json::obj();
    doc.set("base", base.canonical_json());
    doc.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(|s| s.canonical_json()).collect()),
    );
    sha256::hex_digest(doc.to_string_compact().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CampaignConfig;
    use crate::coordinator::ScenarioConfig;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn miss_then_hit() {
        let cache = ResultCache::new(1 << 20);
        let (r, o) =
            cache.get_or_compute("k", || Ok(b"body".to_vec()));
        assert_eq!(o, Outcome::Miss);
        assert_eq!(r.unwrap().as_slice(), b"body");
        let (r, o) = cache.get_or_compute("k", || {
            panic!("must not recompute a cached key")
        });
        assert_eq!(o, Outcome::Hit);
        assert_eq!(r.unwrap().as_slice(), b"body");
        assert_eq!(cache.get("k").unwrap().as_slice(), b"body");
        assert!(cache.get("other").is_none());
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = ResultCache::new(1 << 20);
        let (r, o) = cache.get_or_compute("k", || Err("boom".into()));
        assert_eq!(o, Outcome::Miss);
        assert!(r.is_err());
        assert!(cache.get("k").is_none());
        let (r, o) = cache.get_or_compute("k", || Ok(b"ok".to_vec()));
        assert_eq!(o, Outcome::Miss, "failed flights must retry");
        assert!(r.is_ok());
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let cache = ResultCache::new(10);
        cache.get_or_compute("a", || Ok(vec![0u8; 4])).0.unwrap();
        cache.get_or_compute("b", || Ok(vec![0u8; 4])).0.unwrap();
        // touch `a` so `b` is the LRU victim
        assert!(cache.get("a").is_some());
        cache.get_or_compute("c", || Ok(vec![0u8; 4])).0.unwrap();
        assert!(cache.get("b").is_none(), "LRU entry should be evicted");
        assert!(cache.get("a").is_some());
        assert!(cache.get("c").is_some());
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 2);
        assert_eq!(bytes, 8);
    }

    #[test]
    fn oversized_single_entry_is_kept() {
        let cache = ResultCache::new(4);
        cache.get_or_compute("big", || Ok(vec![0u8; 100])).0.unwrap();
        assert!(cache.get("big").is_some());
        // the next insert evicts it
        cache.get_or_compute("next", || Ok(vec![0u8; 2])).0.unwrap();
        assert!(cache.get("big").is_none());
        assert!(cache.get("next").is_some());
    }

    #[test]
    fn reinsert_same_key_replaces_bytes() {
        let cache = ResultCache::new(100);
        cache.get_or_compute("k", || Ok(vec![0u8; 10])).0.unwrap();
        // direct store insert models a re-publish after eviction races;
        // byte accounting must not double-count
        cache
            .store
            .lock()
            .unwrap()
            .insert("k".into(), Arc::new(vec![0u8; 20]));
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 1);
        assert_eq!(bytes, 20);
    }

    #[test]
    fn concurrent_identical_keys_compute_once() {
        let cache = Arc::new(ResultCache::new(1 << 20));
        let computations = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let barrier = Arc::new(std::sync::Barrier::new(8));
        for _ in 0..8 {
            let cache = Arc::clone(&cache);
            let computations = Arc::clone(&computations);
            let barrier = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                let (r, o) = cache.get_or_compute("same", || {
                    computations.fetch_add(1, Ordering::SeqCst);
                    // widen the race window
                    std::thread::sleep(
                        std::time::Duration::from_millis(30),
                    );
                    Ok(b"result".to_vec())
                });
                (r.unwrap().to_vec(), o)
            }));
        }
        let results: Vec<(Vec<u8>, Outcome)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(computations.load(Ordering::SeqCst), 1);
        let misses =
            results.iter().filter(|(_, o)| *o == Outcome::Miss).count();
        assert_eq!(misses, 1, "exactly one owner");
        for (body, _) in &results {
            assert_eq!(body.as_slice(), b"result");
        }
    }

    #[test]
    fn sweep_key_is_stable_and_sensitive() {
        let base = CampaignConfig::default();
        let scenarios =
            vec![ScenarioConfig::named("a"), ScenarioConfig::named("b")];
        let k1 = sweep_key(&base, &scenarios);
        let k2 = sweep_key(&base, &scenarios);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 64);

        let mut other_base = CampaignConfig::default();
        other_base.seed += 1;
        assert_ne!(k1, sweep_key(&other_base, &scenarios));

        let mut tweaked = scenarios.clone();
        tweaked[1].budget_usd = Some(1.0);
        assert_ne!(k1, sweep_key(&base, &tweaked));

        let reordered =
            vec![ScenarioConfig::named("b"), ScenarioConfig::named("a")];
        assert_ne!(
            k1,
            sweep_key(&base, &reordered),
            "row order is part of the response, so it is part of the key"
        );
    }
}
