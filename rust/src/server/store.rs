//! Content-addressed persistent result store (the disk tier).
//!
//! The memory LRU (`cache`) makes identical-request traffic cheap, but
//! it evaporates on restart and its byte budget bounds total capacity.
//! This module is the durable tier underneath it: one file per cached
//! sweep response, keyed by the same canonical-JSON SHA-256 the memory
//! tier uses, surviving restarts the way IceCube's XRootD Origins keep
//! photon tables across site reboots (Schultz et al., PNRP 2023).
//!
//! Layout (all under one root directory):
//!
//! ```text
//! <root>/entries/<key>        one verified entry per 64-hex key
//! <root>/entries/.tmp.<pid>.<seq>   in-flight writes (crash debris)
//! <root>/quarantine/<key>     entries that failed verification
//! ```
//!
//! Entry format: a single header line
//! `icecloud-store/1 <key> <sha256(body)> <body-len>\n` followed by the
//! raw body bytes.  The header binds the *filename* (a renamed file
//! serves nothing) and the *content* (bit rot and truncation are
//! detected), both checked on startup scan and again on every read.
//!
//! Crash-safety argument (DESIGN.md §14): writes go to a `.tmp.` file,
//! are fsync'd, and enter the namespace only via an atomic rename (the
//! directory is fsync'd best-effort afterwards).  A crash therefore
//! leaves either (a) no entry, (b) a complete verified entry, or (c)
//! `.tmp.` debris — which `open` deletes.  Nothing under `entries/`
//! is ever served without passing verification; anything that fails is
//! moved to `quarantine/` for post-mortem (unique-suffixed so repeat
//! failures never overwrite earlier evidence; deleted only as a last
//! resort when the move itself fails, so a bad entry can never be
//! served), and a corrupt entry can never panic the server.

use super::events::{EventBus, EventKind};
use crate::util::sha256;
use std::collections::HashMap;
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Entry header magic; bump on any layout change.
const MAGIC: &str = "icecloud-store/1";

/// A persistent content-addressed store rooted at one directory.
pub struct DiskStore {
    entries_dir: PathBuf,
    quarantine_dir: PathBuf,
    /// key -> body length, rebuilt by scanning on open.
    index: Mutex<HashMap<String, u64>>,
    tmp_seq: AtomicU64,
    /// Ops bus for `store.quarantine` events; `None` outside a server.
    events: Option<Arc<EventBus>>,
}

/// A key is the lowercase-hex SHA-256 the cache derives from the
/// resolved request; nothing else may name an entry file.
fn valid_key(key: &str) -> bool {
    key.len() == 64
        && key
            .bytes()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

/// Read an entry file and verify header magic, key binding, length and
/// body digest.  Returns the body bytes.
fn read_verified(path: &Path, key: &str) -> Result<Vec<u8>, String> {
    let raw =
        fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let nl = raw
        .iter()
        .position(|&b| b == b'\n')
        .ok_or("no header line")?;
    let header = std::str::from_utf8(&raw[..nl])
        .map_err(|_| "non-UTF-8 header".to_string())?;
    let mut parts = header.split(' ');
    let (magic, hkey, hsha, hlen) = match (
        parts.next(),
        parts.next(),
        parts.next(),
        parts.next(),
        parts.next(),
    ) {
        (Some(m), Some(k), Some(s), Some(l), None) => (m, k, s, l),
        _ => return Err("malformed header".into()),
    };
    if magic != MAGIC {
        return Err(format!("bad magic '{magic}'"));
    }
    if hkey != key {
        return Err(format!("header key '{hkey}' does not match filename"));
    }
    let body = &raw[nl + 1..];
    let len: usize = hlen.parse().map_err(|_| format!("bad length '{hlen}'"))?;
    if body.len() != len {
        return Err(format!("body is {} bytes, header says {len}", body.len()));
    }
    if sha256::hex_digest(body) != hsha {
        return Err("body digest mismatch".into());
    }
    Ok(body.to_vec())
}

/// Write header + body to `path` and flush it to the platter; the
/// caller renames it into the namespace afterwards.
fn write_entry(
    path: &Path,
    key: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(
        format!(
            "{MAGIC} {key} {} {}\n",
            sha256::hex_digest(body),
            body.len()
        )
        .as_bytes(),
    )?;
    f.write_all(body)?;
    f.sync_all()
}

impl DiskStore {
    /// Open (creating if needed) the store at `root`, rebuilding the
    /// index by scanning: `.tmp.` debris from a crashed writer is
    /// deleted, every entry is verified, and anything that fails —
    /// truncated, bit-rotted, renamed, or just not ours — is moved to
    /// `quarantine/` instead of being served or trusted.
    pub fn open(root: impl Into<PathBuf>) -> Result<DiskStore, String> {
        let root = root.into();
        let entries_dir = root.join("entries");
        let quarantine_dir = root.join("quarantine");
        for dir in [&entries_dir, &quarantine_dir] {
            fs::create_dir_all(dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
        }
        let store = DiskStore {
            entries_dir,
            quarantine_dir,
            index: Mutex::new(HashMap::new()),
            tmp_seq: AtomicU64::new(0),
            events: None,
        };
        let listing = fs::read_dir(&store.entries_dir)
            .map_err(|e| format!("scan {}: {e}", store.entries_dir.display()))?;
        for dirent in listing {
            let dirent = match dirent {
                Ok(d) => d,
                Err(_) => continue,
            };
            let path = dirent.path();
            if !path.is_file() {
                continue;
            }
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n.to_string(),
                None => {
                    store.quarantine_path(
                        &path,
                        "non-unicode",
                        "non-unicode filename",
                    );
                    continue;
                }
            };
            if name.starts_with(".tmp.") {
                // a writer died between create and rename; the rename
                // never happened, so this was never an entry
                let _ = fs::remove_file(&path);
                continue;
            }
            if !valid_key(&name) {
                store.quarantine_path(
                    &path,
                    &name,
                    "foreign file (not a store key)",
                );
                continue;
            }
            match read_verified(&path, &name) {
                Ok(body) => {
                    store
                        .index
                        .lock()
                        .unwrap()
                        .insert(name, body.len() as u64);
                }
                Err(e) => store.quarantine_path(&path, &name, &e),
            }
        }
        Ok(store)
    }

    /// Attach the ops bus (called once by `Server::bind` before the
    /// store moves into the cache).
    pub fn set_events(&mut self, events: Arc<EventBus>) {
        self.events = Some(events);
    }

    /// Move a failed entry aside for post-mortem.  Repeat failures of
    /// one key get unique suffixes so earlier evidence is preserved.
    fn quarantine_path(&self, path: &Path, name: &str, reason: &str) {
        let base = if name.is_empty() { "unnamed" } else { name };
        let mut dest = self.quarantine_dir.join(base);
        let mut n = 1u32;
        while dest.exists() {
            dest = self.quarantine_dir.join(format!("{base}.{n}"));
            n += 1;
        }
        if fs::rename(path, &dest).is_err() {
            // cross-device or permission trouble: last resort is to
            // remove the file so it can never be served
            let _ = fs::remove_file(path);
        }
        if let Some(bus) = &self.events {
            bus.publish(EventKind::StoreQuarantine {
                name: base.to_string(),
                reason: reason.to_string(),
            });
        }
    }

    /// `(entries, body bytes)` currently indexed.
    pub fn stats(&self) -> (usize, u64) {
        let index = self.index.lock().unwrap();
        (index.len(), index.values().sum())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.index.lock().unwrap().contains_key(key)
    }

    /// Files sitting in quarantine (tests and post-mortems).
    pub fn quarantined(&self) -> usize {
        fs::read_dir(&self.quarantine_dir)
            .map(|d| d.filter_map(|e| e.ok()).count())
            .unwrap_or(0)
    }

    /// Fetch and re-verify one entry.  A file that no longer verifies
    /// (rot since the open scan) is quarantined and reported as a miss
    /// — never served, never a panic.
    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        if !self.contains(key) {
            return None;
        }
        let path = self.entries_dir.join(key);
        match read_verified(&path, key) {
            Ok(body) => Some(body),
            Err(e) => {
                self.index.lock().unwrap().remove(key);
                self.quarantine_path(&path, key, &e);
                None
            }
        }
    }

    /// Persist one entry: write-to-temp, fsync, atomic rename into the
    /// namespace, fsync the directory (best-effort).  Re-putting an
    /// existing key is a no-op — the store is content-addressed, so one
    /// key names one body forever.
    pub fn put(&self, key: &str, body: &[u8]) -> Result<(), String> {
        if !valid_key(key) {
            return Err(format!("invalid store key '{key}'"));
        }
        if self.contains(key) {
            return Ok(());
        }
        let tmp = self.entries_dir.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        if let Err(e) = write_entry(&tmp, key, body) {
            let _ = fs::remove_file(&tmp);
            return Err(format!("write {}: {e}", tmp.display()));
        }
        let dest = self.entries_dir.join(key);
        if let Err(e) = fs::rename(&tmp, &dest) {
            let _ = fs::remove_file(&tmp);
            return Err(format!("rename into {}: {e}", dest.display()));
        }
        // entry durability needs the directory entry on disk too; not
        // every platform lets us open a directory, so best-effort
        if let Ok(dir) = File::open(&self.entries_dir) {
            let _ = dir.sync_all();
        }
        self.index
            .lock()
            .unwrap()
            .insert(key.to_string(), body.len() as u64);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    /// A unique scratch root per test (std-only; no tempfile crate).
    fn scratch() -> PathBuf {
        std::env::temp_dir().join(format!(
            "icecloud-store-unit-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn key(i: u8) -> String {
        format!("{i:064x}")
    }

    #[test]
    fn put_get_roundtrip_and_stats() {
        let root = scratch();
        let store = DiskStore::open(&root).unwrap();
        assert_eq!(store.stats(), (0, 0));
        store.put(&key(1), b"hello world").unwrap();
        assert!(store.contains(&key(1)));
        assert_eq!(store.get(&key(1)).unwrap(), b"hello world");
        assert_eq!(store.stats(), (1, 11));
        assert!(store.get(&key(2)).is_none());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_rebuilds_index() {
        let root = scratch();
        {
            let store = DiskStore::open(&root).unwrap();
            store.put(&key(1), b"aaa").unwrap();
            store.put(&key(2), b"bbbb").unwrap();
        }
        let store = DiskStore::open(&root).unwrap();
        assert_eq!(store.stats(), (2, 7));
        assert_eq!(store.get(&key(2)).unwrap(), b"bbbb");
        assert_eq!(store.quarantined(), 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn repeated_put_is_idempotent() {
        let root = scratch();
        let store = DiskStore::open(&root).unwrap();
        store.put(&key(3), b"body").unwrap();
        store.put(&key(3), b"body").unwrap();
        assert_eq!(store.stats(), (1, 4));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn invalid_keys_rejected() {
        let root = scratch();
        let store = DiskStore::open(&root).unwrap();
        let nonhex = "Z".repeat(64);
        let short_hex = "a".repeat(63);
        for bad in ["", "short", nonhex.as_str(), short_hex.as_str()] {
            assert!(store.put(bad, b"x").is_err(), "key '{bad}'");
        }
        // uppercase hex is not canonical either
        assert!(store.put(&"A".repeat(64), b"x").is_err());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn truncated_entry_quarantined_on_open() {
        let root = scratch();
        {
            let store = DiskStore::open(&root).unwrap();
            store.put(&key(4), b"a body that will be truncated").unwrap();
        }
        let path = root.join("entries").join(key(4));
        let raw = fs::read(&path).unwrap();
        fs::write(&path, &raw[..raw.len() - 5]).unwrap();
        let store = DiskStore::open(&root).unwrap();
        assert_eq!(store.stats(), (0, 0));
        assert!(store.get(&key(4)).is_none());
        assert_eq!(store.quarantined(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn bitrot_after_open_quarantined_on_get() {
        let root = scratch();
        let store = DiskStore::open(&root).unwrap();
        store.put(&key(5), b"pristine bytes").unwrap();
        let path = root.join("entries").join(key(5));
        let mut raw = fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff;
        fs::write(&path, &raw).unwrap();
        assert!(store.get(&key(5)).is_none(), "rotted entry must not serve");
        assert!(!store.contains(&key(5)));
        assert_eq!(store.quarantined(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn renamed_entry_does_not_serve_under_wrong_key() {
        let root = scratch();
        {
            let store = DiskStore::open(&root).unwrap();
            store.put(&key(6), b"bound to key 6").unwrap();
            fs::rename(
                root.join("entries").join(key(6)),
                root.join("entries").join(key(7)),
            )
            .unwrap();
        }
        let store = DiskStore::open(&root).unwrap();
        assert!(store.get(&key(7)).is_none());
        assert_eq!(store.quarantined(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn tmp_debris_cleaned_on_open() {
        let root = scratch();
        {
            let store = DiskStore::open(&root).unwrap();
            store.put(&key(8), b"real").unwrap();
        }
        let debris = root.join("entries").join(".tmp.999.0");
        fs::write(&debris, b"half-written").unwrap();
        let store = DiskStore::open(&root).unwrap();
        assert!(!debris.exists(), "crash debris must be deleted");
        assert_eq!(store.stats(), (1, 4));
        assert_eq!(store.quarantined(), 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn quarantine_publishes_an_event_when_a_bus_is_attached() {
        use super::super::events::EventBus;
        let root = scratch();
        let mut store = DiskStore::open(&root).unwrap();
        let bus = Arc::new(EventBus::new(16));
        store.set_events(Arc::clone(&bus));
        store.put(&key(10), b"pristine").unwrap();
        let path = root.join("entries").join(key(10));
        let mut raw = fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff;
        fs::write(&path, &raw).unwrap();
        assert!(store.get(&key(10)).is_none());
        assert_eq!(bus.published_total(), 1, "rot must announce itself");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn foreign_files_quarantined_not_served() {
        let root = scratch();
        {
            let store = DiskStore::open(&root).unwrap();
            store.put(&key(9), b"mine").unwrap();
        }
        fs::write(root.join("entries").join("README.txt"), b"not ours")
            .unwrap();
        let store = DiskStore::open(&root).unwrap();
        assert_eq!(store.stats(), (1, 4));
        assert_eq!(store.quarantined(), 1);
        let _ = fs::remove_dir_all(&root);
    }
}
