//! Request routing: the five-endpoint decision-support API.
//!
//! | route                | what it answers                                  |
//! |----------------------|--------------------------------------------------|
//! | `GET /healthz`       | liveness                                         |
//! | `GET /matrix`        | the built-in what-if matrix, as override sets    |
//! | `POST /sweep`        | replay a scenario spec (TOML or JSON body)       |
//! | `GET /results/<key>` | re-fetch a cached sweep response by content key  |
//! | `GET /metrics`       | counters + latency percentiles (text exposition) |
//!
//! `POST /sweep` is where the subsystem earns its keep: resolve the
//! spec against the server's base campaign, derive the content address
//! (`cache::sweep_key`), and either serve bytes straight from the cache
//! or run the matrix on the shared replay pool — with single-flight
//! collapsing concurrent identical requests into one computation.

use super::cache::{sweep_key, Outcome, ResultCache};
use super::http::{Request, Response};
use super::jobs::ReplayPool;
use super::metrics::Metrics;
use crate::config::CampaignConfig;
use crate::coordinator::ScenarioConfig;
use crate::experiments;
use crate::sweep;
use crate::util::json::{self, Json};

/// Most scenarios one request may ask for.
pub const MAX_SCENARIOS_PER_REQUEST: usize = 64;
/// Longest replay one request may ask for (sim-seconds).
pub const MAX_DURATION_S: u64 = 60 * 86_400;
/// Largest ramp target / on-prem slot count one request may ask for.
pub const MAX_FLEET: u32 = 100_000;

/// Everything the request handlers share.
pub struct AppState {
    pub base: CampaignConfig,
    pub cache: ResultCache,
    pub pool: ReplayPool,
    pub metrics: Metrics,
}

/// Dispatch one parsed request to its handler.
pub fn route(state: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            Response::json(200, b"{\"status\":\"ok\"}\n".to_vec())
        }
        ("GET", "/matrix") => matrix(),
        ("POST", "/sweep") => sweep_post(state, req),
        ("GET", "/metrics") => metrics(state),
        ("GET", path) if path.starts_with("/results/") => {
            results(state, &path["/results/".len()..])
        }
        // known paths, wrong method
        (_, "/healthz" | "/matrix" | "/metrics") => {
            Response::error(405, "method not allowed")
                .with_header("Allow", "GET")
        }
        (_, "/sweep") => Response::error(405, "method not allowed")
            .with_header("Allow", "POST"),
        (_, path) if path.starts_with("/results/") => {
            Response::error(405, "method not allowed")
                .with_header("Allow", "GET")
        }
        _ => Response::error(404, "no such route"),
    }
}

fn matrix() -> Response {
    let scenarios = sweep::builtin_matrix();
    let mut o = Json::obj();
    o.set("count", Json::from(scenarios.len()));
    o.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(|s| s.canonical_json()).collect()),
    );
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    Response::json(200, body)
}

fn metrics(state: &AppState) -> Response {
    let (entries, bytes) = state.cache.stats();
    Response::text(
        200,
        state
            .metrics
            .render(state.pool.queue_depth(), entries, bytes),
    )
}

fn results(state: &AppState, key: &str) -> Response {
    match state.cache.get(key) {
        Some(body) => Response::json_shared(200, body)
            .with_header("X-Cache", "hit"),
        None => Response::error(404, "no cached result under this key"),
    }
}

/// Parse the request body into `(resolved base, scenarios)`.  JSON and
/// TOML share the spec shape; the decode path is chosen by
/// `Content-Type`, falling back to sniffing the first byte.
fn parse_sweep_body(
    base: &CampaignConfig,
    req: &Request,
) -> Result<(CampaignConfig, Vec<ScenarioConfig>), String> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| "body is not valid UTF-8".to_string())?;
    if text.trim().is_empty() {
        return Err("empty body; send a scenario spec (TOML or JSON)"
            .to_string());
    }
    let content_type = req.header("content-type").unwrap_or("");
    let looks_json = content_type.contains("json")
        || (!content_type.contains("toml")
            && text.trim_start().starts_with('{'));
    let mut resolved = base.clone();
    let scenarios = if looks_json {
        let doc = json::parse(text).map_err(|e| e.to_string())?;
        sweep::parse_spec_json(&doc, &mut resolved)?
    } else {
        sweep::matrix::parse_spec(text, &mut resolved)?
    };
    Ok((resolved, scenarios))
}

/// Refuse requests that would tie up the replay pool for minutes; the
/// service replays bounded what-if slices, not open-ended simulations.
fn validate_limits(
    base: &CampaignConfig,
    scenarios: &[ScenarioConfig],
) -> Result<(), String> {
    if scenarios.len() > MAX_SCENARIOS_PER_REQUEST {
        return Err(format!(
            "{} scenarios exceeds the per-request limit of {}",
            scenarios.len(),
            MAX_SCENARIOS_PER_REQUEST
        ));
    }
    for s in scenarios {
        let duration = s.duration_s.unwrap_or(base.duration_s);
        if duration > MAX_DURATION_S {
            return Err(format!(
                "scenario '{}' asks for {duration} sim-seconds; limit {}",
                s.name, MAX_DURATION_S
            ));
        }
        let ramp = s.ramp.as_ref().unwrap_or(&base.ramp);
        if ramp.iter().any(|step| step.target > MAX_FLEET) {
            return Err(format!(
                "scenario '{}' ramp target exceeds {MAX_FLEET} GPUs",
                s.name
            ));
        }
        if s.onprem_slots.unwrap_or(base.onprem.slots) > MAX_FLEET {
            return Err(format!(
                "scenario '{}' on-prem slots exceed {MAX_FLEET}",
                s.name
            ));
        }
    }
    Ok(())
}

fn sweep_post(state: &AppState, req: &Request) -> Response {
    let (resolved, scenarios) = match parse_sweep_body(&state.base, req) {
        Ok(parsed) => parsed,
        Err(e) => return Response::error(400, &e),
    };
    if let Err(e) = validate_limits(&resolved, &scenarios) {
        return Response::error(400, &e);
    }

    let key = sweep_key(&resolved, &scenarios);
    let replays = scenarios.len();
    let (result, outcome) = state.cache.get_or_compute(&key, || {
        let rows = state.pool.run_matrix(&resolved, &scenarios)?;
        // count only completed computations, after the replay succeeds
        state.metrics.on_sweep_computed(replays);
        Ok(render_sweep_body(&key, &rows))
    });
    // accounting contract: every Miss (attempted computation) counts as
    // a miss whether or not it succeeded; a Hit counts only when it
    // delivered bytes (a waiter surfacing the owner's error served
    // nothing)
    if outcome == Outcome::Miss {
        state.metrics.on_cache_miss();
    }
    match (result, outcome) {
        (Ok(body), Outcome::Hit) => {
            state.metrics.on_cache_hit();
            Response::json_shared(200, body).with_header("X-Cache", "hit")
        }
        (Ok(body), Outcome::Miss) => {
            Response::json_shared(200, body)
                .with_header("X-Cache", "miss")
        }
        (Err(e), _) => Response::error(500, &e),
    }
}

/// The cached response body: content key + summary rows.  Everything in
/// it is a pure function of the resolved request, so byte-identical
/// requests get byte-identical bodies whether replayed or cached.
fn render_sweep_body(
    key: &str,
    rows: &[sweep::ScenarioSummary],
) -> Vec<u8> {
    let mut o = Json::obj();
    o.set("key", Json::from(key));
    o.set("rows", experiments::sweep::to_json(rows));
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    body
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};

    fn tiny_state() -> AppState {
        let mut base = CampaignConfig::default();
        base.duration_s = 2 * HOUR;
        base.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
        base.outage = None;
        base.onprem.slots = 8;
        base.generator.min_backlog = 30;
        AppState {
            base,
            cache: ResultCache::new(1 << 20),
            pool: ReplayPool::new(2),
            metrics: Metrics::new(),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            http11: true,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    fn post(path: &str, content_type: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            http11: true,
            headers: vec![(
                "Content-Type".into(),
                content_type.into(),
            )],
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn healthz_and_matrix_and_404_405() {
        let state = tiny_state();
        assert_eq!(route(&state, &get("/healthz")).status, 200);
        let m = route(&state, &get("/matrix"));
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body.to_vec()).unwrap();
        assert!(text.contains("baseline"), "{text}");
        assert_eq!(route(&state, &get("/nope")).status, 404);
        assert_eq!(route(&state, &get("/sweep")).status, 405);
        let r = Request { method: "DELETE".into(), ..get("/healthz") };
        assert_eq!(route(&state, &r).status, 405);
    }

    #[test]
    fn sweep_toml_roundtrip_and_results_lookup() {
        let state = tiny_state();
        let spec = "[scenario.a]\n\n[scenario.b]\nseed = 9\n";
        let first =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(
            first.status,
            200,
            "{}",
            String::from_utf8_lossy(&first.body)
        );
        let doc = json::parse(
            std::str::from_utf8(&first.body).unwrap().trim(),
        )
        .unwrap();
        let key = doc.get("key").unwrap().as_str().unwrap().to_string();
        assert_eq!(key.len(), 64);
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("name").unwrap().as_str(),
            Some("a")
        );

        // byte-identical on the second, cached request
        let second =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(second.body, first.body);
        assert_eq!(second.header_value("X-Cache"), Some("hit"));

        // and via the content address
        let by_key =
            route(&state, &get(&format!("/results/{key}")));
        assert_eq!(by_key.status, 200);
        assert_eq!(by_key.body, first.body);
        assert_eq!(
            route(&state, &get("/results/deadbeef")).status,
            404
        );
        assert_eq!(state.metrics.sweep_computation_count(), 1);
        assert_eq!(state.metrics.cache_hit_count(), 1);
    }

    #[test]
    fn sweep_json_body_equals_toml_body() {
        let state = tiny_state();
        let toml_resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.x]\nbudget_usd = 40.0\n",
            ),
        );
        let json_resp = route(
            &state,
            &post(
                "/sweep",
                "application/json",
                r#"{"scenario": {"x": {"budget_usd": 40.0}}}"#,
            ),
        );
        assert_eq!(toml_resp.status, 200);
        assert_eq!(
            toml_resp.body, json_resp.body,
            "same spec, either encoding, same content address and bytes"
        );
    }

    #[test]
    fn malformed_bodies_rejected() {
        let state = tiny_state();
        for (ct, body) in [
            ("application/toml", "not toml = = ="),
            ("application/toml", "[scenario.a]\nbad_key = 1"),
            ("application/json", "{\"scenario\": "),
            ("application/json", "{}"),
            ("application/toml", ""),
        ] {
            let resp = route(&state, &post("/sweep", ct, body));
            assert_eq!(resp.status, 400, "body {body:?} must be rejected");
        }
        // invalid UTF-8
        let mut req = post("/sweep", "application/toml", "");
        req.body = vec![0xff, 0xfe, 0x00];
        assert_eq!(route(&state, &req).status, 400);
        assert_eq!(state.metrics.sweep_computation_count(), 0);
    }

    #[test]
    fn oversized_requests_rejected() {
        let state = tiny_state();
        let mut many = String::new();
        for i in 0..=MAX_SCENARIOS_PER_REQUEST {
            many.push_str(&format!("[scenario.s{i:03}]\n"));
        }
        let resp =
            route(&state, &post("/sweep", "application/toml", &many));
        assert_eq!(resp.status, 400);

        let resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.long]\nduration_days = 365.0\n",
            ),
        );
        assert_eq!(resp.status, 400);

        let resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.big]\nramp_targets = [2000000]\n",
            ),
        );
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn metrics_expose_counters() {
        let state = tiny_state();
        route(&state, &post("/sweep", "", "[scenario.a]\n"));
        let resp = route(&state, &get("/metrics"));
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body.to_vec()).unwrap();
        assert!(
            text.contains("icecloud_sweep_computations_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_result_cache_entries 1"),
            "{text}"
        );
    }

    impl Response {
        fn header_value(&self, name: &str) -> Option<&str> {
            self.extra_headers
                .iter()
                .find(|(k, _)| k.eq_ignore_ascii_case(name))
                .map(|(_, v)| v.as_str())
        }
    }
}
