//! Request routing: the decision-support API.
//!
//! | route                     | what it answers                               |
//! |---------------------------|-----------------------------------------------|
//! | `GET /healthz`            | liveness                                      |
//! | `GET /matrix`             | the built-in what-if matrix, as override sets |
//! | `POST /sweep`             | replay a scenario spec (TOML or JSON body)    |
//! | `POST /sweep?mode=async`  | `202 {job_id}` — queue the sweep, poll later  |
//! | `GET /jobs`               | every tracked async job, in submission order  |
//! | `GET /jobs/<id>`          | one job: state, queue position, timings       |
//! | `GET /results/<key>`      | re-fetch a cached sweep response by key       |
//! | `GET /metrics`            | counters + latency percentiles (text)         |
//! | `POST /fleet/register`    | announce a worker (id, slots)                 |
//! | `POST /fleet/lease`       | pull one scenario unit under a lease          |
//! | `POST /fleet/heartbeat`   | extend a lease's deadline                     |
//! | `POST /fleet/complete`    | stream a finished unit's row back             |
//! | `GET /events`             | live SSE stream of typed ops events           |
//! | `GET /timeseries`         | index of the server's wall-clock series       |
//! | `GET /timeseries/<name>`  | one series, downsampled                       |
//! | `GET /dash`               | the SVG burn-down board (`/dash.json` twin)   |
//!
//! Every route above is also mounted under `/v1/...` (the documented
//! spelling); the unprefixed paths are permanent aliases.  All
//! responses carry `X-Api-Version: 1`, and every error body is the one
//! canonical shape `{"error": <code>, "detail": <msg>}` (plus
//! `retry_after` on 429s) from `http::error_response` — the full
//! normative route table lives in DESIGN.md §19.
//!
//! `POST /sweep` is where the subsystem earns its keep: resolve the
//! spec against the server's base campaign, derive the content address
//! (`cache::sweep_key`), and either serve bytes straight from a cache
//! tier (memory, then disk) or run the matrix on the shared replay
//! pool — with single-flight collapsing concurrent identical requests
//! into one computation.  The async mode routes the same resolved spec
//! through the bounded job queue instead of blocking the connection;
//! a full queue sheds with `429 + Retry-After` (DESIGN.md §14).

use super::cache::{render_sweep_body, sweep_key, Outcome};
use super::fleet::CompleteOutcome;
use super::http::{
    error_response, error_response_after, Request, Response,
};
use super::jobs::{Admission, JobSpec};
use super::metrics::Gauges;
use super::ops::OpsMonitor;
use crate::config::CampaignConfig;
use crate::coordinator::ScenarioConfig;
use crate::sweep;
use crate::util::json::{self, Json};

/// Most scenarios one request may ask for.  `[grid]` sections expand
/// *before* this check (in `sweep::parse_spec_json_with_limit`), so a
/// grid counts by its cartesian product, not by its axis count.  This
/// limit is server-enforced: `parse_sweep_body` threads it into grid
/// expansion, where it bounds the O(axes) axis-length product before
/// any scenario is materialized and — unlike the spec-overridable
/// `[grid] max_scenarios` knob — cannot be raised by the request body
/// (`sweep::grid` additionally hard-caps `max_scenarios` itself).
pub const MAX_SCENARIOS_PER_REQUEST: usize = 64;
/// Longest replay one request may ask for (sim-seconds).
pub const MAX_DURATION_S: u64 = 60 * 86_400;
/// Largest ramp target / on-prem slot count one request may ask for.
pub const MAX_FLEET: u32 = 100_000;

/// Everything the request handlers share.  Cache, pool and metrics are
/// `Arc`-shared with the job-runner threads (`jobs::JobTable`).
pub struct AppState {
    pub base: CampaignConfig,
    pub cache: std::sync::Arc<super::cache::ResultCache>,
    pub pool: std::sync::Arc<super::jobs::ReplayPool>,
    pub fleet: std::sync::Arc<super::fleet::FleetTable>,
    pub metrics: std::sync::Arc<super::metrics::Metrics>,
    pub jobs: super::jobs::JobTable,
    pub events: std::sync::Arc<super::events::EventBus>,
    pub ops: std::sync::Arc<OpsMonitor>,
}

/// Where one request goes: almost everything is an ordinary
/// `Content-Length`-framed [`Response`], but `GET /events` hands the
/// connection over to the SSE writer in `server::mod`, which owns the
/// socket from then on.
pub enum Routed {
    Response(Response),
    /// Stream events over SSE; `resume` carries the parsed
    /// `Last-Event-ID`, so a reconnecting client replays only what it
    /// missed.
    Events { resume: Option<u64> },
}

/// Route one request, separating the SSE hand-off from plain
/// responses.  The query string is split off before matching, so
/// `/healthz?x=1` still routes; only `POST /sweep` interprets it.
///
/// The whole surface is mounted twice: versioned under `/v1/...` (the
/// documented spelling, DESIGN.md §19) and at the legacy unprefixed
/// paths, which stay as aliases of the same handlers.  Every response
/// carries `X-Api-Version: 1` either way, so clients can discover the
/// contract from any reply.
pub fn dispatch(state: &AppState, req: &Request) -> Routed {
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (req.path.as_str(), None),
    };
    // `/v1/healthz` → `/healthz`; bare `/v1` and non-boundary matches
    // like `/v1events` are *not* the versioned surface and fall through
    // to the 404 arm
    let path = match path.strip_prefix("/v1") {
        Some(rest) if rest.starts_with('/') => rest,
        _ => path,
    };
    if path == "/events" {
        return match events_route(req, query) {
            Routed::Response(r) => {
                Routed::Response(r.with_header("X-Api-Version", "1"))
            }
            // the SSE writer stamps the header on its hand-written head
            stream => stream,
        };
    }
    Routed::Response(
        route_plain(state, req, path, query)
            .with_header("X-Api-Version", "1"),
    )
}

/// [`dispatch`] flattened for callers that cannot stream (unit tests):
/// the SSE case becomes an empty `text/event-stream` response.
pub fn route(state: &AppState, req: &Request) -> Response {
    match dispatch(state, req) {
        Routed::Response(resp) => resp,
        Routed::Events { .. } => Response {
            status: 200,
            content_type: "text/event-stream",
            body: std::sync::Arc::new(Vec::new()),
            extra_headers: Vec::new(),
        }
        .with_header("X-Api-Version", "1"),
    }
}

/// `GET /events`: validate strictly *before* the connection commits to
/// streaming — after the SSE head is written there is no way to signal
/// an error in-band.
fn events_route(req: &Request, query: Option<&str>) -> Routed {
    if req.method != "GET" {
        return Routed::Response(
            error_response(405, "method not allowed")
                .with_header("Allow", "GET"),
        );
    }
    if query.is_some() {
        return Routed::Response(error_response(
            400,
            "/events takes no query parameters; \
             resume with the Last-Event-ID header",
        ));
    }
    match req.header("last-event-id") {
        None => Routed::Events { resume: None },
        Some(v) => match v.trim().parse::<u64>() {
            Ok(seq) => Routed::Events { resume: Some(seq) },
            Err(_) => Routed::Response(error_response(
                400,
                "Last-Event-ID must be a decimal event sequence number",
            )),
        },
    }
}

fn route_plain(
    state: &AppState,
    req: &Request,
    path: &str,
    query: Option<&str>,
) -> Response {
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            Response::json(200, b"{\"status\":\"ok\"}\n".to_vec())
        }
        ("GET", "/matrix") => matrix(),
        ("POST", "/sweep") => sweep_post(state, req, query),
        ("GET", "/metrics") => metrics(state),
        ("GET", "/jobs") => jobs_list(state),
        ("GET", path) if path.starts_with("/jobs/") => {
            job_detail(state, &path["/jobs/".len()..])
        }
        ("GET", path) if path.starts_with("/results/") => {
            results(state, &path["/results/".len()..])
        }
        // the ops read plane is strict the same way the fleet protocol
        // is: a query string is a caller bug, not a silent no-op
        ("GET", p @ ("/timeseries" | "/dash" | "/dash.json")) => {
            if query.is_some() {
                error_response(
                    400,
                    "ops endpoints take no query parameters",
                )
            } else {
                match p {
                    "/timeseries" => timeseries_index(state),
                    "/dash" => Response::svg(200, state.ops.dash_svg()),
                    _ => json_doc(200, state.ops.dash_json()),
                }
            }
        }
        ("GET", path) if path.starts_with("/timeseries/") => {
            if query.is_some() {
                error_response(
                    400,
                    "ops endpoints take no query parameters",
                )
            } else {
                timeseries_series(state, &path["/timeseries/".len()..])
            }
        }
        (
            "POST",
            p @ ("/fleet/register" | "/fleet/lease"
            | "/fleet/heartbeat" | "/fleet/complete"),
        ) => {
            // the fleet protocol carries everything in JSON bodies; a
            // query string here is a caller bug, not a no-op
            if query.is_some() {
                error_response(
                    400,
                    "fleet endpoints take no query parameters",
                )
            } else {
                match p {
                    "/fleet/register" => fleet_register(state, req),
                    "/fleet/lease" => fleet_lease(state, req),
                    "/fleet/heartbeat" => fleet_heartbeat(state, req),
                    _ => fleet_complete(state, req),
                }
            }
        }
        (
            _,
            "/fleet/register" | "/fleet/lease" | "/fleet/heartbeat"
            | "/fleet/complete",
        ) => error_response(405, "method not allowed")
            .with_header("Allow", "POST"),
        // known paths, wrong method
        (
            _,
            "/healthz" | "/matrix" | "/metrics" | "/jobs"
            | "/timeseries" | "/dash" | "/dash.json",
        ) => error_response(405, "method not allowed")
            .with_header("Allow", "GET"),
        (_, "/sweep") => error_response(405, "method not allowed")
            .with_header("Allow", "POST"),
        (_, path)
            if path.starts_with("/results/")
                || path.starts_with("/jobs/")
                || path.starts_with("/timeseries/") =>
        {
            error_response(405, "method not allowed")
                .with_header("Allow", "GET")
        }
        _ => error_response(404, "no such route"),
    }
}

fn matrix() -> Response {
    let scenarios = sweep::builtin_matrix();
    let mut o = Json::obj();
    o.set("count", Json::from(scenarios.len()));
    o.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(|s| s.canonical_json()).collect()),
    );
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    Response::json(200, body)
}

fn metrics(state: &AppState) -> Response {
    let (cache_entries, cache_bytes) = state.cache.stats();
    let (store_entries, store_bytes) = state.cache.disk_stats();
    let (jobs_queued, jobs_running) = state.jobs.counts();
    Response::text(
        200,
        state.metrics.render(&Gauges {
            replay_queue_depth: state.pool.queue_depth(),
            cache_entries,
            cache_bytes,
            store_entries,
            store_bytes,
            jobs_queued,
            jobs_running,
            fleet: state.fleet.stats(),
            events_published: state.events.published_total(),
            events_dropped: state.events.dropped_total(),
            events_subscribers: state.events.subscriber_count(),
        }),
    )
}

/// Pretty-print a JSON document as a 200/404/... response body.
fn json_doc(status: u16, doc: Json) -> Response {
    let mut body = doc.to_string_pretty().into_bytes();
    body.push(b'\n');
    Response::json(status, body)
}

fn timeseries_index(state: &AppState) -> Response {
    json_doc(200, state.ops.index_json())
}

fn timeseries_series(state: &AppState, name: &str) -> Response {
    match state.ops.series_json(name) {
        Some(doc) => json_doc(200, doc),
        None => error_response(404, "no such series"),
    }
}

/// Counter contract: `icecloud_sweep_cache_{hits,misses}_total` count
/// `POST /sweep` outcomes only (the request-dedup story), while
/// `icecloud_store_hits_total` counts every body the disk tier
/// actually served, whichever endpoint asked — so by-key fetches of a
/// memory-resident entry deliberately count nothing here.
fn results(state: &AppState, key: &str) -> Response {
    match state.cache.lookup(key) {
        Some((body, Outcome::DiskHit)) => {
            state.metrics.on_disk_hit();
            Response::json_shared(200, body).with_header("X-Cache", "disk")
        }
        Some((body, _)) => Response::json_shared(200, body)
            .with_header("X-Cache", "hit"),
        None => error_response(404, "no cached result under this key"),
    }
}

fn jobs_list(state: &AppState) -> Response {
    let views = state.jobs.list();
    let mut o = Json::obj();
    o.set("count", Json::from(views.len()));
    o.set(
        "jobs",
        Json::Arr(views.iter().map(|v| v.to_json()).collect()),
    );
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    Response::json(200, body)
}

fn job_detail(state: &AppState, id: &str) -> Response {
    match state.jobs.view(id) {
        Some(view) => {
            let mut body = view.to_json().to_string_pretty().into_bytes();
            body.push(b'\n');
            Response::json(200, body)
        }
        None => error_response(404, "no such job"),
    }
}

// ---- the fleet protocol --------------------------------------------------

/// Parse a fleet-endpoint body: a non-empty JSON object or a 400.
fn parse_fleet_body(req: &Request) -> Result<Json, String> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| "body is not valid UTF-8".to_string())?;
    if text.trim().is_empty() {
        return Err("empty body; send a JSON object".to_string());
    }
    let doc = json::parse(text).map_err(|e| e.to_string())?;
    if doc.as_obj().is_none() {
        return Err("body must be a JSON object".to_string());
    }
    Ok(doc)
}

fn fleet_json(status: u16, o: Json) -> Response {
    let mut body = o.to_string_pretty().into_bytes();
    body.push(b'\n');
    Response::json(status, body)
}

fn fleet_register(state: &AppState, req: &Request) -> Response {
    let doc = match parse_fleet_body(req) {
        Ok(doc) => doc,
        Err(e) => return error_response(400, &e),
    };
    let Some(worker_id) = doc.get("worker_id").and_then(Json::as_str)
    else {
        return error_response(
            400,
            "register body needs a worker_id string",
        );
    };
    if worker_id.is_empty() {
        return error_response(400, "worker_id must not be empty");
    }
    let Some(slots) = doc.get("slots").and_then(Json::as_u64) else {
        return error_response(400, "register body needs a slots count");
    };
    let Ok(slots) = u32::try_from(slots) else {
        return error_response(400, "slots out of range");
    };
    if slots == 0 {
        return error_response(400, "slots must be at least 1");
    }
    state.fleet.register(worker_id, slots);
    let opts = state.fleet.options();
    let mut o = Json::obj();
    o.set("worker_id", Json::from(worker_id));
    o.set(
        "lease_ttl_ms",
        Json::from(opts.lease_ttl.as_millis() as u64),
    );
    o.set(
        "heartbeat_every_ms",
        Json::from(opts.heartbeat_every.as_millis() as u64),
    );
    o.set("spot_check_rate", Json::from(opts.spot_check_rate));
    fleet_json(200, o)
}

fn fleet_lease(state: &AppState, req: &Request) -> Response {
    let doc = match parse_fleet_body(req) {
        Ok(doc) => doc,
        Err(e) => return error_response(400, &e),
    };
    let Some(worker_id) = doc.get("worker_id").and_then(Json::as_str)
    else {
        return error_response(
            400,
            "lease body needs a worker_id string",
        );
    };
    let opts = state.fleet.options();
    match state.fleet.lease(worker_id) {
        // unknown worker: register first (404 so a misconfigured
        // client fails loudly instead of spinning on idle polls)
        Err(e) => error_response(404, &e),
        Ok(None) => {
            let mut o = Json::obj();
            o.set("idle", Json::from(true));
            o.set(
                "poll_after_ms",
                Json::from(opts.heartbeat_every.as_millis() as u64),
            );
            fleet_json(200, o)
        }
        Ok(Some(grant)) => {
            let mut o = Json::obj();
            o.set("lease_id", Json::from(grant.lease_id));
            o.set("unit_id", Json::from(grant.unit_id));
            o.set("name", Json::from(grant.name.as_str()));
            o.set("config", grant.config.canonical_json());
            o.set(
                "lease_ttl_ms",
                Json::from(opts.lease_ttl.as_millis() as u64),
            );
            o.set(
                "heartbeat_every_ms",
                Json::from(opts.heartbeat_every.as_millis() as u64),
            );
            fleet_json(200, o)
        }
    }
}

fn fleet_heartbeat(state: &AppState, req: &Request) -> Response {
    let doc = match parse_fleet_body(req) {
        Ok(doc) => doc,
        Err(e) => return error_response(400, &e),
    };
    let Some(lease_id) = doc.get("lease_id").and_then(Json::as_u64)
    else {
        return error_response(400, "heartbeat body needs a lease_id");
    };
    match state.fleet.heartbeat(lease_id) {
        None => error_response(
            404,
            "no such lease (expired, completed, or never granted)",
        ),
        Some(ttl) => {
            let mut o = Json::obj();
            o.set("lease_id", Json::from(lease_id));
            o.set("lease_ttl_ms", Json::from(ttl.as_millis() as u64));
            fleet_json(200, o)
        }
    }
}

fn fleet_complete(state: &AppState, req: &Request) -> Response {
    let doc = match parse_fleet_body(req) {
        Ok(doc) => doc,
        Err(e) => return error_response(400, &e),
    };
    let Some(lease_id) = doc.get("lease_id").and_then(Json::as_u64)
    else {
        return error_response(400, "complete body needs a lease_id");
    };
    let Some(sha) = doc.get("sha256").and_then(Json::as_str) else {
        return error_response(
            400,
            "complete body needs the row's sha256",
        );
    };
    let Some(row) = doc.get("row") else {
        return error_response(400, "complete body needs the row");
    };
    match state.fleet.complete(lease_id, sha, row) {
        CompleteOutcome::Accepted => {
            let mut o = Json::obj();
            o.set("accepted", Json::from(true));
            fleet_json(200, o)
        }
        CompleteOutcome::Unknown => error_response(
            404,
            "no such lease (expired, completed, or never granted)",
        ),
        CompleteOutcome::Rejected(e) => error_response(400, &e),
    }
}

/// The `POST /sweep` execution mode, parsed from the query string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SweepMode {
    Sync,
    Async,
}

/// Strict query parsing: only `mode=sync|async` is understood, and an
/// unknown parameter is an error rather than a silent no-op (the same
/// contract the body parsers follow).
fn parse_sweep_query(query: Option<&str>) -> Result<SweepMode, String> {
    let mut mode = SweepMode::Sync;
    let Some(query) = query else {
        return Ok(mode);
    };
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match (k, v) {
            ("mode", "sync") => mode = SweepMode::Sync,
            ("mode", "async") => mode = SweepMode::Async,
            ("mode", other) => {
                return Err(format!(
                    "unknown sweep mode '{other}' (sync|async)"
                ))
            }
            (other, _) => {
                return Err(format!("unknown query parameter '{other}'"))
            }
        }
    }
    Ok(mode)
}

/// Parse the request body into `(resolved base, scenarios)`.  JSON and
/// TOML share the spec shape; the decode path is chosen by
/// `Content-Type`, falling back to sniffing the first byte.
///
/// Knob validation (and therefore every 400 an invalid knob produces)
/// is owned by the typed registry — `crate::config::registry` via
/// `sweep::parse_spec_json_with_limit` — with one shared
/// error-context format; a knob registered there is sweepable over
/// `POST /sweep` with no changes in this router.
fn parse_sweep_body(
    base: &CampaignConfig,
    req: &Request,
) -> Result<(CampaignConfig, Vec<ScenarioConfig>), String> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| "body is not valid UTF-8".to_string())?;
    if text.trim().is_empty() {
        return Err("empty body; send a scenario spec (TOML or JSON)"
            .to_string());
    }
    let content_type = req.header("content-type").unwrap_or("");
    let looks_json = content_type.contains("json")
        || (!content_type.contains("toml")
            && text.trim_start().starts_with('{'));
    let mut resolved = base.clone();
    // the per-request limit rides into [grid] expansion so a hostile
    // product is refused from the axis lengths alone — never
    // materialized first and counted by validate_limits after
    let limit = Some(MAX_SCENARIOS_PER_REQUEST);
    let scenarios = if looks_json {
        let doc = json::parse(text).map_err(|e| e.to_string())?;
        sweep::parse_spec_json_with_limit(&doc, &mut resolved, limit)?
    } else {
        sweep::matrix::parse_spec_with_limit(text, &mut resolved, limit)?
    };
    Ok((resolved, scenarios))
}

/// Refuse requests that would tie up the replay pool for minutes; the
/// service replays bounded what-if slices, not open-ended simulations.
fn validate_limits(
    base: &CampaignConfig,
    scenarios: &[ScenarioConfig],
) -> Result<(), String> {
    if scenarios.len() > MAX_SCENARIOS_PER_REQUEST {
        return Err(format!(
            "{} scenarios exceeds the per-request limit of {}",
            scenarios.len(),
            MAX_SCENARIOS_PER_REQUEST
        ));
    }
    for s in scenarios {
        let duration = s.duration_s.unwrap_or(base.duration_s);
        if duration > MAX_DURATION_S {
            return Err(format!(
                "scenario '{}' asks for {duration} sim-seconds; limit {}",
                s.name, MAX_DURATION_S
            ));
        }
        let ramp = s.ramp.as_ref().unwrap_or(&base.ramp);
        if ramp.iter().any(|step| step.target > MAX_FLEET) {
            return Err(format!(
                "scenario '{}' ramp target exceeds {MAX_FLEET} GPUs",
                s.name
            ));
        }
        if s.onprem_slots.unwrap_or(base.onprem.slots) > MAX_FLEET {
            return Err(format!(
                "scenario '{}' on-prem slots exceed {MAX_FLEET}",
                s.name
            ));
        }
    }
    Ok(())
}

fn sweep_post(
    state: &AppState,
    req: &Request,
    query: Option<&str>,
) -> Response {
    let mode = match parse_sweep_query(query) {
        Ok(mode) => mode,
        Err(e) => return error_response(400, &e),
    };
    let (resolved, scenarios) = match parse_sweep_body(&state.base, req) {
        Ok(parsed) => parsed,
        Err(e) => return error_response(400, &e),
    };
    if let Err(e) = validate_limits(&resolved, &scenarios) {
        return error_response(400, &e);
    }

    let key = sweep_key(&resolved, &scenarios);
    match mode {
        SweepMode::Sync => sweep_sync(state, key, resolved, scenarios),
        SweepMode::Async => sweep_async(state, key, resolved, scenarios),
    }
}

fn sweep_sync(
    state: &AppState,
    key: String,
    resolved: CampaignConfig,
    scenarios: Vec<ScenarioConfig>,
) -> Response {
    let replays = scenarios.len();
    let (result, outcome) = state.cache.get_or_compute(&key, || {
        // fleet-aware dispatch: remote workers drain the matrix when
        // any are registered, the local pool otherwise — either way
        // the rows land in the same cache under the same key
        let rows =
            state.fleet.run_matrix(&state.pool, &resolved, &scenarios)?;
        // count only completed computations, after the replay succeeds
        state.metrics.on_sweep_computed(
            replays,
            rows.iter().map(|r| r.goodput_hours).sum(),
            rows.iter().map(|r| r.wasted_hours).sum(),
        );
        Ok(render_sweep_body(&key, &rows))
    });
    // accounting contract: every delivered outcome counts exactly once;
    // a Miss (attempted computation) counts whether or not it
    // succeeded, while a waiter surfacing the owner's error served
    // nothing and counts nothing
    match (result, outcome) {
        (Ok(body), Outcome::Hit) => {
            state.metrics.on_lookup_outcome(
                Outcome::Hit,
                state.cache.has_disk(),
            );
            Response::json_shared(200, body).with_header("X-Cache", "hit")
        }
        (Ok(body), Outcome::DiskHit) => {
            state.metrics.on_lookup_outcome(
                Outcome::DiskHit,
                state.cache.has_disk(),
            );
            Response::json_shared(200, body)
                .with_header("X-Cache", "disk")
        }
        (Ok(body), Outcome::Miss) => {
            state.metrics.on_lookup_outcome(
                Outcome::Miss,
                state.cache.has_disk(),
            );
            Response::json_shared(200, body)
                .with_header("X-Cache", "miss")
        }
        (Err(e), Outcome::Miss) => {
            state.metrics.on_lookup_outcome(
                Outcome::Miss,
                state.cache.has_disk(),
            );
            error_response(500, &e)
        }
        (Err(e), _) => error_response(500, &e),
    }
}

fn sweep_async(
    state: &AppState,
    key: String,
    resolved: CampaignConfig,
    scenarios: Vec<ScenarioConfig>,
) -> Response {
    let admission = state.jobs.submit(JobSpec {
        key,
        resolved,
        scenarios,
    });
    match admission {
        Admission::Accepted { id } | Admission::Duplicate { id } => {
            let status = state
                .jobs
                .view(&id)
                .map(|v| v.status)
                .unwrap_or("queued");
            let mut o = Json::obj();
            o.set("job_id", Json::from(id.as_str()));
            o.set("status", Json::from(status));
            o.set("poll", Json::from(format!("/jobs/{id}")));
            let mut body = o.to_string_pretty().into_bytes();
            body.push(b'\n');
            Response::json(202, body)
                .with_header("Location", &format!("/jobs/{id}"))
        }
        Admission::Shed { retry_after_s } => error_response_after(
            429,
            "job queue is full; retry later",
            retry_after_s,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::super::cache::ResultCache;
    use super::super::events::{EventBus, EventKind, DEFAULT_EVENTS_RING};
    use super::super::fleet::{FleetOptions, FleetTable};
    use super::super::jobs::{JobTable, ReplayPool, DEFAULT_JOBS_KEEP};
    use super::super::metrics::Metrics;
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};
    use std::sync::Arc;

    fn tiny_base() -> CampaignConfig {
        let mut base = CampaignConfig::default();
        base.duration_s = 2 * HOUR;
        base.ramp = vec![RampStep { target: 10, hold_s: 60 * DAY }];
        base.outage = None;
        base.onprem.slots = 8;
        base.generator.min_backlog = 30;
        base
    }

    fn tiny_state() -> AppState {
        let events = Arc::new(EventBus::new(DEFAULT_EVENTS_RING));
        let mut cache = ResultCache::new(1 << 20);
        cache.set_events(Arc::clone(&events));
        let cache = Arc::new(cache);
        let pool = Arc::new(ReplayPool::new(2));
        let fleet = Arc::new(FleetTable::with_events(
            FleetOptions::default(),
            Arc::clone(&events),
        ));
        let metrics = Arc::new(Metrics::new());
        let jobs = JobTable::start(
            4,
            1,
            Arc::clone(&cache),
            Arc::clone(&pool),
            Arc::clone(&fleet),
            Arc::clone(&metrics),
            Arc::clone(&events),
            DEFAULT_JOBS_KEEP,
        );
        AppState {
            base: tiny_base(),
            cache,
            pool,
            fleet,
            metrics,
            jobs,
            events,
            ops: Arc::new(OpsMonitor::new()),
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            http11: true,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    fn post(path: &str, content_type: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            http11: true,
            headers: vec![(
                "Content-Type".into(),
                content_type.into(),
            )],
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn healthz_and_matrix_and_404_405() {
        let state = tiny_state();
        assert_eq!(route(&state, &get("/healthz")).status, 200);
        // query strings do not break routing
        assert_eq!(route(&state, &get("/healthz?probe=1")).status, 200);
        let m = route(&state, &get("/matrix"));
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body.to_vec()).unwrap();
        assert!(text.contains("baseline"), "{text}");
        assert_eq!(route(&state, &get("/nope")).status, 404);
        assert_eq!(route(&state, &get("/sweep")).status, 405);
        assert_eq!(route(&state, &post("/jobs", "", "")).status, 405);
        assert_eq!(
            route(&state, &post("/jobs/abc", "", "")).status,
            405
        );
        let r = Request { method: "DELETE".into(), ..get("/healthz") };
        assert_eq!(route(&state, &r).status, 405);
    }

    #[test]
    fn sweep_toml_roundtrip_and_results_lookup() {
        let state = tiny_state();
        let spec = "[scenario.a]\n\n[scenario.b]\nseed = 9\n";
        let first =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(
            first.status,
            200,
            "{}",
            String::from_utf8_lossy(&first.body)
        );
        let doc = json::parse(
            std::str::from_utf8(&first.body).unwrap().trim(),
        )
        .unwrap();
        let key = doc.get("key").unwrap().as_str().unwrap().to_string();
        assert_eq!(key.len(), 64);
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("name").unwrap().as_str(),
            Some("a")
        );

        // byte-identical on the second, cached request
        let second =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(second.body, first.body);
        assert_eq!(second.header_value("X-Cache"), Some("hit"));

        // and via the content address
        let by_key =
            route(&state, &get(&format!("/results/{key}")));
        assert_eq!(by_key.status, 200);
        assert_eq!(by_key.body, first.body);
        assert_eq!(
            route(&state, &get("/results/deadbeef")).status,
            404
        );
        assert_eq!(state.metrics.sweep_computation_count(), 1);
        assert_eq!(state.metrics.cache_hit_count(), 1);
    }

    #[test]
    fn sweep_json_body_equals_toml_body() {
        let state = tiny_state();
        let toml_resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.x]\nbudget_usd = 40.0\n",
            ),
        );
        let json_resp = route(
            &state,
            &post(
                "/sweep",
                "application/json",
                r#"{"scenario": {"x": {"budget_usd": 40.0}}}"#,
            ),
        );
        assert_eq!(toml_resp.status, 200);
        assert_eq!(
            toml_resp.body, json_resp.body,
            "same spec, either encoding, same content address and bytes"
        );
    }

    #[test]
    fn malformed_bodies_rejected() {
        let state = tiny_state();
        for (ct, body) in [
            ("application/toml", "not toml = = ="),
            ("application/toml", "[scenario.a]\nbad_key = 1"),
            ("application/json", "{\"scenario\": "),
            ("application/json", "{}"),
            ("application/toml", ""),
        ] {
            let resp = route(&state, &post("/sweep", ct, body));
            assert_eq!(resp.status, 400, "body {body:?} must be rejected");
        }
        // invalid UTF-8
        let mut req = post("/sweep", "application/toml", "");
        req.body = vec![0xff, 0xfe, 0x00];
        assert_eq!(route(&state, &req).status, 400);
        assert_eq!(state.metrics.sweep_computation_count(), 0);
    }

    #[test]
    fn bad_sweep_queries_rejected() {
        let state = tiny_state();
        for path in [
            "/sweep?mode=later",
            "/sweep?priority=high",
            "/sweep?mode",
        ] {
            let resp = route(
                &state,
                &post(path, "application/toml", "[scenario.a]\n"),
            );
            assert_eq!(resp.status, 400, "'{path}' must be rejected");
        }
        // an explicit sync mode is the default path
        let resp = route(
            &state,
            &post("/sweep?mode=sync", "application/toml", "[scenario.a]\n"),
        );
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn async_submit_races_through_job_lifecycle() {
        let state = tiny_state();
        let resp = route(
            &state,
            &post(
                "/sweep?mode=async",
                "application/toml",
                "[scenario.a]\nseed = 3\n",
            ),
        );
        assert_eq!(
            resp.status,
            202,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        let doc = json::parse(
            std::str::from_utf8(&resp.body).unwrap().trim(),
        )
        .unwrap();
        let id = doc.get("job_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(id.len(), 64);
        assert_eq!(
            resp.header_value("Location"),
            Some(format!("/jobs/{id}").as_str())
        );

        // poll until done
        let mut done = None;
        for _ in 0..1000 {
            let poll = route(&state, &get(&format!("/jobs/{id}")));
            assert_eq!(poll.status, 200);
            let j = json::parse(
                std::str::from_utf8(&poll.body).unwrap().trim(),
            )
            .unwrap();
            let status =
                j.get("status").unwrap().as_str().unwrap().to_string();
            assert_ne!(status, "failed");
            if status == "done" {
                done = Some(j);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let job = done.expect("job finished");
        assert_eq!(
            job.get("result").unwrap().as_str(),
            Some(format!("/results/{id}").as_str())
        );

        // the async result equals the sync response for the same spec
        let fetched = route(&state, &get(&format!("/results/{id}")));
        assert_eq!(fetched.status, 200);
        let sync = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.a]\nseed = 3\n",
            ),
        );
        assert_eq!(sync.status, 200);
        assert_eq!(sync.body, fetched.body);

        // the jobs listing tracks it
        let listing = route(&state, &get("/jobs"));
        assert_eq!(listing.status, 200);
        let l = json::parse(
            std::str::from_utf8(&listing.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(l.get("count").unwrap().as_u64(), Some(1));

        assert_eq!(route(&state, &get("/jobs/0000")).status, 404);
    }

    #[test]
    fn async_invalid_body_never_reaches_the_queue() {
        let state = tiny_state();
        let resp = route(
            &state,
            &post("/sweep?mode=async", "application/toml", "{}"),
        );
        assert_eq!(resp.status, 400);
        assert_eq!(state.metrics.jobs_submitted_count(), 0);
    }

    #[test]
    fn oversized_requests_rejected() {
        let state = tiny_state();
        let mut many = String::new();
        for i in 0..=MAX_SCENARIOS_PER_REQUEST {
            many.push_str(&format!("[scenario.s{i:03}]\n"));
        }
        let resp =
            route(&state, &post("/sweep", "application/toml", &many));
        assert_eq!(resp.status, 400);

        let resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.long]\nduration_days = 365.0\n",
            ),
        );
        assert_eq!(resp.status, 400);

        let resp = route(
            &state,
            &post(
                "/sweep",
                "application/toml",
                "[scenario.big]\nramp_targets = [2000000]\n",
            ),
        );
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn grid_specs_accepted_and_capped_over_http() {
        let state = tiny_state();
        // a [grid] body flows through the same parse path as explicit
        // [scenario.<name>] tables — no special routing
        let spec = "[grid]\nseed = [1, 2]\n\
                    keepalive_s = [60, 120, 240, 300]\n";
        let resp =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(
            resp.status,
            200,
            "{}",
            String::from_utf8_lossy(&resp.body)
        );
        let doc = json::parse(
            std::str::from_utf8(&resp.body).unwrap().trim(),
        )
        .unwrap();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 8);
        assert_eq!(
            rows[0].get("name").unwrap().as_str(),
            Some("keepalive_s=60/seed=1")
        );
        // same spec again: byte-identical body (content-addressed)
        let again =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(again.body, resp.body);

        // a grid expanding past the per-request scenario limit is a
        // 400, not a replay storm: 5 x 4 x 4 = 80 > 64
        let big = "[grid]\nseed = [1, 2, 3, 4, 5]\n\
                   keepalive_s = [60, 120, 240, 300]\n\
                   preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n";
        let resp =
            route(&state, &post("/sweep", "application/toml", big));
        assert_eq!(resp.status, 400);
        // and one past the grid's own expansion cap dies in the parser
        let mut huge = String::from("[grid]\n");
        for key in ["seed", "keepalive_s", "checkpoint_every_s"] {
            let vals: Vec<String> =
                (1..=17).map(|i| i.to_string()).collect();
            huge.push_str(&format!("{key} = [{}]\n", vals.join(", ")));
        }
        let resp =
            route(&state, &post("/sweep", "application/toml", &huge));
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn client_supplied_grid_cap_cannot_lift_request_limits() {
        let state = tiny_state();
        // a small body declaring an astronomical product: the spec's
        // own max_scenarios knob must not be able to buy expansion (or
        // allocation) past the server's per-request limit — refused
        // from the axis lengths alone
        for cap in ["18446744073709551615", "1048576"] {
            let mut evil =
                format!("[grid]\nmax_scenarios = {cap}\n");
            for key in
                ["seed", "keepalive_s", "checkpoint_every_s", "budget_usd"]
            {
                let vals: Vec<String> =
                    (1..=1000).map(|i| i.to_string()).collect();
                evil.push_str(&format!(
                    "{key} = [{}]\n",
                    vals.join(", ")
                ));
            }
            let resp = route(
                &state,
                &post("/sweep", "application/toml", &evil),
            );
            assert_eq!(resp.status, 400, "cap={cap}");
        }
        // even a modest 128-cell grid under the spec's default cap is
        // pre-refused against the request limit of 64
        let spec = "[grid]\nseed = [1, 2, 3, 4, 5, 6, 7, 8]\n\
                    keepalive_s = [60, 120, 240, 300]\n\
                    preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n";
        let resp =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(resp.status, 400);
        let body = String::from_utf8_lossy(&resp.body);
        assert!(body.contains("limit of 64"), "{body}");
    }

    #[test]
    fn metrics_expose_counters() {
        let state = tiny_state();
        route(&state, &post("/sweep", "", "[scenario.a]\n"));
        let resp = route(&state, &get("/metrics"));
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body.to_vec()).unwrap();
        assert!(
            text.contains("icecloud_sweep_computations_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_result_cache_entries 1"),
            "{text}"
        );
        assert!(text.contains("icecloud_jobs_queued 0"), "{text}");
        assert!(
            text.contains("icecloud_result_store_entries 0"),
            "{text}"
        );
    }

    #[test]
    fn fleet_routes_enforce_method_query_and_body_contracts() {
        let state = tiny_state();
        // wrong method: 405 with the Allow header
        for path in [
            "/fleet/register",
            "/fleet/lease",
            "/fleet/heartbeat",
            "/fleet/complete",
        ] {
            let resp = route(&state, &get(path));
            assert_eq!(resp.status, 405, "GET {path}");
            assert_eq!(resp.header_value("Allow"), Some("POST"));
        }
        // query parameters are a hard error, not a silent no-op
        let resp = route(
            &state,
            &post(
                "/fleet/lease?fast=1",
                "application/json",
                r#"{"worker_id":"w1"}"#,
            ),
        );
        assert_eq!(resp.status, 400);
        // malformed bodies
        for body in [
            "",
            "[1, 2]",
            "{\"worker_id\": \"w1\"}",            // missing slots
            "{\"worker_id\": \"w1\", \"slots\": 0}", // zero slots
            "{\"worker_id\": \"\", \"slots\": 1}", // empty id
            "{\"slots\": 1}",                      // missing id
        ] {
            let resp = route(
                &state,
                &post("/fleet/register", "application/json", body),
            );
            assert_eq!(resp.status, 400, "register body {body:?}");
        }
        assert_eq!(state.fleet.stats().workers_registered, 0);
    }

    #[test]
    fn fleet_lease_lifecycle_over_http() {
        let state = tiny_state();
        // an unregistered worker cannot lease
        let resp = route(
            &state,
            &post(
                "/fleet/lease",
                "application/json",
                r#"{"worker_id":"ghost"}"#,
            ),
        );
        assert_eq!(resp.status, 404);

        let resp = route(
            &state,
            &post(
                "/fleet/register",
                "application/json",
                r#"{"worker_id":"w1","slots":2}"#,
            ),
        );
        assert_eq!(resp.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&resp.body).unwrap().trim(),
        )
        .unwrap();
        assert!(
            doc.get("heartbeat_every_ms").unwrap().as_u64().unwrap()
                >= 1
        );

        // nothing queued yet: idle poll
        let lease_body = r#"{"worker_id":"w1"}"#;
        let resp = route(
            &state,
            &post("/fleet/lease", "application/json", lease_body),
        );
        assert_eq!(resp.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&resp.body).unwrap().trim(),
        )
        .unwrap();
        assert!(doc.get("idle").is_some(), "no pending units yet");

        // queue one unit; the next lease grants it
        let _flight = state
            .fleet
            .begin_sweep(&state.base, &[ScenarioConfig::named("u")]);
        let resp = route(
            &state,
            &post("/fleet/lease", "application/json", lease_body),
        );
        assert_eq!(resp.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&resp.body).unwrap().trim(),
        )
        .unwrap();
        let lease_id =
            doc.get("lease_id").unwrap().as_u64().unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("u"));
        assert!(
            doc.get("config").unwrap().as_obj().is_some(),
            "grant carries the canonical config"
        );

        // heartbeat extends it; an unknown lease 404s, table untouched
        let hb = format!("{{\"lease_id\": {lease_id}}}");
        let resp = route(
            &state,
            &post("/fleet/heartbeat", "application/json", &hb),
        );
        assert_eq!(resp.status, 200);
        let resp = route(
            &state,
            &post(
                "/fleet/heartbeat",
                "application/json",
                r#"{"lease_id": 999}"#,
            ),
        );
        assert_eq!(resp.status, 404);
        assert_eq!(state.fleet.stats().leases_outstanding, 1);

        // a corrupt completion rejects with 400 and requeues the unit
        let done = format!(
            "{{\"lease_id\": {lease_id}, \"sha256\": \"{}\", \"row\": {{}}}}",
            "0".repeat(64)
        );
        let resp = route(
            &state,
            &post("/fleet/complete", "application/json", &done),
        );
        assert_eq!(resp.status, 400);
        let stats = state.fleet.stats();
        assert_eq!(stats.leases_rejected, 1);
        assert_eq!(stats.units_pending, 1, "rejected unit requeued");
        assert_eq!(stats.leases_outstanding, 0);

        // completing a lease that no longer exists is a 404
        let resp = route(
            &state,
            &post("/fleet/complete", "application/json", &done),
        );
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn ops_routes_enforce_method_and_query_contracts() {
        let state = tiny_state();
        // wrong method: 405 with the Allow header, SSE included
        for path in [
            "/events",
            "/timeseries",
            "/timeseries/jobs.queued",
            "/dash",
            "/dash.json",
        ] {
            let r = Request { method: "DELETE".into(), ..get(path) };
            let resp = route(&state, &r);
            assert_eq!(resp.status, 405, "DELETE {path}");
            assert_eq!(resp.header_value("Allow"), Some("GET"));
        }
        // query parameters are a hard error, not a silent no-op
        for path in [
            "/events?from=3",
            "/timeseries?limit=9",
            "/timeseries/jobs.queued?points=5",
            "/dash?theme=light",
            "/dash.json?pretty=1",
        ] {
            assert_eq!(route(&state, &get(path)).status, 400, "{path}");
        }
        // a malformed Last-Event-ID is a 400 before the stream starts,
        // not a silently-fresh stream
        let mut r = get("/events");
        r.headers.push(("Last-Event-ID".into(), "abc".into()));
        assert_eq!(route(&state, &r).status, 400);
        // unknown series 404s
        assert_eq!(route(&state, &get("/timeseries/nope")).status, 404);
    }

    #[test]
    fn v1_prefix_aliases_the_whole_surface() {
        let state = tiny_state();
        assert_eq!(route(&state, &get("/v1/healthz")).status, 200);
        assert_eq!(route(&state, &get("/v1/matrix")).status, 200);
        assert_eq!(route(&state, &get("/v1/jobs")).status, 200);
        assert_eq!(route(&state, &get("/v1/timeseries")).status, 200);

        // same spec, either mount, same content address and bytes
        let spec = "[scenario.a]\nseed = 4\n";
        let versioned =
            route(&state, &post("/v1/sweep", "application/toml", spec));
        assert_eq!(versioned.status, 200);
        let legacy =
            route(&state, &post("/sweep", "application/toml", spec));
        assert_eq!(versioned.body, legacy.body);
        assert_eq!(versioned.header_value("X-Cache"), Some("miss"));
        assert_eq!(legacy.header_value("X-Cache"), Some("hit"));

        // only a real path boundary counts as the versioned mount
        assert_eq!(route(&state, &get("/v1")).status, 404);
        assert_eq!(route(&state, &get("/v1healthz")).status, 404);
        assert_eq!(route(&state, &get("/v1/nope")).status, 404);

        // the SSE hand-off works from the versioned mount too
        match dispatch(&state, &get("/v1/events")) {
            Routed::Events { resume: None } => {}
            _ => panic!("expected an event stream via /v1/events"),
        }
    }

    #[test]
    fn every_response_carries_the_api_version_header() {
        let state = tiny_state();
        for req in [
            get("/healthz"),
            get("/v1/healthz"),
            get("/nope"),
            get("/sweep"), // 405
            post("/sweep", "application/toml", "not toml = ="),
        ] {
            let resp = route(&state, &req);
            assert_eq!(
                resp.header_value("X-Api-Version"),
                Some("1"),
                "{} {}",
                req.method,
                req.path
            );
        }
    }

    #[test]
    fn error_bodies_are_the_canonical_shape() {
        let state = tiny_state();
        for (req, status, code) in [
            (get("/nope"), 404, "not_found"),
            (get("/sweep"), 405, "method_not_allowed"),
            (
                post("/sweep", "application/toml", "not toml = ="),
                400,
                "bad_request",
            ),
            (get("/results/deadbeef"), 404, "not_found"),
            (get("/timeseries/nope"), 404, "not_found"),
        ] {
            let resp = route(&state, &req);
            assert_eq!(resp.status, status, "{}", req.path);
            let doc = json::parse(
                std::str::from_utf8(&resp.body).unwrap().trim(),
            )
            .unwrap();
            assert_eq!(
                doc.get("error").unwrap().as_str(),
                Some(code),
                "{}",
                req.path
            );
            assert!(
                doc.get("detail")
                    .unwrap()
                    .as_str()
                    .is_some_and(|d| !d.is_empty()),
                "{} needs a human-readable detail",
                req.path
            );
        }
    }

    #[test]
    fn events_dispatch_separates_streams_from_responses() {
        let state = tiny_state();
        match dispatch(&state, &get("/events")) {
            Routed::Events { resume: None } => {}
            _ => panic!("expected a fresh event stream"),
        }
        let mut r = get("/events");
        r.headers.push(("Last-Event-ID".into(), "17".into()));
        match dispatch(&state, &r) {
            Routed::Events { resume: Some(17) } => {}
            _ => panic!("expected a resumed event stream"),
        }
        // the flattened route() twin is an empty event-stream response
        let resp = route(&state, &get("/events"));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.content_type, "text/event-stream");
    }

    #[test]
    fn timeseries_and_dash_render_the_ops_monitor() {
        let state = tiny_state();
        state.ops.record("jobs.queued", 2.0);
        let idx = route(&state, &get("/timeseries"));
        assert_eq!(idx.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&idx.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(doc.get("count").unwrap().as_u64(), Some(1));

        let one = route(&state, &get("/timeseries/jobs.queued"));
        assert_eq!(one.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&one.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(doc.get("samples").unwrap().as_u64(), Some(1));

        let svg = route(&state, &get("/dash"));
        assert_eq!(svg.status, 200);
        assert_eq!(svg.content_type, "image/svg+xml");
        assert!(
            std::str::from_utf8(&svg.body).unwrap().starts_with("<svg ")
        );

        let twin = route(&state, &get("/dash.json"));
        assert_eq!(twin.status, 200);
        let doc = json::parse(
            std::str::from_utf8(&twin.body).unwrap().trim(),
        )
        .unwrap();
        assert_eq!(
            doc.get("series").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn metrics_expose_event_bus_counters() {
        let state = tiny_state();
        state
            .events
            .publish(EventKind::JobDone { id: "j1".into() });
        let resp = route(&state, &get("/metrics"));
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body.to_vec()).unwrap();
        assert!(
            text.contains("icecloud_events_published_total 1"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_events_dropped_total 0"),
            "{text}"
        );
        assert!(
            text.contains("icecloud_events_subscribers 0"),
            "{text}"
        );
    }

    impl Response {
        fn header_value(&self, name: &str) -> Option<&str> {
            self.extra_headers
                .iter()
                .find(|(k, _)| k.eq_ignore_ascii_case(name))
                .map(|(_, v)| v.as_str())
        }
    }
}
