//! EXP-SWEEP — the scenario-matrix comparison: cost vs EFLOP-hours.
//!
//! The paper's headline is one point on a cost/compute plane ($58k →
//! 3.1 fp32 EFLOP-hours); this harness renders the whole plane for a
//! sweep matrix — one row per scenario with its cost, delivered
//! GPU-days/EFLOP-hours, $/EFLOP-hour, stability (preemptions, NAT
//! drops, goodput) and budget state — plus the CloudBank per-scenario
//! roll-up and a CSV for external plotting.
//!
//! Columns here are *outputs* (metrics of a finished replay); the
//! sweepable *input* surface — every knob a spec may set — is the
//! typed registry in `crate::config::registry` (`icecloud knobs`),
//! so a knob added there flows into these rows with no changes here.

use crate::cloudbank::report;
use crate::sweep::ScenarioSummary;
use crate::util::json::Json;
use std::path::Path;

/// Render the comparative table (one row per scenario).  The name
/// column stretches to the longest scenario name (grid-synthesized
/// names easily exceed the hand-written ones), floor 18 so small
/// matrices keep their historical layout.
pub fn render(rows: &[ScenarioSummary]) -> String {
    let name_w =
        rows.iter().map(|r| r.name.len()).max().unwrap_or(0).max(18);
    let mut out = String::new();
    out.push_str("SWEEP — scenario matrix: cost vs delivered compute\n");
    out.push_str(&format!(
        "{:<name_w$} {:>9} {:>5} {:>9} {:>9} {:>8} {:>9} {:>6} {:>7} {:>7} {:>6} {:>8} {:>6} {:>7} {:>8}\n",
        "scenario", "seed", "days", "cost $", "GPU-days", "EFLOPh",
        "$/EFLOPh", "peak", "done", "intr", "drops", "preempt", "good%",
        "resume", "waste h"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<name_w$} {:>9} {:>5.1} {:>9.0} {:>9.1} {:>8.4} {:>9.0} {:>6.0} {:>7} {:>7} {:>6} {:>8} {:>5.1}% {:>7} {:>8.1}\n",
            r.name,
            r.seed,
            r.duration_days,
            r.cost_usd(),
            r.gpu_days,
            r.eflop_hours,
            r.cost_per_eflop_hour,
            r.peak_gpus,
            r.completed,
            r.interrupted,
            r.nat_drops,
            r.preemptions,
            r.goodput_fraction * 100.0,
            r.resumes,
            r.wasted_hours,
        ));
    }
    out.push_str(
        "\npaper operating point: ~$58k -> ~16k GPU-days / ~3.1 fp32 \
         EFLOP-hours (~$18.7k per EFLOP-hour)\n",
    );
    out
}

/// Machine-readable rows.
pub fn to_csv(rows: &[ScenarioSummary]) -> String {
    let mut out = String::from(
        "scenario,seed,duration_days,budget_usd,cost_usd,azure_usd,gcp_usd,\
         aws_usd,gpu_days,eflop_hours,cost_per_eflop_hour,peak_gpus,\
         mean_gpus,completed,interrupted,goodput_fraction,nat_drops,\
         preemptions,resumes,goodput_hours,wasted_hours,expansion_factor,\
         alerts\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            super::csv_field(&r.name),
            r.seed,
            r.duration_days,
            r.snapshot.budget_usd,
            r.cost_usd(),
            r.snapshot.azure_usd,
            r.snapshot.gcp_usd,
            r.snapshot.aws_usd,
            r.gpu_days,
            r.eflop_hours,
            r.cost_per_eflop_hour,
            r.peak_gpus,
            r.mean_gpus,
            r.completed,
            r.interrupted,
            r.goodput_fraction,
            r.nat_drops,
            r.preemptions,
            r.resumes,
            r.goodput_hours,
            r.wasted_hours,
            r.expansion_factor,
            r.alerts,
        ));
    }
    out
}

/// Machine-readable rows as a JSON array — the one rendering shared by
/// `--out` sweep files and the `icecloud serve` response bodies.  All
/// key order and number formatting comes from `util::json`, so the same
/// rows always serialize to the same bytes (which is what makes the
/// server's content-addressed cache able to promise byte-identical
/// responses).
pub fn to_json(rows: &[ScenarioSummary]) -> Json {
    Json::Arr(rows.iter().map(row_to_json).collect())
}

fn row_to_json(r: &ScenarioSummary) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::from(r.name.as_str()));
    o.set("seed", Json::from(r.seed));
    o.set("duration_days", Json::from(r.duration_days));
    o.set("budget_usd", Json::from(r.snapshot.budget_usd));
    o.set("cost_usd", Json::from(r.cost_usd()));
    o.set("aws_usd", Json::from(r.snapshot.aws_usd));
    o.set("gcp_usd", Json::from(r.snapshot.gcp_usd));
    o.set("azure_usd", Json::from(r.snapshot.azure_usd));
    o.set("gpu_days", Json::from(r.gpu_days));
    o.set("eflop_hours", Json::from(r.eflop_hours));
    o.set("cost_per_eflop_hour", Json::from(r.cost_per_eflop_hour));
    o.set("peak_gpus", Json::from(r.peak_gpus));
    o.set("mean_gpus", Json::from(r.mean_gpus));
    o.set("completed", Json::from(r.completed));
    o.set("interrupted", Json::from(r.interrupted));
    o.set("goodput_fraction", Json::from(r.goodput_fraction));
    o.set("nat_drops", Json::from(r.nat_drops));
    o.set("preemptions", Json::from(r.preemptions));
    o.set("resumes", Json::from(r.resumes));
    o.set("goodput_hours", Json::from(r.goodput_hours));
    o.set("wasted_hours", Json::from(r.wasted_hours));
    o.set("expansion_factor", Json::from(r.expansion_factor));
    o.set("alerts", Json::from(r.alerts));
    o
}

/// Write `sweep.txt`, `sweep.csv`, `sweep.json` and the CloudBank
/// `rollup.txt` into `<out_root>/sweep/`.
pub fn write(rows: &[ScenarioSummary], out_root: &Path) -> std::io::Result<()> {
    let dir = super::exp_dir(out_root, "sweep")?;
    super::write_output(&dir, "sweep.txt", &render(rows))?;
    super::write_output(&dir, "sweep.csv", &to_csv(rows))?;
    super::write_output(&dir, "sweep.json", &to_json(rows).to_string_pretty())?;
    let rollup: Vec<report::RollupRow> = rows
        .iter()
        .map(|r| report::RollupRow {
            name: r.name.clone(),
            snapshot: r.snapshot,
            goodput_hours: r.goodput_hours,
            wasted_hours: r.wasted_hours,
        })
        .collect();
    super::write_output(&dir, "rollup.txt", &report::render_rollup(&rollup))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudbank::BudgetSnapshot;

    fn row(name: &str, cost: f64) -> ScenarioSummary {
        ScenarioSummary {
            name: name.to_string(),
            seed: 1,
            duration_days: 4.0,
            snapshot: BudgetSnapshot {
                at: 0,
                budget_usd: 58_000.0,
                spent_usd: cost,
                aws_usd: cost * 0.1,
                gcp_usd: cost * 0.1,
                azure_usd: cost * 0.8,
            },
            gpu_days: 100.0,
            eflop_hours: 0.02,
            cost_per_eflop_hour: cost / 0.02,
            peak_gpus: 80.0,
            mean_gpus: 60.0,
            completed: 1000,
            interrupted: 5,
            goodput_fraction: 0.99,
            nat_drops: 0,
            preemptions: 3,
            resumes: 2,
            goodput_hours: 2200.5,
            wasted_hours: 199.5,
            expansion_factor: 2.0,
            alerts: 1,
        }
    }

    #[test]
    fn render_lists_every_scenario() {
        let rows = vec![row("baseline", 400.0), row("budget-half", 200.0)];
        let txt = render(&rows);
        assert!(txt.contains("baseline"));
        assert!(txt.contains("budget-half"));
        assert!(txt.contains("$/EFLOPh"));
        assert!(txt.contains("waste h"));
        assert!(txt.contains("199.5"));
        assert_eq!(txt.lines().count(), 6);
    }

    #[test]
    fn csv_has_one_line_per_scenario_plus_header() {
        let rows = vec![row("a", 1.0), row("b", 2.0), row("c", 3.0)];
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("scenario,seed"));
        for line in csv.lines() {
            assert_eq!(line.split(',').count(), 23, "bad row: {line}");
        }
    }

    #[test]
    fn csv_quotes_hostile_names() {
        // a quoted TOML key ([scenario."a,b"]) or grid name must not
        // shift every downstream column
        let rows = vec![row("a,b\"c", 1.0), row("plain", 2.0)];
        let csv = to_csv(&rows);
        let hostile = csv.lines().nth(1).unwrap();
        assert!(hostile.starts_with("\"a,b\"\"c\","), "row: {hostile}");
        // the quoted field counts as one column: strip it, then the
        // remaining 22 numeric fields split cleanly on commas
        let rest = hostile.strip_prefix("\"a,b\"\"c\",").unwrap();
        assert_eq!(rest.split(',').count(), 22);
        let plain = csv.lines().nth(2).unwrap();
        assert_eq!(plain.split(',').count(), 23);
    }

    #[test]
    fn render_widens_name_column_to_longest_name() {
        let long = "budget_usd=14500/keepalive_s=60/preempt_multiplier=1";
        let rows = vec![row("baseline", 1.0), row(long, 2.0)];
        let txt = render(&rows);
        let header = txt.lines().nth(1).unwrap();
        let short_row = txt.lines().nth(2).unwrap();
        let long_row = txt.lines().nth(3).unwrap();
        // the name column is as wide as the longest name, so the next
        // column starts at the same offset on every line
        assert_eq!(&header[..8], "scenario");
        assert!(header[8..long.len()].trim().is_empty());
        assert_eq!(&short_row[..8], "baseline");
        assert!(short_row[8..long.len()].trim().is_empty());
        assert_eq!(&long_row[..long.len()], long);
        // small matrices keep the historical 18-char floor
        let small = render(&vec![row("baseline", 1.0)]);
        let line = small.lines().nth(2).unwrap();
        assert_eq!(&line[..8], "baseline");
        assert!(line[8..18].trim().is_empty());
    }

    #[test]
    fn write_emits_all_outputs() {
        let root = std::env::temp_dir().join("icecloud-sweep-exp-test");
        let rows = vec![row("x", 10.0)];
        write(&rows, &root).unwrap();
        for f in ["sweep.txt", "sweep.csv", "sweep.json", "rollup.txt"] {
            assert!(root.join("sweep").join(f).exists(), "missing {f}");
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn json_rows_parse_back_with_all_fields() {
        let rows = vec![row("baseline", 400.0), row("other", 10.0)];
        let text = to_json(&rows).to_string_compact();
        let v = crate::util::json::parse(&text).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("baseline"));
        assert_eq!(arr[0].get("cost_usd").unwrap().as_f64(), Some(400.0));
        assert_eq!(arr[0].get("completed").unwrap().as_u64(), Some(1000));
        // the JSON carries the same column set as the CSV header
        for key in [
            "seed", "duration_days", "budget_usd", "azure_usd", "gpu_days",
            "eflop_hours", "cost_per_eflop_hour", "peak_gpus", "mean_gpus",
            "interrupted", "goodput_fraction", "nat_drops", "preemptions",
            "resumes", "goodput_hours", "wasted_hours",
            "expansion_factor", "alerts",
        ] {
            assert!(arr[0].get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn json_serialization_is_deterministic() {
        let rows = vec![row("a", 1.5)];
        assert_eq!(
            to_json(&rows).to_string_compact(),
            to_json(&rows).to_string_compact()
        );
    }
}
