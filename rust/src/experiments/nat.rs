//! EXP-NAT — the §IV keepalive-vs-NAT-timeout incident, as a sweep.
//!
//! "The default Azure NAT setup has a 4-minute timeout on idle outgoing
//! TCP connections ... and the default OSG setup was set to 5 minutes,
//! resulting in constant preemption of the user jobs. Once that parameter
//! was adjusted, all regions ... were successfully executing user jobs."
//!
//! We sweep the keepalive interval across the 240 s boundary on an
//! Azure-only fleet and report job-interrupt rates and completions: the
//! paper's incident appears as a cliff at keepalive > 240 s.

use crate::config::{CampaignConfig, PolicyMode, ProviderWeights, RampStep};
use crate::coordinator::Campaign;
use crate::sim::{DAY, HOUR};
use std::path::Path;

/// One sweep point.
#[derive(Debug, Clone, Copy)]
pub struct NatRow {
    pub keepalive_s: u64,
    pub nat_drops: u64,
    pub completed: u64,
    pub interrupted: u64,
    pub badput_hours: f64,
    pub goodput_hours: f64,
}

impl NatRow {
    /// Fraction of wall time wasted.
    pub fn badput_fraction(&self) -> f64 {
        let total = self.badput_hours + self.goodput_hours;
        if total > 0.0 { self.badput_hours / total } else { 0.0 }
    }
}

/// Azure-only scenario used for every sweep point.
fn scenario(keepalive_s: u64, duration_s: u64, gpus: u32) -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.seed = 777;
    c.duration_s = duration_s;
    c.keepalive_s = keepalive_s;
    c.outage = None;
    c.ramp = vec![RampStep { target: gpus, hold_s: 30 * DAY }];
    // Azure-only: the incident is NAT-specific
    c.policy = PolicyMode::Fixed(ProviderWeights { aws: 0.0, gcp: 0.0, azure: 1.0 });
    c.onprem.slots = 0; // isolate the cloud path
    c.generator.min_backlog = (gpus as usize) * 3;
    // shorter jobs so completions are measurable inside the window
    c.generator.runtimes.median_s = 1800.0;
    c.generator.runtimes.min_s = 600;
    c.generator.runtimes.max_s = 3600;
    c
}

/// Run the sweep. Default grid crosses the 240 s NAT boundary.
pub fn run_sweep(keepalives: &[u64], duration_s: u64, gpus: u32) -> Vec<NatRow> {
    keepalives
        .iter()
        .map(|&k| {
            let result = Campaign::new(scenario(k, duration_s, gpus)).run();
            NatRow {
                keepalive_s: k,
                nat_drops: result.pool_stats.nat_drops,
                completed: result.schedd_stats.completed,
                interrupted: result.schedd_stats.interrupted,
                badput_hours: result.schedd_stats.badput_s as f64 / 3600.0,
                goodput_hours: result.schedd_stats.goodput_s as f64 / 3600.0,
            }
        })
        .collect()
}

pub const DEFAULT_KEEPALIVES: [u64; 6] = [60, 120, 180, 240, 300, 360];

pub fn render(rows: &[NatRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "NAT — keepalive interval vs Azure 4-min NAT idle timeout\n");
    out.push_str(&format!(
        "{:>12} {:>10} {:>10} {:>12} {:>10} {:>9}\n",
        "keepalive_s", "nat_drops", "completed", "interrupted", "badput%",
        "verdict"
    ));
    for r in rows {
        let verdict = if r.keepalive_s <= 240 { "stable" } else { "STORM" };
        out.push_str(&format!(
            "{:>12} {:>10} {:>10} {:>12} {:>9.1}% {:>9}\n",
            r.keepalive_s,
            r.nat_drops,
            r.completed,
            r.interrupted,
            r.badput_fraction() * 100.0,
            verdict
        ));
    }
    out.push_str(
        "\npaper: OSG default (300 s) > Azure NAT timeout (240 s) caused\n\
         constant preemption; lowering the keepalive fixed all regions.\n",
    );
    out
}

pub fn to_csv(rows: &[NatRow]) -> String {
    let mut out = String::from(
        "keepalive_s,nat_drops,completed,interrupted,badput_hours,goodput_hours\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{}\n",
            r.keepalive_s, r.nat_drops, r.completed, r.interrupted,
            r.badput_hours, r.goodput_hours
        ));
    }
    out
}

pub fn write(out_root: &Path) -> std::io::Result<Vec<NatRow>> {
    let rows = run_sweep(&DEFAULT_KEEPALIVES, 12 * HOUR, 100);
    let dir = super::exp_dir(out_root, "nat")?;
    super::write_output(&dir, "nat.csv", &to_csv(&rows))?;
    super::write_output(&dir, "nat.txt", &render(&rows))?;
    Ok(rows)
}

/// The cliff check: below-timeout keepalives stable, above-timeout broken.
pub fn check_cliff(rows: &[NatRow]) -> Result<(), String> {
    for r in rows {
        if r.keepalive_s <= 240 && r.nat_drops > 0 {
            return Err(format!(
                "keepalive {} should survive the NAT but saw {} drops",
                r.keepalive_s, r.nat_drops
            ));
        }
        if r.keepalive_s > 240 && r.nat_drops == 0 {
            return Err(format!(
                "keepalive {} should storm but saw no drops",
                r.keepalive_s
            ));
        }
    }
    let stable_completed: u64 =
        rows.iter().filter(|r| r.keepalive_s <= 240).map(|r| r.completed).sum();
    let storm_completed: u64 =
        rows.iter().filter(|r| r.keepalive_s > 240).map(|r| r.completed).sum();
    let stable_n = rows.iter().filter(|r| r.keepalive_s <= 240).count() as u64;
    let storm_n = rows.iter().filter(|r| r.keepalive_s > 240).count() as u64;
    if stable_n > 0
        && storm_n > 0
        && storm_completed * 2 * stable_n >= stable_completed * storm_n
    {
        return Err(format!(
            "storm side should complete <50% of stable side \
             (stable {stable_completed}/{stable_n}, storm {storm_completed}/{storm_n})"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cliff_at_240s() {
        // reduced sweep for test speed: one stable, one storming point
        let rows = run_sweep(&[120, 300], 6 * HOUR, 40);
        check_cliff(&rows).unwrap();
        let stable = &rows[0];
        let storm = &rows[1];
        assert_eq!(stable.nat_drops, 0);
        assert!(storm.nat_drops > 50, "drops={}", storm.nat_drops);
        assert!(stable.completed > storm.completed * 2);
        assert!(storm.badput_fraction() > 0.5);
        assert!(stable.badput_fraction() < 0.05);
    }

    #[test]
    fn renders() {
        let rows = run_sweep(&[120, 300], 3 * HOUR, 20);
        let txt = render(&rows);
        assert!(txt.contains("STORM"));
        assert!(txt.contains("stable"));
        assert!(to_csv(&rows).lines().count() == 3);
    }
}
