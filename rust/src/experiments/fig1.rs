//! EXP-F1 — Fig 1: the monitoring snapshot of provisioned cloud GPUs.
//!
//! Paper shape: staged ramp 400 → 900 → 1.2k → 1.6k → 2k with holds, a
//! cliff to ~0 at the CE-host outage on day ~11, and a resume at 1k GPUs
//! for the remaining days.

use crate::coordinator::CampaignResult;
use crate::monitoring::{line_chart, TimeSeries};
use crate::sim::DAY;
use std::path::Path;

/// Extracted Fig-1 data.
pub struct Fig1 {
    pub total: TimeSeries,
    pub azure: TimeSeries,
    pub gcp: TimeSeries,
    pub aws: TimeSeries,
    pub transitions: Vec<(u64, u32)>,
    pub outage_window: Option<(u64, u64)>,
}

/// Shape checks the reproduction must satisfy (who wins / what shape,
/// not absolute numbers).
pub struct Fig1Checks {
    pub peak: f64,
    pub collapse_min: f64,
    pub resume_level: f64,
    pub ramp_monotonic_until_peak: bool,
}

pub fn extract(result: &CampaignResult) -> Fig1 {
    Fig1 {
        total: result.monitor.get("gpus.total").cloned().unwrap_or_default(),
        azure: result.monitor.get("gpus.azure").cloned().unwrap_or_default(),
        gcp: result.monitor.get("gpus.gcp").cloned().unwrap_or_default(),
        aws: result.monitor.get("gpus.aws").cloned().unwrap_or_default(),
        transitions: result.ramp_transitions.clone(),
        outage_window: result.outage_window,
    }
}

impl Fig1 {
    pub fn checks(&self) -> Fig1Checks {
        let peak = self.total.max().unwrap_or(0.0);
        let (collapse_min, resume_level) = match self.outage_window {
            Some((start, end)) => {
                let collapse = self
                    .total
                    .points
                    .iter()
                    .filter(|(t, _)| *t >= start && *t <= end + 1800)
                    .map(|(_, v)| *v)
                    .fold(f64::INFINITY, f64::min);
                let resume = self
                    .total
                    .points
                    .iter()
                    .filter(|(t, _)| *t > end + DAY / 2)
                    .map(|(_, v)| *v)
                    .fold(0.0f64, f64::max);
                (collapse, resume)
            }
            None => (f64::NAN, f64::NAN),
        };
        // daily maxima must be non-decreasing until the peak day
        let peak_t = self
            .total
            .points
            .iter()
            .find(|(_, v)| *v >= peak)
            .map(|(t, _)| *t)
            .unwrap_or(0);
        let mut daily_max = vec![0.0f64; (peak_t / DAY + 1) as usize];
        for &(t, v) in &self.total.points {
            if t <= peak_t {
                let d = (t / DAY) as usize;
                daily_max[d] = daily_max[d].max(v);
            }
        }
        let ramp_monotonic_until_peak =
            daily_max.windows(2).all(|w| w[1] >= w[0] * 0.85);
        Fig1Checks { peak, collapse_min, resume_level, ramp_monotonic_until_peak }
    }

    /// ASCII rendition of the monitoring snapshot.
    pub fn chart(&self) -> String {
        let mut out = line_chart(
            "Fig 1 — provisioned cloud GPUs over the two-week exercise",
            &[
                ("total", &self.total),
                ("azure", &self.azure),
                ("gcp", &self.gcp),
                ("aws", &self.aws),
            ],
            100,
            20,
        );
        if let Some((s, e)) = self.outage_window {
            out.push_str(&format!(
                "  outage: day {:.2} → {:.2} (CE-host provider network failure)\n",
                s as f64 / DAY as f64,
                e as f64 / DAY as f64
            ));
        }
        out.push_str("  ramp plan: ");
        for (t, target) in &self.transitions {
            out.push_str(&format!("d{:.1}->{} ", *t as f64 / DAY as f64, target));
        }
        out.push('\n');
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_s,total,azure,gcp,aws\n");
        for (i, &(t, total)) in self.total.points.iter().enumerate() {
            let g = |s: &TimeSeries| {
                s.points.get(i).map(|(_, v)| *v).unwrap_or(f64::NAN)
            };
            out.push_str(&format!(
                "{t},{total},{},{},{}\n",
                g(&self.azure),
                g(&self.gcp),
                g(&self.aws)
            ));
        }
        out
    }
}

/// Run + write the full Fig-1 experiment into `out/fig1/`.
pub fn write(result: &CampaignResult, out_root: &Path) -> std::io::Result<Fig1> {
    let fig = extract(result);
    let dir = super::exp_dir(out_root, "fig1")?;
    super::write_output(&dir, "fig1.csv", &fig.to_csv())?;
    super::write_output(&dir, "fig1.txt", &fig.chart())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CampaignConfig, OutageSpec, RampStep};
    use crate::coordinator::Campaign;
    use crate::sim::HOUR;

    fn mini_result() -> CampaignResult {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * DAY;
        c.ramp = vec![
            RampStep { target: 20, hold_s: 6 * HOUR },
            RampStep { target: 60, hold_s: 30 * DAY },
        ];
        c.outage = Some(OutageSpec { at_s: DAY, duration_s: 2 * HOUR });
        c.post_outage_target = 30;
        c.low_budget_resume_fraction = 1.1;
        c.onprem.slots = 20;
        c.generator.min_backlog = 100;
        Campaign::new(c).run()
    }

    #[test]
    fn fig1_shape_checks() {
        let result = mini_result();
        let fig = extract(&result);
        let checks = fig.checks();
        assert!(checks.peak >= 50.0, "peak={}", checks.peak);
        assert!(checks.collapse_min <= 5.0, "collapse={}", checks.collapse_min);
        assert!(
            checks.resume_level > 20.0 && checks.resume_level < checks.peak,
            "resume={}",
            checks.resume_level
        );
        assert!(checks.ramp_monotonic_until_peak);
    }

    #[test]
    fn chart_and_csv_render() {
        let result = mini_result();
        let fig = extract(&result);
        let chart = fig.chart();
        assert!(chart.contains("Fig 1"));
        assert!(chart.contains("outage"));
        let csv = fig.to_csv();
        assert!(csv.starts_with("t_s,total,azure,gcp,aws"));
        assert!(csv.lines().count() > 10);
    }
}
