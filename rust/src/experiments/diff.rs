//! EXP-DIFF — sweep-vs-sweep comparison: per-column deltas.
//!
//! Takes two sweep result documents — `sweep.json` files written by the
//! sweep harness, or `/results/<key>` response bodies from `icecloud
//! serve` (the `{"key": ..., "rows": [...]}` shape) — joins their rows
//! by scenario name, and renders per-column absolute and relative
//! deltas.  The point is citability: "checkpointing cut wasted hours
//! 40% across the grid" should be one `icecloud diff` away from the two
//! sweeps that back it.
//!
//! Join semantics: rows match on exact scenario name; matched rows are
//! reported in the A-side's order; names present on only one side are
//! listed separately (`only_a` / `only_b`), never silently dropped.
//! Within a matched row the column set is the union of both sides — a
//! column missing on one side reads as NaN, which renders as an empty
//! CSV cell / JSON `null` rather than a fake zero.  Deltas are
//! `b - a` absolute and `100 * (b - a) / |a|` percent (NaN when the A
//! side is zero or either side is missing).

use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// One joined scenario: column name → (A value, B value).
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    pub name: String,
    pub cells: BTreeMap<String, (f64, f64)>,
}

/// The full join of two sweep result sets.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepDiff {
    /// Scenarios present on both sides, in the A-side's row order.
    pub rows: Vec<DiffRow>,
    /// Scenario names only the A side has, in A order.
    pub only_a: Vec<String>,
    /// Scenario names only the B side has, in B order.
    pub only_b: Vec<String>,
}

/// A parsed result set: rows in document order.
pub type Rows = Vec<(String, BTreeMap<String, f64>)>;

/// Parse a sweep result document.  Accepts either a bare JSON array of
/// row objects (`sweep.json`) or an object with a `rows` array (the
/// server's `/results/<key>` body).  Every row needs a string `name`;
/// every other field must be a number or `null` (the JSON writer emits
/// NaN as `null`).  Duplicate names are an error — the join would be
/// ambiguous.
pub fn parse_rows(text: &str) -> Result<Rows, String> {
    let doc = crate::util::json::parse(text).map_err(|e| e.to_string())?;
    let arr = match &doc {
        Json::Arr(items) => items.as_slice(),
        Json::Obj(_) => doc
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or("document has no 'rows' array")?,
        _ => return Err("document is not a sweep result".into()),
    };
    let mut out: Rows = Vec::with_capacity(arr.len());
    let mut seen = BTreeSet::new();
    for (i, row) in arr.iter().enumerate() {
        let obj = row
            .as_obj()
            .ok_or_else(|| format!("row {i} is not an object"))?;
        let name = obj
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("row {i} has no string 'name'"))?
            .to_string();
        if !seen.insert(name.clone()) {
            return Err(format!("duplicate scenario name '{name}'"));
        }
        let mut cols = BTreeMap::new();
        for (key, v) in obj {
            if key == "name" {
                continue;
            }
            let v = match v {
                Json::Num(n) => *n,
                Json::Null => f64::NAN,
                _ => {
                    return Err(format!(
                        "row '{name}' column '{key}' is not numeric"
                    ))
                }
            };
            cols.insert(key.clone(), v);
        }
        out.push((name, cols));
    }
    Ok(out)
}

/// Join two parsed result sets by scenario name.
pub fn diff(a: &Rows, b: &Rows) -> SweepDiff {
    let b_by_name: BTreeMap<&str, &BTreeMap<String, f64>> =
        b.iter().map(|(n, c)| (n.as_str(), c)).collect();
    let a_names: BTreeSet<&str> =
        a.iter().map(|(n, _)| n.as_str()).collect();
    let mut rows = Vec::new();
    let mut only_a = Vec::new();
    for (name, ac) in a {
        let Some(bc) = b_by_name.get(name.as_str()) else {
            only_a.push(name.clone());
            continue;
        };
        let mut cells = BTreeMap::new();
        for col in ac.keys().chain(bc.keys()) {
            if cells.contains_key(col) {
                continue;
            }
            let av = ac.get(col).copied().unwrap_or(f64::NAN);
            let bv = bc.get(col).copied().unwrap_or(f64::NAN);
            cells.insert(col.clone(), (av, bv));
        }
        rows.push(DiffRow { name: name.clone(), cells });
    }
    let only_b = b
        .iter()
        .filter(|(n, _)| !a_names.contains(n.as_str()))
        .map(|(n, _)| n.clone())
        .collect();
    SweepDiff { rows, only_a, only_b }
}

fn delta(a: f64, b: f64) -> f64 {
    b - a
}

fn delta_pct(a: f64, b: f64) -> f64 {
    if a == 0.0 {
        f64::NAN
    } else {
        100.0 * (b - a) / a.abs()
    }
}

/// Did this cell actually change?  Two NaNs (both sides missing or
/// undefined) count as unchanged.
fn changed(a: f64, b: f64) -> bool {
    !(a == b || (a.is_nan() && b.is_nan()))
}

/// Number formatting shared with every other emitter: the JSON writer's
/// (`29000` not `29000.0`, NaN as `null` in JSON / empty in CSV).
fn fmt_num(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        Json::from(v).to_string_compact()
    }
}

/// Human-readable diff: one block per joined scenario listing only the
/// columns that changed, then the one-sided scenario lists.
pub fn render(d: &SweepDiff) -> String {
    let mut out = String::new();
    out.push_str("DIFF — sweep A vs sweep B (delta = B - A)\n");
    let mut changed_rows = 0usize;
    for row in &d.rows {
        let hot: Vec<(&String, &(f64, f64))> = row
            .cells
            .iter()
            .filter(|(_, (a, b))| changed(*a, *b))
            .collect();
        if hot.is_empty() {
            continue;
        }
        changed_rows += 1;
        out.push_str(&format!("\n{}\n", row.name));
        let col_w = hot
            .iter()
            .map(|(c, _)| c.len())
            .max()
            .unwrap_or(0)
            .max(6);
        for (col, (a, b)) in hot {
            let pct = delta_pct(*a, *b);
            let pct = if pct.is_nan() {
                String::new()
            } else {
                format!(" ({pct:+.1}%)")
            };
            out.push_str(&format!(
                "  {:<col_w$}  {} -> {}  delta {}{}\n",
                col,
                fmt_num(*a),
                fmt_num(*b),
                fmt_num(delta(*a, *b)),
                pct,
            ));
        }
    }
    out.push_str(&format!(
        "\n{} scenarios joined, {} changed, {} only in A, {} only in B\n",
        d.rows.len(),
        changed_rows,
        d.only_a.len(),
        d.only_b.len()
    ));
    for n in &d.only_a {
        out.push_str(&format!("  only in A: {n}\n"));
    }
    for n in &d.only_b {
        out.push_str(&format!("  only in B: {n}\n"));
    }
    out
}

/// Long-format CSV: one line per (scenario, column) pair, *all*
/// columns (changed or not), NaN cells empty.
pub fn to_csv(d: &SweepDiff) -> String {
    let mut out = String::from("scenario,column,a,b,delta,delta_pct\n");
    let cell = |v: f64| {
        if v.is_nan() {
            String::new()
        } else {
            Json::from(v).to_string_compact()
        }
    };
    for row in &d.rows {
        for (col, (a, b)) in &row.cells {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                super::csv_field(&row.name),
                super::csv_field(col),
                cell(*a),
                cell(*b),
                cell(delta(*a, *b)),
                cell(delta_pct(*a, *b)),
            ));
        }
    }
    out
}

/// Machine-readable diff.  NaN serializes as `null` (the JSON writer's
/// contract), so missing-on-one-side cells are explicit.
pub fn to_json(d: &SweepDiff) -> Json {
    let mut o = Json::obj();
    o.set("joined", Json::from(d.rows.len()));
    o.set(
        "only_a",
        Json::Arr(d.only_a.iter().map(|n| Json::from(n.as_str())).collect()),
    );
    o.set(
        "only_b",
        Json::Arr(d.only_b.iter().map(|n| Json::from(n.as_str())).collect()),
    );
    let rows = d
        .rows
        .iter()
        .map(|row| {
            let mut r = Json::obj();
            r.set("name", Json::from(row.name.as_str()));
            let mut cols = Json::obj();
            for (col, (a, b)) in &row.cells {
                let mut c = Json::obj();
                c.set("a", Json::from(*a));
                c.set("b", Json::from(*b));
                c.set("delta", Json::from(delta(*a, *b)));
                c.set("delta_pct", Json::from(delta_pct(*a, *b)));
                cols.set(col, c);
            }
            r.set("columns", cols);
            r
        })
        .collect();
    o.set("rows", Json::Arr(rows));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_json(rows: &[(&str, &[(&str, f64)])]) -> String {
        let arr: Vec<Json> = rows
            .iter()
            .map(|(name, cols)| {
                let mut o = Json::obj();
                o.set("name", Json::from(*name));
                for (k, v) in *cols {
                    o.set(k, Json::from(*v));
                }
                o
            })
            .collect();
        Json::Arr(arr).to_string_compact()
    }

    #[test]
    fn parses_array_and_results_body_shapes() {
        let arr = rows_json(&[("a", &[("cost_usd", 10.0)])]);
        let from_arr = parse_rows(&arr).unwrap();
        assert_eq!(from_arr.len(), 1);
        assert_eq!(from_arr[0].1["cost_usd"], 10.0);
        let body = format!("{{\"key\": \"abc\", \"rows\": {arr}}}");
        assert_eq!(parse_rows(&body).unwrap(), from_arr);
        // null (the writer's NaN) is a missing value, not an error
        let with_null = r#"[{"name": "a", "cost_per_eflop_hour": null}]"#;
        let r = parse_rows(with_null).unwrap();
        assert!(r[0].1["cost_per_eflop_hour"].is_nan());
    }

    #[test]
    fn malformed_documents_rejected() {
        for bad in [
            "42",
            "[42]",
            r#"[{"cost_usd": 1}]"#,
            r#"[{"name": "a"}, {"name": "a"}]"#,
            r#"[{"name": "a", "cost_usd": "ten"}]"#,
            r#"{"key": "abc"}"#,
            "not json",
        ] {
            assert!(parse_rows(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn join_reports_deltas_and_one_sided_rows() {
        let a = parse_rows(&rows_json(&[
            ("base", &[("cost_usd", 100.0), ("gpu_days", 8.0)]),
            ("gone", &[("cost_usd", 1.0)]),
        ]))
        .unwrap();
        let b = parse_rows(&rows_json(&[
            ("base", &[("cost_usd", 150.0), ("gpu_days", 8.0)]),
            ("new", &[("cost_usd", 2.0)]),
        ]))
        .unwrap();
        let d = diff(&a, &b);
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.only_a, vec!["gone".to_string()]);
        assert_eq!(d.only_b, vec!["new".to_string()]);
        assert_eq!(d.rows[0].cells["cost_usd"], (100.0, 150.0));
        assert_eq!(delta(100.0, 150.0), 50.0);
        assert_eq!(delta_pct(100.0, 150.0), 50.0);
        // unchanged cells join but don't count as changed
        assert!(!changed(8.0, 8.0));
        assert!(changed(8.0, 9.0));
        assert!(!changed(f64::NAN, f64::NAN));
        assert!(changed(8.0, f64::NAN));
    }

    #[test]
    fn golden_render_csv_json() {
        let a = parse_rows(&rows_json(&[(
            "base",
            &[("cost_usd", 100.0), ("gpu_days", 8.0)],
        )]))
        .unwrap();
        let b = parse_rows(&rows_json(&[(
            "base",
            &[("cost_usd", 150.0), ("gpu_days", 8.0)],
        )]))
        .unwrap();
        let d = diff(&a, &b);

        let txt = render(&d);
        assert!(txt.contains("base"), "{txt}");
        assert!(
            txt.contains("cost_usd  100 -> 150  delta 50 (+50.0%)"),
            "{txt}"
        );
        // unchanged column is not listed in the table
        assert!(!txt.contains("gpu_days"), "{txt}");
        assert!(txt.contains("1 scenarios joined, 1 changed"), "{txt}");

        let csv = to_csv(&d);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "scenario,column,a,b,delta,delta_pct");
        // CSV carries every column, changed or not, in sorted order
        assert_eq!(lines[1], "base,cost_usd,100,150,50,50");
        assert_eq!(lines[2], "base,gpu_days,8,8,0,0");
        assert_eq!(lines.len(), 3);

        let j = to_json(&d);
        assert_eq!(j.get("joined").unwrap().as_u64(), Some(1));
        let cell = j
            .get_path(&["rows"])
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get_path(&["columns", "cost_usd"])
            .unwrap();
        assert_eq!(cell.get("a").unwrap().as_f64(), Some(100.0));
        assert_eq!(cell.get("delta").unwrap().as_f64(), Some(50.0));
        assert_eq!(cell.get("delta_pct").unwrap().as_f64(), Some(50.0));
        // deterministic output
        assert_eq!(
            to_json(&d).to_string_compact(),
            j.to_string_compact()
        );
    }

    #[test]
    fn zero_baseline_and_missing_columns_render_safely() {
        let a = parse_rows(&rows_json(&[(
            "s",
            &[("nat_drops", 0.0)],
        )]))
        .unwrap();
        let b = parse_rows(&rows_json(&[(
            "s",
            &[("nat_drops", 5.0), ("extra", 1.0)],
        )]))
        .unwrap();
        let d = diff(&a, &b);
        // a == 0: percent is undefined, not infinite
        assert!(delta_pct(0.0, 5.0).is_nan());
        let csv = to_csv(&d);
        // NaN cells are empty, never "NaN"
        assert!(csv.contains("s,nat_drops,0,5,5,\n"), "{csv}");
        assert!(csv.contains("s,extra,,1,,\n"), "{csv}");
        // JSON: missing-side cells are null
        let j = to_json(&d).to_string_compact();
        assert!(j.contains("\"a\":null"), "{j}");
        // hostile scenario names stay one CSV field
        let a = parse_rows(&rows_json(&[("a,b", &[("x", 1.0)])])).unwrap();
        let b2 = parse_rows(&rows_json(&[("a,b", &[("x", 2.0)])])).unwrap();
        let csv = to_csv(&diff(&a, &b2));
        assert!(csv.contains("\"a,b\",x,1,2,1,100\n"), "{csv}");
    }
}
