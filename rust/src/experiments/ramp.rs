//! EXP-RAMP — the validation phase: per-provider stability at small scale.
//!
//! §IV: "we initially provisioned a small number of VMs in each of the
//! targeted Cloud regions ... We spent the next few days slowly raising
//! the number of instances in each of the targeted Cloud regions and
//! monitoring the preemption rate. We were pleasantly surprised to find
//! Azure ... to have plenty of spare capacity with very low preemption
//! rates. We thus heavily favored Azure during most of the exercise."
//!
//! The harness runs a uniform (non-favoring) fleet and reports the
//! price / fulfilment / preemption table the operators used to pick the
//! Azure-heavy weights — plus an ablation comparing the resulting
//! policies' delivered GPU-hours per dollar.

use crate::cloud::Provider;
use crate::config::{CampaignConfig, PolicyMode, ProviderWeights, RampStep};
use crate::coordinator::Campaign;
use crate::sim::DAY;
use std::path::Path;

/// One provider's validation-phase observation.
#[derive(Debug, Clone)]
pub struct RampRow {
    pub provider: String,
    pub price_per_day: f64,
    pub instance_hours: f64,
    pub preemptions: u64,
    pub preempts_per_inst_hour: f64,
}

/// Policy-ablation entry.
#[derive(Debug, Clone)]
pub struct PolicyAblation {
    pub policy: String,
    pub gpu_hours: f64,
    pub cost_usd: f64,
    pub gpu_hours_per_usd: f64,
    pub interrupts: u64,
}

fn validation_config(total: u32, days: u64, policy: PolicyMode) -> CampaignConfig {
    let mut c = CampaignConfig::default();
    c.seed = 4242;
    c.duration_s = days * DAY;
    c.outage = None;
    c.onprem.slots = 0;
    c.ramp = vec![RampStep { target: total, hold_s: 60 * DAY }];
    c.policy = policy;
    c.generator.min_backlog = (total as usize) * 2;
    c
}

/// Run the uniform validation fleet and tabulate per-provider rates.
pub fn run_validation(total: u32, days: u64) -> Vec<RampRow> {
    let uniform = PolicyMode::Fixed(ProviderWeights {
        aws: 1.0 / 3.0,
        gcp: 1.0 / 3.0,
        azure: 1.0 / 3.0,
    });
    let result = Campaign::new(validation_config(total, days, uniform)).run();
    let prices = [3.8, 3.5, 2.9];
    Provider::ALL
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (_, preempts, hours) = result.provider_ops[i];
            RampRow {
                provider: p.name().to_string(),
                price_per_day: prices[i],
                instance_hours: hours,
                preemptions: preempts,
                preempts_per_inst_hour: if hours > 0.0 {
                    preempts as f64 / hours
                } else {
                    0.0
                },
            }
        })
        .collect()
}

/// Ablation: uniform vs Azure-favoring vs adaptive policy.
pub fn run_policy_ablation(total: u32, days: u64) -> Vec<PolicyAblation> {
    let policies: Vec<(&str, PolicyMode)> = vec![
        (
            "uniform",
            PolicyMode::Fixed(ProviderWeights {
                aws: 1.0 / 3.0,
                gcp: 1.0 / 3.0,
                azure: 1.0 / 3.0,
            }),
        ),
        (
            "azure-favored (paper)",
            PolicyMode::Fixed(ProviderWeights { aws: 0.15, gcp: 0.15, azure: 0.7 }),
        ),
        ("adaptive", PolicyMode::Adaptive),
    ];
    policies
        .into_iter()
        .map(|(name, policy)| {
            let result =
                Campaign::new(validation_config(total, days, policy)).run();
            let hours = result.meter.total_instance_hours();
            let cost = result.ledger.total_spent();
            PolicyAblation {
                policy: name.to_string(),
                gpu_hours: hours,
                cost_usd: cost,
                gpu_hours_per_usd: if cost > 0.0 { hours / cost } else { 0.0 },
                interrupts: result.schedd_stats.interrupted,
            }
        })
        .collect()
}

pub fn render(rows: &[RampRow], ablation: &[PolicyAblation]) -> String {
    let mut out = String::new();
    out.push_str("RAMP — validation phase: per-provider spot behaviour\n");
    out.push_str(&format!(
        "{:<8} {:>10} {:>14} {:>12} {:>18}\n",
        "provider", "$/T4-day", "inst-hours", "preemptions", "preempts/inst-h"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<8} {:>10.2} {:>14.1} {:>12} {:>18.4}\n",
            r.provider,
            r.price_per_day,
            r.instance_hours,
            r.preemptions,
            r.preempts_per_inst_hour
        ));
    }
    out.push_str("\npolicy ablation (same total target):\n");
    out.push_str(&format!(
        "{:<24} {:>12} {:>10} {:>14} {:>10}\n",
        "policy", "GPU-hours", "cost $", "GPUh per $", "interrupts"
    ));
    for a in ablation {
        out.push_str(&format!(
            "{:<24} {:>12.0} {:>10.0} {:>14.2} {:>10}\n",
            a.policy, a.gpu_hours, a.cost_usd, a.gpu_hours_per_usd, a.interrupts
        ));
    }
    out
}

pub fn to_csv(rows: &[RampRow]) -> String {
    let mut out = String::from(
        "provider,price_per_day,instance_hours,preemptions,preempts_per_inst_hour\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            r.provider,
            r.price_per_day,
            r.instance_hours,
            r.preemptions,
            r.preempts_per_inst_hour
        ));
    }
    out
}

pub fn write(out_root: &Path) -> std::io::Result<(Vec<RampRow>, Vec<PolicyAblation>)> {
    let rows = run_validation(300, 2);
    let ablation = run_policy_ablation(300, 2);
    let dir = super::exp_dir(out_root, "ramp")?;
    super::write_output(&dir, "ramp.csv", &to_csv(&rows))?;
    super::write_output(&dir, "ramp.txt", &render(&rows, &ablation))?;
    Ok((rows, ablation))
}

/// Shape check: Azure is cheapest AND most stable — the basis of the
/// paper's Azure-favoring decision.
pub fn check_azure_wins(rows: &[RampRow]) -> Result<(), String> {
    let get = |name: &str| rows.iter().find(|r| r.provider == name).unwrap();
    let azure = get("azure");
    let aws = get("aws");
    let gcp = get("gcp");
    if !(azure.price_per_day < aws.price_per_day
        && azure.price_per_day < gcp.price_per_day)
    {
        return Err("azure must be cheapest".into());
    }
    if !(azure.preempts_per_inst_hour <= aws.preempts_per_inst_hour
        && azure.preempts_per_inst_hour <= gcp.preempts_per_inst_hour)
    {
        return Err(format!(
            "azure must preempt least: az={:.4} aws={:.4} gcp={:.4}",
            azure.preempts_per_inst_hour,
            aws.preempts_per_inst_hour,
            gcp.preempts_per_inst_hour
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_shows_azure_advantage() {
        let rows = run_validation(150, 1);
        check_azure_wins(&rows).unwrap();
    }

    #[test]
    fn azure_favoring_beats_uniform_on_cost() {
        let ablation = run_policy_ablation(120, 1);
        let uniform = &ablation[0];
        let favored = &ablation[1];
        assert!(
            favored.gpu_hours_per_usd > uniform.gpu_hours_per_usd,
            "favored {:.3} must beat uniform {:.3} GPUh/$",
            favored.gpu_hours_per_usd,
            uniform.gpu_hours_per_usd
        );
    }

    #[test]
    fn renders() {
        let rows = run_validation(60, 1);
        let ablation = run_policy_ablation(60, 1);
        let txt = render(&rows, &ablation);
        assert!(txt.contains("azure"));
        assert!(txt.contains("policy ablation"));
        assert_eq!(to_csv(&rows).lines().count(), 4);
    }
}
