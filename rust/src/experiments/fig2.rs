//! EXP-F2 — Fig 2: daily GPU wall hours, on-prem vs on-prem + cloud.
//!
//! Paper claim: "we more than doubled the number of GPU hours that
//! IceCube had access to" over the two-week period.

use crate::coordinator::CampaignResult;
use crate::monitoring::daily_bars;
use crate::osg::UsageAccounting;
use std::path::Path;

pub struct Fig2 {
    /// (day, onprem GPUh, cloud GPUh)
    pub days: Vec<(u32, f64, f64)>,
    pub total_onprem: f64,
    pub total_cloud: f64,
    pub expansion_factor: f64,
}

pub fn extract(result: &CampaignResult) -> Fig2 {
    let days = result
        .usage
        .days()
        .iter()
        .map(|d| (d.day, d.onprem_gpu_hours, d.cloud_gpu_hours))
        .collect();
    Fig2 {
        days,
        total_onprem: result.usage.total_onprem_gpu_hours(),
        total_cloud: result.usage.total_cloud_gpu_hours(),
        expansion_factor: result.usage.expansion_factor(),
    }
}

impl Fig2 {
    pub fn chart(&self) -> String {
        let mut out = daily_bars(
            "Fig 2 — daily IceCube GPU wall hours (onprem + cloud)",
            &self.days,
            70,
        );
        out.push_str(&format!(
            "  totals: onprem {:.0} GPUh, cloud {:.0} GPUh — expansion {:.2}x\n",
            self.total_onprem, self.total_cloud, self.expansion_factor
        ));
        out.push_str(&format!(
            "  cloud EFLOP-hours: {:.2} (fp32, T4 @ 8.1 TFLOPS)\n",
            UsageAccounting::eflop_hours(self.total_cloud)
        ));
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("day,onprem_gpu_hours,cloud_gpu_hours,total\n");
        for (d, onprem, cloud) in &self.days {
            out.push_str(&format!("{d},{onprem},{cloud},{}\n", onprem + cloud));
        }
        out
    }

    /// Peak-period expansion: the paper's doubling is most visible once
    /// the ramp is high; report the max single-day factor too.
    pub fn peak_day_factor(&self) -> f64 {
        self.days
            .iter()
            .filter(|(_, onprem, _)| *onprem > 0.0)
            .map(|(_, onprem, cloud)| (onprem + cloud) / onprem)
            .fold(0.0, f64::max)
    }
}

pub fn write(result: &CampaignResult, out_root: &Path) -> std::io::Result<Fig2> {
    let fig = extract(result);
    let dir = super::exp_dir(out_root, "fig2")?;
    super::write_output(&dir, "fig2.csv", &fig.to_csv())?;
    super::write_output(&dir, "fig2.txt", &fig.chart())?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CampaignConfig, RampStep};
    use crate::coordinator::Campaign;
    use crate::sim::{DAY, HOUR};

    fn mini_result() -> CampaignResult {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * DAY;
        c.ramp = vec![RampStep { target: 60, hold_s: 30 * DAY }];
        c.outage = None;
        c.onprem.slots = 50;
        c.generator.min_backlog = 200;
        // avoid matching delays distorting the tiny run
        c.negotiation_period_s = HOUR / 30;
        Campaign::new(c).run()
    }

    #[test]
    fn cloud_expands_capacity() {
        let fig = extract(&mini_result());
        assert_eq!(fig.days.len(), 2);
        assert!(fig.total_onprem > 0.0);
        assert!(fig.total_cloud > 0.0);
        assert!(fig.expansion_factor > 1.5, "factor={}", fig.expansion_factor);
        assert!(fig.peak_day_factor() >= fig.expansion_factor * 0.8);
    }

    #[test]
    fn renders() {
        let fig = extract(&mini_result());
        assert!(fig.chart().contains("Fig 2"));
        assert!(fig.to_csv().lines().count() >= 3);
    }
}
