//! Experiment harnesses: one regenerator per paper figure/table.
//!
//! | id       | paper artifact              | module      |
//! |----------|------------------------------|-------------|
//! | F1       | Fig 1 monitoring snapshot   | `fig1`      |
//! | F2       | Fig 2 GPU wall-hour doubling| `fig2`      |
//! | T1       | in-text headline numbers    | `headline`  |
//! | NAT      | §IV keepalive incident      | `nat`       |
//! | RAMP     | §IV validation/preemption   | `ramp`      |
//! | SWEEP    | what-if scenario matrix     | `sweep`     |
//! | DIFF     | sweep-vs-sweep deltas       | `diff`      |
//!
//! Each harness runs the campaign (or a reduced scenario), renders the
//! same rows/series the paper reports, and writes CSV/JSON/text into a
//! results directory.  EXPERIMENTS.md records paper-vs-measured.

pub mod diff;
pub mod fig1;
pub mod fig2;
pub mod headline;
pub mod nat;
pub mod ramp;
pub mod sweep;

use std::fs;
use std::path::{Path, PathBuf};

/// RFC-4180-quote one CSV field: fields containing a comma, a double
/// quote, or a line break are wrapped in quotes with embedded quotes
/// doubled; everything else passes through unchanged.  Scenario names
/// are attacker-ish input here — quoted TOML keys (`[scenario."a,b"]`)
/// and grid-synthesized names are legal and must not shift columns.
pub fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
    {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Create (if needed) and return the directory for one experiment.
pub fn exp_dir(out_root: &Path, exp: &str) -> std::io::Result<PathBuf> {
    let dir = out_root.join(exp);
    fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Write a file, logging the path to stdout.
pub fn write_output(dir: &Path, name: &str, content: &str) -> std::io::Result<()> {
    let path = dir.join(name);
    fs::write(&path, content)?;
    println!("  wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("baseline"), "baseline");
        assert_eq!(csv_field("a=1/b=2"), "a=1/b=2");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("two\nlines"), "\"two\nlines\"");
        assert_eq!(csv_field("a,b\"c"), "\"a,b\"\"c\"");
    }

    #[test]
    fn exp_dir_creates_nested() {
        let root = std::env::temp_dir().join("icecloud-exp-test");
        let d = exp_dir(&root, "fig1").unwrap();
        assert!(d.exists());
        write_output(&d, "x.txt", "hello").unwrap();
        assert_eq!(fs::read_to_string(d.join("x.txt")).unwrap(), "hello");
        let _ = fs::remove_dir_all(&root);
    }
}
