//! EXP-T1 — the paper's in-text headline numbers, as a table.
//!
//! "The total cost of this exercise was approximately $58k, all included,
//! which allowed us to deliver 16k GPU days or about 3.1 fp32 EFLOP hours
//! of compute." Plus the per-provider price/stability table implied by
//! §IV (Azure spot T4 at $2.9/day, lowest preemption, most capacity).

use crate::cloud::Provider;
use crate::coordinator::CampaignResult;
use crate::osg::UsageAccounting;
use crate::util::json::Json;
use std::path::Path;

/// The reproduced headline table.
#[derive(Debug, Clone)]
pub struct Headline {
    pub total_cost_usd: f64,
    pub gpu_days: f64,
    pub eflop_hours: f64,
    pub cost_per_eflop_hour: f64,
    pub expansion_factor: f64,
    pub jobs_completed: u64,
    pub goodput_fraction: f64,
    /// Per provider: (name, price $/T4-day, instance-hours, share,
    /// preempts per instance-hour).
    pub providers: Vec<(String, f64, f64, f64, f64)>,
    pub alerts_fired: usize,
}

pub fn extract(result: &CampaignResult) -> Headline {
    let gpu_hours = result.meter.total_instance_hours();
    let eflop_hours = UsageAccounting::eflop_hours(gpu_hours);
    let total_cost = result.ledger.total_spent();
    let prices = [3.8, 3.5, 2.9]; // aws, gcp, azure $/T4-day
    let providers = Provider::ALL
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (_, preempts, hours) = result.provider_ops[i];
            (
                p.name().to_string(),
                prices[i],
                hours,
                if gpu_hours > 0.0 { hours / gpu_hours } else { 0.0 },
                if hours > 0.0 { preempts as f64 / hours } else { 0.0 },
            )
        })
        .collect();
    let good = result.schedd_stats.goodput_s as f64;
    let bad = result.schedd_stats.badput_s as f64;
    Headline {
        total_cost_usd: total_cost,
        gpu_days: gpu_hours / 24.0,
        eflop_hours,
        cost_per_eflop_hour: if eflop_hours > 0.0 {
            total_cost / eflop_hours
        } else {
            f64::NAN
        },
        expansion_factor: result.usage.expansion_factor(),
        jobs_completed: result.schedd_stats.completed,
        goodput_fraction: if good + bad > 0.0 { good / (good + bad) } else { 1.0 },
        providers,
        alerts_fired: result.ledger.alerts().len(),
    }
}

impl Headline {
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str("T1 — headline numbers (paper vs measured shape)\n");
        out.push_str(&format!(
            "{:<28} {:>12} {:>12}\n",
            "metric", "paper", "measured"
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.0}\n",
            "total cost (USD)", "~58000", self.total_cost_usd
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.0}\n",
            "GPU-days delivered", "~16000", self.gpu_days
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.2}\n",
            "fp32 EFLOP-hours", "~3.1", self.eflop_hours
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.2}\n",
            "GPU-hour expansion", "~2x", self.expansion_factor
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.0}\n",
            "$ per EFLOP-hour", "~18700", self.cost_per_eflop_hour
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12}\n",
            "jobs completed", "-", self.jobs_completed
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12.3}\n",
            "goodput fraction", "-", self.goodput_fraction
        ));
        out.push_str(&format!(
            "{:<28} {:>12} {:>12}\n",
            "CloudBank alerts fired", "-", self.alerts_fired
        ));
        out.push('\n');
        out.push_str("per-provider (spot T4):\n");
        out.push_str(&format!(
            "{:<8} {:>10} {:>14} {:>8} {:>16}\n",
            "provider", "$/T4-day", "inst-hours", "share", "preempts/inst-h"
        ));
        for (name, price, hours, share, preempt) in &self.providers {
            out.push_str(&format!(
                "{:<8} {:>10.2} {:>14.0} {:>7.1}% {:>16.4}\n",
                name,
                price,
                hours,
                share * 100.0,
                preempt
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("total_cost_usd", Json::from(self.total_cost_usd));
        o.set("gpu_days", Json::from(self.gpu_days));
        o.set("eflop_hours", Json::from(self.eflop_hours));
        o.set("cost_per_eflop_hour", Json::from(self.cost_per_eflop_hour));
        o.set("expansion_factor", Json::from(self.expansion_factor));
        o.set("jobs_completed", Json::from(self.jobs_completed));
        o.set("goodput_fraction", Json::from(self.goodput_fraction));
        o.set("alerts_fired", Json::from(self.alerts_fired));
        let provs: Vec<Json> = self
            .providers
            .iter()
            .map(|(name, price, hours, share, preempt)| {
                let mut p = Json::obj();
                p.set("provider", Json::from(name.as_str()));
                p.set("price_per_t4_day", Json::from(*price));
                p.set("instance_hours", Json::from(*hours));
                p.set("share", Json::from(*share));
                p.set("preempts_per_hour", Json::from(*preempt));
                p
            })
            .collect();
        o.set("providers", Json::Arr(provs));
        o
    }

    /// Shape assertions the reproduction must satisfy.
    pub fn check_shape(&self) -> Result<(), String> {
        let azure = self.providers.iter().find(|p| p.0 == "azure").unwrap();
        let aws = self.providers.iter().find(|p| p.0 == "aws").unwrap();
        let gcp = self.providers.iter().find(|p| p.0 == "gcp").unwrap();
        if !(azure.1 < aws.1 && azure.1 < gcp.1) {
            return Err("azure must be cheapest".into());
        }
        if !(azure.3 > aws.3 && azure.3 > gcp.3) {
            return Err("azure must carry the largest share".into());
        }
        if !(azure.4 <= aws.4 && azure.4 <= gcp.4) {
            return Err(format!(
                "azure preemption ({:.4}) must be lowest (aws {:.4}, gcp {:.4})",
                azure.4, aws.4, gcp.4
            ));
        }
        Ok(())
    }
}

pub fn write(result: &CampaignResult, out_root: &Path) -> std::io::Result<Headline> {
    let h = extract(result);
    let dir = super::exp_dir(out_root, "headline")?;
    super::write_output(&dir, "headline.txt", &h.table())?;
    super::write_output(&dir, "headline.json", &h.to_json().to_string_pretty())?;
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CampaignConfig, RampStep};
    use crate::coordinator::Campaign;
    use crate::sim::DAY;

    fn mini_result() -> CampaignResult {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * DAY;
        c.ramp = vec![RampStep { target: 90, hold_s: 30 * DAY }];
        c.outage = None;
        c.onprem.slots = 40;
        c.generator.min_backlog = 150;
        Campaign::new(c).run()
    }

    #[test]
    fn headline_math_is_consistent() {
        let h = extract(&mini_result());
        assert!(h.total_cost_usd > 0.0);
        assert!(h.gpu_days > 0.0);
        // eflop-hours must equal gpu-hours * 8.1/1e6
        let expect = h.gpu_days * 24.0 * 8.1 / 1e6;
        assert!((h.eflop_hours - expect).abs() < 1e-9);
        assert!((h.cost_per_eflop_hour - h.total_cost_usd / h.eflop_hours).abs()
            < 1e-6);
        assert!(h.goodput_fraction > 0.9);
    }

    #[test]
    fn shape_holds_in_mini_campaign() {
        let h = extract(&mini_result());
        h.check_shape().unwrap();
    }

    #[test]
    fn renders_table_and_json() {
        let h = extract(&mini_result());
        let t = h.table();
        assert!(t.contains("total cost"));
        assert!(t.contains("azure"));
        let j = h.to_json().to_string_pretty();
        assert!(crate::util::json::parse(&j).is_ok());
    }
}
