//! AOT artifact metadata and Rust-side input builders.
//!
//! `artifacts/meta.json` (written by `python -m compile.aot`) describes
//! every compiled variant: shapes, FLOP estimate, file name.  The input
//! builders mirror `python/compile/geometry.py` so the Rust hot path can
//! synthesize the same detector geometry and ice model the pytest oracle
//! validated.

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Constants mirrored from python/compile/geometry.py.
pub const DOM_SPACING_M: f32 = 17.0;
pub const R_DOM_EFF: f32 = 0.16510 * 12.0;
pub const V_GROUP_M_NS: f32 = 0.299_792_458 / 1.35;
pub const N_LAYERS: usize = 10;

/// One compiled variant's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    pub file: String,
    pub num_photons: u64,
    pub block: u64,
    pub num_doms: u64,
    pub num_steps: u64,
    pub num_layers: u64,
    pub flops_estimate: f64,
}

impl VariantMeta {
    /// A synthetic (artifact-less) variant: the shape plus the analytic
    /// FLOP estimate from `geometry.Variant.flops_estimate` (~170 flops
    /// of RNG/transport/scattering per photon-step plus ~15 per DOM
    /// test).  The single source of the shape tables used by
    /// `icecloud parity` and the engine benches.
    pub fn synthetic(
        name: &str,
        num_photons: u64,
        block: u64,
        num_doms: u64,
        num_steps: u64,
    ) -> VariantMeta {
        let per_step = 170.0 + 15.0 * num_doms as f64;
        VariantMeta {
            name: name.to_string(),
            file: "synthetic".into(),
            num_photons,
            block,
            num_doms,
            num_steps,
            num_layers: N_LAYERS as u64,
            flops_estimate: num_photons as f64 * num_steps as f64 * per_step,
        }
    }
}

/// Parsed artifacts/meta.json.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub variants: Vec<VariantMeta>,
}

impl ArtifactMeta {
    /// Load from `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .map_err(|e| format!("cannot read {}: {e}", meta_path.display()))?;
        let root = json::parse(&text).map_err(|e| e.to_string())?;
        let variants_obj = root
            .get("variants")
            .and_then(Json::as_obj)
            .ok_or("meta.json: missing 'variants' object")?;
        let mut variants = Vec::new();
        for (name, v) in variants_obj {
            let get = |key: &str| -> Result<f64, String> {
                v.get(key)
                    .and_then(Json::as_f64)
                    .ok_or(format!("meta.json: variant {name} missing {key}"))
            };
            variants.push(VariantMeta {
                name: name.clone(),
                file: v
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or(format!("variant {name} missing file"))?
                    .to_string(),
                num_photons: get("num_photons")? as u64,
                block: get("block")? as u64,
                num_doms: get("num_doms")? as u64,
                num_steps: get("num_steps")? as u64,
                num_layers: get("num_layers")? as u64,
                flops_estimate: get("flops_estimate")?,
            });
        }
        variants.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ArtifactMeta { dir: dir.to_path_buf(), variants })
    }

    pub fn variant(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    pub fn hlo_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.file)
    }
}

/// Inputs for one artifact execution (mirrors geometry.variant_inputs).
#[derive(Debug, Clone)]
pub struct PhotonInputs {
    pub source: [f32; 8],
    /// Row-major `[num_layers][4]`: scat_len, abs_len, g, pad.
    pub media: Vec<f32>,
    /// Row-major `[num_doms][3]`.
    pub doms: Vec<f32>,
    pub params: [f32; 8],
}

/// Build DOM positions: single string for <=80 DOMs, 2x2 string grid above.
pub fn dom_positions(num_doms: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(num_doms * 3);
    if num_doms <= 80 {
        for i in 0..num_doms {
            out.extend_from_slice(&[0.0, 0.0, -DOM_SPACING_M * i as f32]);
        }
    } else {
        let per = num_doms / 4;
        let pitch = 125.0f32;
        for ix in 0..2 {
            for iy in 0..2 {
                let x = ix as f32 * pitch - pitch / 2.0;
                let y = iy as f32 * pitch - pitch / 2.0;
                for i in 0..per {
                    out.extend_from_slice(&[x, y, -DOM_SPACING_M * i as f32]);
                }
            }
        }
        out.truncate(num_doms * 3);
    }
    out
}

/// Layered ice with the default dust layer (mirrors geometry.layered_ice).
pub fn layered_ice(num_layers: usize, dusty: bool) -> Vec<f32> {
    let mut media = Vec::with_capacity(num_layers * 4);
    for _ in 0..num_layers {
        media.extend_from_slice(&[25.0, 100.0, 0.9, 0.0]);
    }
    if dusty && num_layers >= 3 {
        let mid = num_layers / 2;
        media[mid * 4] = 5.0;
        media[mid * 4 + 1] = 20.0;
    }
    media
}

/// Build the full input set for a variant + seed.
pub fn build_inputs(v: &VariantMeta, seed: u32, dusty: bool) -> PhotonInputs {
    let doms = dom_positions(v.num_doms as usize);
    // mean z of the DOM array
    let mut mid_z = 0.0f32;
    for i in 0..v.num_doms as usize {
        mid_z += doms[i * 3 + 2];
    }
    mid_z /= v.num_doms as f32;

    let depth_span = DOM_SPACING_M * (v.num_doms as f32 + 4.0);
    let params = [
        R_DOM_EFF,
        40.0,
        depth_span / N_LAYERS as f32,
        V_GROUP_M_NS,
        1e-7,
        0.0,
        0.0,
        0.0,
    ];
    let source = [10.0, 0.0, mid_z, 0.0, 0.0, 0.0, 0.0, seed as f32];
    PhotonInputs {
        source,
        media: layered_ice(v.num_layers as usize, dusty),
        doms,
        params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn loads_repo_meta_if_built() {
        let Some(dir) = meta_dir() else { return };
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert!(meta.variant("default").is_some());
        let v = meta.variant("default").unwrap();
        assert_eq!(v.num_photons, 4096);
        assert_eq!(v.num_doms, 60);
        assert!(v.flops_estimate > 0.0);
        assert!(meta.hlo_path(v).exists());
    }

    #[test]
    fn parses_meta_from_string_fixture() {
        let dir = std::env::temp_dir().join("icecloud-meta-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"artifact_version":1,"variants":{"tiny":{
                "file":"photon_tiny.hlo.txt","num_photons":64,"block":32,
                "num_doms":8,"num_steps":4,"num_layers":10,"grid":2,
                "flops_estimate":74240.0,"inputs":[],"outputs":[]}}}"#,
        )
        .unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        let v = meta.variant("tiny").unwrap();
        assert_eq!(v.block, 32);
        assert_eq!(v.num_steps, 4);
        assert_eq!(v.flops_estimate, 74240.0);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(ArtifactMeta::load(Path::new("/nonexistent-xyz")).is_err());
    }

    #[test]
    fn dom_positions_single_string() {
        let doms = dom_positions(60);
        assert_eq!(doms.len(), 180);
        assert_eq!(doms[0..3], [0.0, 0.0, 0.0]);
        assert_eq!(doms[3 * 59 + 2], -17.0 * 59.0);
    }

    #[test]
    fn dom_positions_grid_for_large() {
        let doms = dom_positions(240);
        assert_eq!(doms.len(), 720);
        // four distinct (x, y) columns
        let mut cols = std::collections::BTreeSet::new();
        for i in 0..240 {
            cols.insert((doms[i * 3] as i32, doms[i * 3 + 1] as i32));
        }
        assert_eq!(cols.len(), 4);
    }

    #[test]
    fn ice_has_dust_layer() {
        let media = layered_ice(10, true);
        assert_eq!(media.len(), 40);
        assert_eq!(media[5 * 4], 5.0); // dust scattering length
        let clear = layered_ice(10, false);
        assert_eq!(clear[5 * 4], 25.0);
    }

    #[test]
    fn inputs_match_python_layout() {
        let v = VariantMeta {
            name: "x".into(),
            file: "f".into(),
            num_photons: 256,
            block: 128,
            num_doms: 16,
            num_steps: 16,
            num_layers: 10,
            flops_estimate: 1.0,
        };
        let inp = build_inputs(&v, 7, true);
        assert_eq!(inp.source[7], 7.0);
        assert_eq!(inp.source[0], 10.0);
        assert_eq!(inp.params[0], R_DOM_EFF);
        assert!((inp.params[3] - 0.2220685).abs() < 1e-5);
        assert_eq!(inp.media.len(), 40);
        assert_eq!(inp.doms.len(), 48);
        // source z is the mean DOM depth
        let mean_z: f32 = (0..16).map(|i| -17.0 * i as f32).sum::<f32>() / 16.0;
        assert!((inp.source[2] - mean_z).abs() < 1e-4);
    }
}
