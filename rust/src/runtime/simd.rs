//! Portable fixed-width f32 lanes for the batched segment sweep.
//!
//! The pass-B hot loop of [`super::batch`] evaluates
//! `engine::segment_test` for every (DOM, photon) pair — ~10 f32 ops
//! per pair, no transcendentals, no RNG.  The scalar-helper form leaves
//! vectorization to the compiler's judgement on a loop whose body ends
//! in a data-dependent branch; this module restructures the same math
//! into explicit [`LANES`]-wide operations over `[f32; LANES]` arrays —
//! fixed trip counts, no branches, no external crates — that the
//! autovectorizer lowers to packed instructions on any target
//! (DESIGN.md §18).
//!
//! **Bit-exactness.**  Every lane holds a *distinct photon*, and the
//! sweep has no horizontal reductions: each lane's `(t_along, dist2)`
//! is produced by exactly the scalar op sequence of
//! [`segment_test`](super::engine::segment_test) — same subtractions,
//! same left-associated dot products, same `clamp` — just evaluated
//! LANES photons at a time.  IEEE-754 ops are deterministic per lane,
//! so the lane path is bit-identical to the scalar helper for every
//! input, which is why [`SimdMode::Lanes`] ships as the default and
//! why `SimdMode` stays out of the campaign cache key (the pin lives
//! in `config::tests::engine_knobs_never_split_the_cache_key`, the
//! parity suite in `rust/tests/engine_parity.rs`).

/// Photons processed per lane-sweep iteration.  Eight f32 lanes span a
/// 256-bit vector register (AVX2, SVE-256) and fold to two 128-bit ops
/// on NEON/SSE targets; tails shorter than this fall back to the
/// scalar helper.
pub const LANES: usize = 8;

/// Which pass-B segment-sweep implementation the batched engine runs.
///
/// Both modes produce bit-identical results (see the module docs);
/// the knob trades wall time only, exactly like `ExecPlan::threads`,
/// and is therefore deliberately excluded from
/// `CampaignConfig::canonical_json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Scalar-helper sweep (the PR 3 baseline; autovectorization is
    /// left to the compiler).
    Off,
    /// Explicit-width lane sweep with a scalar tail (default: the
    /// parity suite proved it bit-identical to `run_scalar`).
    #[default]
    Lanes,
}

impl SimdMode {
    /// Strict parse of the `[engine] simd` / `--engine-simd` knob.
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s {
            "off" => Some(SimdMode::Off),
            "lanes" => Some(SimdMode::Lanes),
            _ => None,
        }
    }

    /// The TOML/CLI spelling (`parse` round-trips it).
    pub fn as_str(&self) -> &'static str {
        match self {
            SimdMode::Off => "off",
            SimdMode::Lanes => "lanes",
        }
    }
}

/// One vector of photon state: a fixed-width array the loop vectorizer
/// lowers to packed registers.
type V = [f32; LANES];

/// Broadcast one scalar across all lanes.
#[inline]
fn splat(x: f32) -> V {
    [x; LANES]
}

/// Load LANES contiguous values (caller guarantees `src.len() >= LANES`).
#[inline]
fn load(src: &[f32]) -> V {
    let mut v = [0.0f32; LANES];
    v.copy_from_slice(&src[..LANES]);
    v
}

#[inline]
fn sub(a: V, b: V) -> V {
    let mut o = [0.0f32; LANES];
    for l in 0..LANES {
        o[l] = a[l] - b[l];
    }
    o
}

#[inline]
fn mul(a: V, b: V) -> V {
    let mut o = [0.0f32; LANES];
    for l in 0..LANES {
        o[l] = a[l] * b[l];
    }
    o
}

#[inline]
fn add(a: V, b: V) -> V {
    let mut o = [0.0f32; LANES];
    for l in 0..LANES {
        o[l] = a[l] + b[l];
    }
    o
}

/// Elementwise `f32::clamp` — the same op the scalar helper applies,
/// so NaN/zero edge semantics cannot diverge between paths.
#[inline]
fn clamp(a: V, lo: V, hi: V) -> V {
    let mut o = [0.0f32; LANES];
    for l in 0..LANES {
        o[l] = a[l].clamp(lo[l], hi[l]);
    }
    o
}

/// Left-associated 3-component dot product, matching the scalar
/// helper's `a0*b0 + a1*b1 + a2*b2` evaluation order exactly (no FMA
/// contraction: separate mul and add ops, like the scalar expression).
#[inline]
fn dot3(ax: V, ay: V, az: V, bx: V, by: V, bz: V) -> V {
    add(add(mul(ax, bx), mul(ay, by)), mul(az, bz))
}

/// Segment–sphere closest-approach test for one DOM against LANES
/// photons: `(t_along, dist2)` per lane, `t_along` clamped to each
/// photon's step `[0, d]`.  The lane transcription of
/// [`segment_test`](super::engine::segment_test): identical op
/// sequence per lane, so identical bits per photon.
#[inline]
pub(crate) fn segment_test_lanes(
    dom: [f32; 3],
    px: &[f32],
    py: &[f32],
    pz: &[f32],
    dx: &[f32],
    dy: &[f32],
    dz: &[f32],
    d: &[f32],
) -> (V, V) {
    let (px, py, pz) = (load(px), load(py), load(pz));
    let (dx, dy, dz) = (load(dx), load(dy), load(dz));
    let relx = sub(splat(dom[0]), px);
    let rely = sub(splat(dom[1]), py);
    let relz = sub(splat(dom[2]), pz);
    let ta = clamp(
        dot3(relx, rely, relz, dx, dy, dz),
        splat(0.0),
        load(d),
    );
    let ex = sub(relx, mul(ta, dx));
    let ey = sub(rely, mul(ta, dy));
    let ez = sub(relz, mul(ta, dz));
    (ta, dot3(ex, ey, ez, ex, ey, ez))
}

#[cfg(test)]
mod tests {
    use super::super::engine::segment_test;
    use super::*;

    /// Deterministic pseudo-photon state without pulling in the engine
    /// RNG: enough spread to exercise both clamp ends and hits/misses.
    fn state(n: usize, salt: f32) -> Vec<f32> {
        (0..n)
            .map(|i| ((i as f32 * 37.1 + salt).sin() * 53.7) % 29.0)
            .collect()
    }

    #[test]
    fn lane_sweep_is_bitwise_the_scalar_helper() {
        let n = LANES * 3;
        let (px, py, pz) = (state(n, 0.1), state(n, 1.2), state(n, 2.3));
        let (dx, dy, dz) = (state(n, 3.4), state(n, 4.5), state(n, 5.6));
        let d: Vec<f32> = state(n, 6.7).iter().map(|v| v.abs()).collect();
        for dom in [[0.0f32, 0.0, -17.0], [5.0, -3.0, 40.0], [1e-3, 0.0, 0.0]] {
            let mut i = 0;
            while i + LANES <= n {
                let (ta, dist2) = segment_test_lanes(
                    dom,
                    &px[i..],
                    &py[i..],
                    &pz[i..],
                    &dx[i..],
                    &dy[i..],
                    &dz[i..],
                    &d[i..],
                );
                for l in 0..LANES {
                    let (st, sd2) = segment_test(
                        dom,
                        [px[i + l], py[i + l], pz[i + l]],
                        [dx[i + l], dy[i + l], dz[i + l]],
                        d[i + l],
                    );
                    assert_eq!(ta[l].to_bits(), st.to_bits(), "ta lane {l}");
                    assert_eq!(
                        dist2[l].to_bits(),
                        sd2.to_bits(),
                        "dist2 lane {l}"
                    );
                }
                i += LANES;
            }
        }
    }

    #[test]
    fn clamp_pins_t_along_into_the_step() {
        // a DOM far ahead along +x: ta must clamp to d exactly
        let px = vec![0.0f32; LANES];
        let zeros = vec![0.0f32; LANES];
        let mut dx = vec![0.0f32; LANES];
        dx[0] = 1.0;
        let d = vec![2.5f32; LANES];
        let (ta, _) = segment_test_lanes(
            [100.0, 0.0, 0.0],
            &px,
            &zeros,
            &zeros,
            &dx,
            &zeros,
            &zeros,
            &d,
        );
        assert_eq!(ta[0], 2.5, "forward DOM clamps to the step end");
        assert_eq!(ta[1], 0.0, "zero direction clamps to the step start");
    }

    #[test]
    fn simd_mode_parse_round_trips() {
        assert_eq!(SimdMode::parse("off"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("lanes"), Some(SimdMode::Lanes));
        assert_eq!(SimdMode::parse("auto"), None);
        assert_eq!(SimdMode::parse("LANES"), None, "knob is case-sensitive");
        for m in [SimdMode::Off, SimdMode::Lanes] {
            assert_eq!(SimdMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(SimdMode::default(), SimdMode::Lanes);
    }
}
