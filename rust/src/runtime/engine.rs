//! PJRT photon engine: load, compile and execute the AOT artifacts.
//!
//! This is the Rust end of the three-layer architecture: the JAX/Pallas
//! model was lowered once at build time to HLO *text* (see
//! `python/compile/aot.py` for why text, not serialized protos); here the
//! `xla` crate's PJRT CPU client compiles it once per variant and the
//! coordinator's hot path executes it with no Python anywhere.

use super::artifact::{build_inputs, ArtifactMeta, PhotonInputs, VariantMeta};
use anyhow::{Context, Result};
use std::path::Path;

/// Result of one artifact execution (one photon bunch).
#[derive(Debug, Clone, PartialEq)]
pub struct BunchResult {
    /// Per-DOM photo-electron counts.
    pub hits: Vec<f32>,
    /// [n_detected, n_absorbed, n_alive, path_sum, hit_time_sum,
    ///  alive_steps, 0, 0] — see python/compile/kernels/ref.py.
    pub summary: [f32; 8],
    /// Host wall time of the execution (seconds).
    pub wall_s: f64,
}

impl BunchResult {
    pub fn detected(&self) -> f32 {
        self.summary[0]
    }

    pub fn total_hits(&self) -> f32 {
        self.hits.iter().sum()
    }
}

/// A compiled photon-propagation executable.
pub struct PhotonExecutable {
    pub meta: VariantMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl PhotonExecutable {
    /// Execute one bunch with the given inputs.
    pub fn run(&self, inputs: &PhotonInputs) -> Result<BunchResult> {
        let t0 = std::time::Instant::now();
        let source = xla::Literal::vec1(&inputs.source);
        let media = xla::Literal::vec1(&inputs.media)
            .reshape(&[self.meta.num_layers as i64, 4])?;
        let doms = xla::Literal::vec1(&inputs.doms)
            .reshape(&[self.meta.num_doms as i64, 3])?;
        let params = xla::Literal::vec1(&inputs.params);

        let result = self
            .exe
            .execute::<xla::Literal>(&[source, media, doms, params])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (hits, summary)
        let (hits_lit, summ_lit) = result.to_tuple2()?;
        let hits = hits_lit.to_vec::<f32>()?;
        let summ_vec = summ_lit.to_vec::<f32>()?;
        let mut summary = [0f32; 8];
        summary.copy_from_slice(&summ_vec[..8]);
        Ok(BunchResult { hits, summary, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Execute with default geometry/ice and the given seed.
    pub fn run_seeded(&self, seed: u32) -> Result<BunchResult> {
        let inputs = build_inputs(&self.meta, seed, true);
        self.run(&inputs)
    }

    /// Photons propagated per execution.
    pub fn photons_per_bunch(&self) -> u64 {
        self.meta.num_photons
    }
}

/// The engine: PJRT client + compiled executables.
pub struct PhotonEngine {
    pub meta: ArtifactMeta,
    client: xla::PjRtClient,
}

impl PhotonEngine {
    /// Create a CPU PJRT client and load artifact metadata.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let meta = ArtifactMeta::load(artifact_dir)
            .map_err(|e| anyhow::anyhow!(e))
            .context("loading artifact metadata (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PhotonEngine { meta, client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one variant (slow — do once, reuse the executable).
    pub fn compile(&self, variant: &str) -> Result<PhotonExecutable> {
        let v = self
            .meta
            .variant(variant)
            .with_context(|| format!("unknown variant '{variant}'"))?
            .clone();
        let path = self.meta.hlo_path(&v);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(PhotonExecutable { meta: v, exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    // These tests exercise the real PJRT path and are skipped when
    // artifacts have not been built (`make artifacts`).

    #[test]
    fn compile_and_run_small_variant() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        let exe = engine.compile("small").unwrap();
        let r = exe.run_seeded(7).unwrap();
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize);
        // conservation: detected + absorbed + alive == population
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, exe.meta.num_photons);
        assert_eq!(r.total_hits(), r.detected());
        assert!(r.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        let exe = engine.compile("small").unwrap();
        let a = exe.run_seeded(42).unwrap();
        let b = exe.run_seeded(42).unwrap();
        assert_eq!(a.hits, b.hits);
        assert_eq!(a.summary, b.summary);
        let c = exe.run_seeded(43).unwrap();
        assert_ne!(a.hits, c.hits);
    }

    #[test]
    fn matches_python_oracle_numerics() {
        // cross-language check: the python test suite asserts kernel==ref;
        // here we assert the compiled artifact conserves photons and
        // produces plausible physics for the default variant.
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        let exe = engine.compile("default").unwrap();
        let r = exe.run_seeded(11).unwrap();
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, 4096);
        assert!(r.summary[3] > 0.0, "path length must be positive");
        assert!(r.detected() > 0.0, "a 4k-photon bunch should hit something");
    }

    #[test]
    fn unknown_variant_is_error() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        assert!(engine.compile("nope").is_err());
    }
}
