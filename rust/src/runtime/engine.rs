//! Native photon engine: deterministic Monte-Carlo execution of the AOT
//! photon-propagation artifacts.
//!
//! The original three-layer design lowered the JAX/Pallas model to HLO
//! text and executed it through a PJRT CPU client.  The PJRT runtime
//! crate is not available in the hermetic build environment, so this
//! module implements the same contract natively: it reads the same
//! `artifacts/meta.json`, builds the same inputs (`build_inputs` mirrors
//! `python/compile/geometry.py`), draws from the *same* stateless
//! counter RNG (`python/compile/kernels/rng.py`, the lowbias32 hash of
//! `(seed, photon_id, step, stream)`), and performs the same
//! scatter/absorb/detect walk as the oracle in
//! `python/compile/kernels/ref.py`.
//!
//! Execution is split in two layers (DESIGN.md §13):
//!
//! * this module owns the *physics*: the per-(photon, step) op sequence
//!   as small `#[inline]` helpers on `Walk`, the scalar reference walk
//!   (`Walk::walk_photon`, reachable as
//!   [`PhotonExecutable::run_scalar`]), and the pid-ordered outcome
//!   reduction (`reduce_outcomes`);
//! * [`super::batch`] owns the *execution strategy*: the batched
//!   structure-of-arrays walk with terminated-photon compaction and
//!   chunked multi-thread execution.
//!
//! Because every float expression lives in exactly one helper here, and
//! the stateless RNG makes draw *order* irrelevant, the batched engine
//! is bit-identical to the scalar reference for every (seed, bunch
//! size, thread count) — the property `rust/tests/engine_parity.rs`
//! pins and `tools/parity_check.py` checks against the Python oracle.
//! Results are deterministic in the bunch seed and conserve photons
//! exactly: `detected + absorbed + alive == bunch size`.
//!
//! Public types and signatures match the PJRT version, so a PJRT backend
//! can be restored behind a feature without touching any caller.

use super::artifact::{build_inputs, ArtifactMeta, PhotonInputs, VariantMeta};
use super::batch::{self, ExecPlan};
use super::EngineError;
use std::path::Path;

const TWO_PI: f32 = 2.0 * std::f32::consts::PI;

// ---- counter RNG (bit-mirror of python/compile/kernels/rng.py) -------------

const K_PID: u32 = 0x9E37_79B9;
const K_STEP: u32 = 0x85EB_CA6B;
const K_STREAM: u32 = 0xC2B2_AE35;

const STREAM_LEN: u32 = 0;
const STREAM_ABSORB: u32 = 1;
const STREAM_COS: u32 = 2;
const STREAM_PHI: u32 = 3;
const STREAM_INIT_COS: u32 = 4;
const STREAM_INIT_PHI: u32 = 5;

/// One round of the lowbias32 avalanche finalizer.
#[inline]
fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Uniform f32 in `[0, 1)` from the `(seed, pid, step, stream)` counter —
/// an exact multiple of 2^-24, bit-identical to the Python kernels.
#[inline]
fn uniform(seed: u32, pid: u32, step: u32, stream: u32) -> f32 {
    let key = seed
        ^ pid.wrapping_mul(K_PID)
        ^ step.wrapping_mul(K_STEP)
        ^ stream.wrapping_mul(K_STREAM);
    (mix32(mix32(key)) >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

// ---- scattering kinematics (mirror of ref.py) ------------------------------

/// Henyey-Greenstein scattering angle cosine (isotropic as `|g|` → 0).
#[inline]
fn hg_cos_theta(g: f32, u: f32) -> f32 {
    if g.abs() < 1e-3 {
        return (1.0 - 2.0 * u).clamp(-1.0, 1.0);
    }
    let frac = (1.0 - g * g) / (1.0 - g + 2.0 * g * u);
    ((1.0 + g * g - frac * frac) / (2.0 * g)).clamp(-1.0, 1.0)
}

/// Rotate unit vector `d` by polar angle `acos(cos_t)`, azimuth `phi`
/// (branchless Duff et al. orthonormal basis; re-normalized).
#[inline]
fn rotate_dir(d: [f32; 3], cos_t: f32, phi: f32) -> [f32; 3] {
    let sign = if d[2] >= 0.0 { 1.0f32 } else { -1.0 };
    let a = -1.0 / (sign + d[2]);
    let b = d[0] * d[1] * a;
    let b1 = [1.0 + sign * d[0] * d[0] * a, sign * b, -sign * d[0]];
    let b2 = [b, sign + d[1] * d[1] * a, -d[1]];
    let sin_t = (1.0 - cos_t * cos_t).max(0.0).sqrt();
    let (sp, cp) = (phi.sin(), phi.cos());
    let mut nd = [0.0f32; 3];
    for i in 0..3 {
        nd[i] = sin_t * cp * b1[i] + sin_t * sp * b2[i] + cos_t * d[i];
    }
    let norm = (nd[0] * nd[0] + nd[1] * nd[1] + nd[2] * nd[2])
        .sqrt()
        .max(1e-12);
    [nd[0] / norm, nd[1] / norm, nd[2] / norm]
}

/// Segment–sphere closest-approach test for one (photon, DOM) pair:
/// `(t_along, dist2)` with `t_along` clamped to the step `[0, d]`.
#[inline]
pub(crate) fn segment_test(dom: [f32; 3], pos: [f32; 3], dir: [f32; 3], d: f32) -> (f32, f32) {
    let rel = [dom[0] - pos[0], dom[1] - pos[1], dom[2] - pos[2]];
    let ta = (rel[0] * dir[0] + rel[1] * dir[1] + rel[2] * dir[2]).clamp(0.0, d);
    let diff = [rel[0] - ta * dir[0], rel[1] - ta * dir[1], rel[2] - ta * dir[2]];
    let dist2 = diff[0] * diff[0] + diff[1] * diff[1] + diff[2] * diff[2];
    (ta, dist2)
}

// ---- per-photon outcomes ---------------------------------------------------

/// Photon terminal states.
pub(crate) const ST_ALIVE: u8 = 0;
pub(crate) const ST_ABSORBED: u8 = 1;
pub(crate) const ST_DETECTED: u8 = 2;

/// Sentinel for "no DOM hit".
pub(crate) const NO_DOM: u32 = u32::MAX;

/// What one photon's walk produced.  Outcomes are a pure function of
/// `(inputs, pid)`, which is the whole determinism argument: however the
/// walk is batched or threaded, the outcome vector is identical, and the
/// summary is defined as its pid-ordered sequential fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct PhotonOutcome {
    pub status: u8,
    /// Detecting DOM index, or [`NO_DOM`].
    pub dom: u32,
    /// Steps taken while alive (terminating step included).
    pub steps: u32,
    /// Path length accumulated over this photon's steps (f64 of the
    /// per-step f32 values, in step order).
    pub path: f64,
    /// Arrival time at the detecting DOM (0 unless detected).
    pub hit_time: f64,
}

impl Default for PhotonOutcome {
    fn default() -> Self {
        PhotonOutcome {
            status: ST_ALIVE,
            dom: NO_DOM,
            steps: 0,
            path: 0.0,
            hit_time: 0.0,
        }
    }
}

/// Fold outcomes (in pid order, single-threaded) into a [`BunchResult`].
/// Counts are exact integers; the float sums are sequential f64 folds,
/// so the result does not depend on how the walk was executed.
pub(crate) fn reduce_outcomes(
    outcomes: &[PhotonOutcome],
    num_doms: usize,
    wall_s: f64,
) -> BunchResult {
    let mut hits_u = vec![0u64; num_doms];
    let (mut n_det, mut n_abs, mut n_alive) = (0u64, 0u64, 0u64);
    let mut path_sum = 0.0f64;
    let mut hit_time_sum = 0.0f64;
    let mut alive_steps = 0u64;
    for o in outcomes {
        match o.status {
            ST_DETECTED => {
                n_det += 1;
                hits_u[o.dom as usize] += 1;
                hit_time_sum += o.hit_time;
            }
            ST_ABSORBED => n_abs += 1,
            _ => n_alive += 1,
        }
        path_sum += o.path;
        alive_steps += o.steps as u64;
    }
    let summary = [
        n_det as f32,
        n_abs as f32,
        n_alive as f32,
        path_sum as f32,
        hit_time_sum as f32,
        alive_steps as f32,
        0.0,
        0.0,
    ];
    BunchResult {
        hits: hits_u.into_iter().map(|h| h as f32).collect(),
        summary,
        wall_s,
    }
}

// ---- the walk --------------------------------------------------------------

/// A validated, borrowed view of one bunch execution's inputs, plus the
/// per-(photon, step) physics helpers.  Every float expression of the
/// walk lives in exactly one method here, shared by the scalar reference
/// and the batched engine — bit-divergence between the two would require
/// the compiler to evaluate the *same* expression differently.
pub(crate) struct Walk<'a> {
    seed: u32,
    source: [f32; 8],
    r2: f32,
    z0: f32,
    dz: f32,
    v_group: f32,
    eps: f32,
    media: &'a [f32],
    doms: &'a [f32],
    num_layers: usize,
    num_doms: usize,
    num_steps: u32,
}

impl<'a> Walk<'a> {
    pub(crate) fn new(
        meta: &VariantMeta,
        inputs: &'a PhotonInputs,
    ) -> Result<Walk<'a>, EngineError> {
        let num_doms = meta.num_doms as usize;
        let num_layers = meta.num_layers as usize;
        if inputs.media.len() != num_layers * 4 {
            return Err(EngineError(format!(
                "media shape mismatch: {} != {} * 4",
                inputs.media.len(),
                num_layers
            )));
        }
        if inputs.doms.len() != num_doms * 3 {
            return Err(EngineError(format!(
                "dom shape mismatch: {} != {} * 3",
                inputs.doms.len(),
                num_doms
            )));
        }
        Ok(Walk {
            seed: inputs.source[7] as u32,
            source: inputs.source,
            r2: inputs.params[0] * inputs.params[0],
            z0: inputs.params[1],
            dz: inputs.params[2],
            v_group: inputs.params[3],
            eps: inputs.params[4],
            media: &inputs.media,
            doms: &inputs.doms,
            num_layers,
            num_doms,
            num_steps: meta.num_steps as u32,
        })
    }

    #[inline]
    pub(crate) fn num_doms(&self) -> usize {
        self.num_doms
    }

    #[inline]
    pub(crate) fn num_steps(&self) -> u32 {
        self.num_steps
    }

    #[inline]
    pub(crate) fn source_pos(&self) -> [f32; 3] {
        [self.source[0], self.source[1], self.source[2]]
    }

    #[inline]
    pub(crate) fn t0(&self) -> f32 {
        self.source[6]
    }

    #[inline]
    pub(crate) fn r2(&self) -> f32 {
        self.r2
    }

    #[inline]
    pub(crate) fn v_group(&self) -> f32 {
        self.v_group
    }

    #[inline]
    pub(crate) fn dom(&self, di: usize) -> [f32; 3] {
        [
            self.doms[di * 3],
            self.doms[di * 3 + 1],
            self.doms[di * 3 + 2],
        ]
    }

    /// Initial isotropic direction (RNG streams 4/5 at step 0).
    #[inline]
    pub(crate) fn init_dir(&self, pid: u32) -> [f32; 3] {
        let u_cos = uniform(self.seed, pid, 0, STREAM_INIT_COS);
        let u_phi = uniform(self.seed, pid, 0, STREAM_INIT_PHI);
        let cos_t = 1.0 - 2.0 * u_cos;
        let sin_t = (1.0 - cos_t * cos_t).max(0.0).sqrt();
        let phi = TWO_PI * u_phi;
        [sin_t * phi.cos(), sin_t * phi.sin(), cos_t]
    }

    /// Ice layer index for depth `pz`.
    #[inline]
    pub(crate) fn layer(&self, pz: f32) -> usize {
        (((self.z0 - pz) / self.dz).floor() as i64)
            .clamp(0, self.num_layers as i64 - 1) as usize
    }

    /// Exponential step length in layer `li` (RNG stream 0).
    #[inline]
    pub(crate) fn step_length(&self, li: usize, pid: u32, k: u32) -> f32 {
        let lam_s = self.media[li * 4];
        let u_len = uniform(self.seed, pid, k, STREAM_LEN);
        -lam_s * u_len.max(self.eps).ln()
    }

    /// Did the photon survive absorption over a step of length `d`
    /// (RNG stream 1)?
    #[inline]
    pub(crate) fn survives(&self, li: usize, d: f32, pid: u32, k: u32) -> bool {
        let lam_a = self.media[li * 4 + 1];
        let u_abs = uniform(self.seed, pid, k, STREAM_ABSORB);
        u_abs < (-d / lam_a).exp()
    }

    /// Scatter `dir` by a Henyey-Greenstein deflection (RNG streams 2/3).
    #[inline]
    pub(crate) fn scatter(&self, li: usize, dir: [f32; 3], pid: u32, k: u32) -> [f32; 3] {
        let g = self.media[li * 4 + 2];
        let u_cos = uniform(self.seed, pid, k, STREAM_COS);
        let u_phi = uniform(self.seed, pid, k, STREAM_PHI);
        rotate_dir(dir, hg_cos_theta(g, u_cos), TWO_PI * u_phi)
    }

    /// Earliest DOM hit along a step: `(t_along, dom)` or `(inf, NO_DOM)`.
    /// Ascending DOM order with a strict `<` keeps ties on the lowest
    /// index, exactly like the oracle's `argmin`.
    #[inline]
    pub(crate) fn first_hit(&self, pos: [f32; 3], dir: [f32; 3], d: f32) -> (f32, u32) {
        let mut best_t = f32::INFINITY;
        let mut best_dom = NO_DOM;
        for di in 0..self.num_doms {
            let (ta, dist2) = segment_test(self.dom(di), pos, dir, d);
            if dist2 <= self.r2 && ta < best_t {
                best_t = ta;
                best_dom = di as u32;
            }
        }
        (best_t, best_dom)
    }

    /// The scalar reference walk of one photon — the oracle the batched
    /// engine is pinned against (`rust/tests/engine_parity.rs`).
    pub(crate) fn walk_photon(&self, pid: u32) -> PhotonOutcome {
        let mut pos = self.source_pos();
        let mut t = self.t0();
        let mut dir = self.init_dir(pid);
        let mut path = 0.0f64;
        for k in 0..self.num_steps {
            let li = self.layer(pos[2]);
            let d = self.step_length(li, pid, k);

            // detection beats absorption within the same step
            let (best_t, best_dom) = self.first_hit(pos, dir, d);
            if best_dom != NO_DOM {
                return PhotonOutcome {
                    status: ST_DETECTED,
                    dom: best_dom,
                    steps: k + 1,
                    path: path + best_t as f64,
                    hit_time: (t + best_t / self.v_group) as f64,
                };
            }

            for i in 0..3 {
                pos[i] += dir[i] * d;
            }
            t += d / self.v_group;
            path += d as f64;

            if !self.survives(li, d, pid, k) {
                return PhotonOutcome {
                    status: ST_ABSORBED,
                    dom: NO_DOM,
                    steps: k + 1,
                    path,
                    hit_time: 0.0,
                };
            }
            dir = self.scatter(li, dir, pid, k);
        }
        PhotonOutcome {
            status: ST_ALIVE,
            dom: NO_DOM,
            steps: self.num_steps,
            path,
            hit_time: 0.0,
        }
    }
}

// ---- results ---------------------------------------------------------------

/// Result of one artifact execution (one photon bunch).
#[derive(Debug, Clone, PartialEq)]
pub struct BunchResult {
    /// Per-DOM photo-electron counts.
    pub hits: Vec<f32>,
    /// `[n_detected, n_absorbed, n_alive, path_sum, hit_time_sum,
    /// alive_steps, 0, 0]` — see `python/compile/kernels/ref.py`.
    pub summary: [f32; 8],
    /// Host wall time of the execution (seconds).
    pub wall_s: f64,
}

impl BunchResult {
    pub fn detected(&self) -> f32 {
        self.summary[0]
    }

    pub fn total_hits(&self) -> f32 {
        self.hits.iter().sum()
    }
}

/// A compiled photon-propagation executable.
///
/// "Compilation" for the native engine is metadata validation — the MC
/// walk interprets the variant parameters directly.  [`run`] executes
/// through the batched SoA engine with this executable's [`ExecPlan`];
/// [`run_scalar`] is the reference implementation.
///
/// [`run`]: PhotonExecutable::run
/// [`run_scalar`]: PhotonExecutable::run_scalar
pub struct PhotonExecutable {
    pub meta: VariantMeta,
    plan: ExecPlan,
}

impl PhotonExecutable {
    /// Build an executable straight from variant metadata (no artifact
    /// directory needed — used by tests and synthetic benchmarks).
    pub fn from_meta(meta: VariantMeta) -> Result<Self, EngineError> {
        if meta.num_photons == 0 || meta.num_doms == 0 || meta.num_layers == 0 {
            return Err(EngineError(format!(
                "variant '{}' has a degenerate shape",
                meta.name
            )));
        }
        Ok(PhotonExecutable { meta, plan: ExecPlan::default() })
    }

    /// Replace the execution plan (threads / bunch size).  Plans change
    /// wall time only, never results.
    pub fn with_plan(mut self, plan: ExecPlan) -> Self {
        self.plan = plan;
        self
    }

    /// The plan [`run`](PhotonExecutable::run) executes with.
    pub fn plan(&self) -> ExecPlan {
        self.plan
    }

    /// Execute one bunch with the given inputs (batched engine, this
    /// executable's plan).
    pub fn run(&self, inputs: &PhotonInputs) -> Result<BunchResult, EngineError> {
        batch::run_batched(&self.meta, inputs, self.plan)
    }

    /// Execute one bunch with an explicit plan.
    pub fn run_with_plan(
        &self,
        inputs: &PhotonInputs,
        plan: ExecPlan,
    ) -> Result<BunchResult, EngineError> {
        batch::run_batched(&self.meta, inputs, plan)
    }

    /// Execute one bunch through the scalar reference walk.  This is the
    /// correctness oracle for the batched engine (and the bit-mirror of
    /// `python/compile/kernels/ref.py`); it is kept unconditionally
    /// compiled so benches and `icecloud parity` can reach it too.
    pub fn run_scalar(&self, inputs: &PhotonInputs) -> Result<BunchResult, EngineError> {
        let t0 = std::time::Instant::now();
        let walk = Walk::new(&self.meta, inputs)?;
        let outcomes: Vec<PhotonOutcome> = (0..self.meta.num_photons as usize)
            .map(|p| walk.walk_photon(p as u32))
            .collect();
        Ok(reduce_outcomes(
            &outcomes,
            walk.num_doms(),
            t0.elapsed().as_secs_f64(),
        ))
    }

    /// Execute with default geometry/ice and the given seed.
    pub fn run_seeded(&self, seed: u32) -> Result<BunchResult, EngineError> {
        let inputs = build_inputs(&self.meta, seed, true);
        self.run(&inputs)
    }

    /// Photons propagated per execution.
    pub fn photons_per_bunch(&self) -> u64 {
        self.meta.num_photons
    }
}

/// The engine: artifact metadata + the native executor.
pub struct PhotonEngine {
    pub meta: ArtifactMeta,
}

impl PhotonEngine {
    /// Load artifact metadata (run `python -m compile.aot` to build it).
    pub fn new(artifact_dir: &Path) -> Result<Self, EngineError> {
        let meta = ArtifactMeta::load(artifact_dir).map_err(|e| {
            EngineError(format!(
                "loading artifact metadata (run `python -m compile.aot` from python/): {e}"
            ))
        })?;
        Ok(PhotonEngine { meta })
    }

    /// Execution platform label (the PJRT client reported e.g. "cpu").
    pub fn platform(&self) -> String {
        "native-mc-cpu".to_string()
    }

    /// Prepare one variant for execution.
    pub fn compile(&self, variant: &str) -> Result<PhotonExecutable, EngineError> {
        let v = self
            .meta
            .variant(variant)
            .ok_or_else(|| EngineError(format!("unknown variant '{variant}'")))?
            .clone();
        PhotonExecutable::from_meta(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    /// A small synthetic variant that needs no artifact directory.
    fn tiny_meta() -> VariantMeta {
        VariantMeta {
            name: "tiny".into(),
            file: "synthetic".into(),
            num_photons: 512,
            block: 128,
            num_doms: 16,
            num_steps: 64,
            num_layers: 10,
            flops_estimate: 1.0e6,
        }
    }

    #[test]
    fn conserves_photons_exactly() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let r = exe.run_seeded(7).unwrap();
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, exe.meta.num_photons);
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize);
        // every detection is one whole hit on one DOM
        assert_eq!(r.total_hits(), r.detected());
        assert!(r.hits.iter().all(|h| *h >= 0.0 && h.fract() == 0.0));
        assert!(r.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let a = exe.run_seeded(42).unwrap();
        let b = exe.run_seeded(42).unwrap();
        assert_eq!(a.hits, b.hits);
        assert_eq!(a.summary, b.summary);
        let c = exe.run_seeded(43).unwrap();
        assert_ne!(a.summary, c.summary);
    }

    #[test]
    fn physics_is_plausible() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let r = exe.run_seeded(11).unwrap();
        // with lambda_a ~100 m and ~25 m steps most photons die in 64 steps
        assert!(r.summary[1] > 0.0, "some photons must be absorbed");
        assert!(r.summary[3] > 0.0, "path length must be positive");
        assert!(r.summary[5] >= r.summary[1], "steps >= absorbed photons");
    }

    #[test]
    fn dom_at_source_detects_every_photon() {
        let meta = VariantMeta { num_doms: 1, ..tiny_meta() };
        let exe = PhotonExecutable::from_meta(meta).unwrap();
        let mut inputs = build_inputs(&exe.meta, 5, true);
        // place the single DOM on the cascade vertex: closest approach at
        // t=0 is inside r_dom for every photon, so all detect at step 0
        inputs.doms = inputs.source[0..3].to_vec();
        let r = exe.run(&inputs).unwrap();
        assert_eq!(r.detected() as u64, exe.meta.num_photons);
        assert_eq!(r.hits[0] as u64, exe.meta.num_photons);
        assert_eq!(r.summary[1], 0.0);
        assert_eq!(r.summary[2], 0.0);
    }

    #[test]
    fn batched_default_plan_matches_scalar_reference() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let inputs = build_inputs(&exe.meta, 21, true);
        let scalar = exe.run_scalar(&inputs).unwrap();
        let batched = exe.run(&inputs).unwrap();
        assert_eq!(scalar.hits, batched.hits);
        assert_eq!(scalar.summary, batched.summary);
    }

    #[test]
    fn counter_rng_matches_python_reference_values() {
        // uniform() is an exact multiple of 2^-24 in [0, 1)
        for (pid, step, stream) in [(0, 0, 0), (1, 3, 2), (4096, 63, 5)] {
            let u = uniform(1234, pid, step, stream);
            assert!((0.0..1.0).contains(&u));
            let scaled = u * (1u32 << 24) as f32;
            assert_eq!(scaled.fract(), 0.0, "u={u} not a multiple of 2^-24");
        }
        // decorrelation across counter coordinates
        assert_ne!(uniform(1, 0, 0, 0), uniform(2, 0, 0, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 1, 0, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 0, 1, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 0, 0, 1));
    }

    #[test]
    fn rotate_dir_preserves_unit_length() {
        let mut d = [0.0f32, 0.0, 1.0];
        for k in 0..200 {
            let u = uniform(9, 0, k, STREAM_COS);
            let phi = TWO_PI * uniform(9, 0, k, STREAM_PHI);
            d = rotate_dir(d, hg_cos_theta(0.9, u), phi);
            let n = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
            assert!((n - 1.0).abs() < 1e-4, "norm drifted to {n}");
        }
    }

    #[test]
    fn hg_sampling_is_forward_peaked() {
        // g = 0.9 must scatter forward on average; g = 0 is isotropic
        let mean = |g: f32| -> f32 {
            (0..4000)
                .map(|i| hg_cos_theta(g, uniform(3, i, 0, STREAM_COS)))
                .sum::<f32>()
                / 4000.0
        };
        assert!(mean(0.9) > 0.8, "mean={}", mean(0.9));
        assert!(mean(0.0).abs() < 0.05, "mean={}", mean(0.0));
    }

    #[test]
    fn shape_mismatch_is_error() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let mut inputs = build_inputs(&exe.meta, 1, true);
        inputs.doms.pop();
        assert!(exe.run(&inputs).is_err());
        assert!(exe.run_scalar(&inputs).is_err());
    }

    #[test]
    fn outcome_fold_is_the_summary_contract() {
        // two hand-built outcomes fold to the documented summary layout
        let outcomes = [
            PhotonOutcome {
                status: ST_DETECTED,
                dom: 1,
                steps: 3,
                path: 10.0,
                hit_time: 7.5,
            },
            PhotonOutcome {
                status: ST_ABSORBED,
                dom: NO_DOM,
                steps: 2,
                path: 4.0,
                hit_time: 0.0,
            },
        ];
        let r = reduce_outcomes(&outcomes, 3, 1e-6);
        assert_eq!(r.hits, vec![0.0, 1.0, 0.0]);
        assert_eq!(r.summary[0..6], [1.0, 1.0, 0.0, 14.0, 7.5, 5.0]);
    }

    // The remaining tests exercise real artifacts and are skipped when
    // they have not been built (`python -m compile.aot`).

    #[test]
    fn compile_and_run_small_variant() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        let exe = engine.compile("small").unwrap();
        let r = exe.run_seeded(7).unwrap();
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize);
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, exe.meta.num_photons);
        assert_eq!(r.total_hits(), r.detected());
        assert!(r.wall_s > 0.0);
    }

    #[test]
    fn unknown_variant_is_error() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        assert!(engine.compile("nope").is_err());
    }

    #[test]
    fn missing_artifact_dir_is_error() {
        assert!(PhotonEngine::new(Path::new("/nonexistent-icecloud")).is_err());
    }
}
