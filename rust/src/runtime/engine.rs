//! Native photon engine: deterministic Monte-Carlo execution of the AOT
//! photon-propagation artifacts.
//!
//! The original three-layer design lowered the JAX/Pallas model to HLO
//! text and executed it through a PJRT CPU client.  The PJRT runtime
//! crate is not available in the hermetic build environment, so this
//! module implements the same contract natively: it reads the same
//! `artifacts/meta.json`, builds the same inputs (`build_inputs` mirrors
//! `python/compile/geometry.py`), draws from the *same* stateless
//! counter RNG (`python/compile/kernels/rng.py`, the lowbias32 hash of
//! `(seed, photon_id, step, stream)`), and performs the same per-photon
//! scatter/absorb/detect walk as the oracle in
//! `python/compile/kernels/ref.py`.  Results are deterministic in the
//! bunch seed and conserve photons exactly:
//! `detected + absorbed + alive == bunch size`.
//!
//! Public types and signatures match the PJRT version, so a PJRT backend
//! can be restored behind a feature without touching any caller.

use super::artifact::{build_inputs, ArtifactMeta, PhotonInputs, VariantMeta};
use super::EngineError;
use std::path::Path;

const TWO_PI: f32 = 2.0 * std::f32::consts::PI;

// ---- counter RNG (bit-mirror of python/compile/kernels/rng.py) -------------

const K_PID: u32 = 0x9E37_79B9;
const K_STEP: u32 = 0x85EB_CA6B;
const K_STREAM: u32 = 0xC2B2_AE35;

const STREAM_LEN: u32 = 0;
const STREAM_ABSORB: u32 = 1;
const STREAM_COS: u32 = 2;
const STREAM_PHI: u32 = 3;
const STREAM_INIT_COS: u32 = 4;
const STREAM_INIT_PHI: u32 = 5;

/// One round of the lowbias32 avalanche finalizer.
#[inline]
fn mix32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Uniform f32 in `[0, 1)` from the `(seed, pid, step, stream)` counter —
/// an exact multiple of 2^-24, bit-identical to the Python kernels.
#[inline]
fn uniform(seed: u32, pid: u32, step: u32, stream: u32) -> f32 {
    let key = seed
        ^ pid.wrapping_mul(K_PID)
        ^ step.wrapping_mul(K_STEP)
        ^ stream.wrapping_mul(K_STREAM);
    (mix32(mix32(key)) >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

// ---- scattering kinematics (mirror of ref.py) ------------------------------

/// Henyey-Greenstein scattering angle cosine (isotropic as `|g|` → 0).
#[inline]
fn hg_cos_theta(g: f32, u: f32) -> f32 {
    if g.abs() < 1e-3 {
        return (1.0 - 2.0 * u).clamp(-1.0, 1.0);
    }
    let frac = (1.0 - g * g) / (1.0 - g + 2.0 * g * u);
    ((1.0 + g * g - frac * frac) / (2.0 * g)).clamp(-1.0, 1.0)
}

/// Rotate unit vector `d` by polar angle `acos(cos_t)`, azimuth `phi`
/// (branchless Duff et al. orthonormal basis; re-normalized).
#[inline]
fn rotate_dir(d: [f32; 3], cos_t: f32, phi: f32) -> [f32; 3] {
    let sign = if d[2] >= 0.0 { 1.0f32 } else { -1.0 };
    let a = -1.0 / (sign + d[2]);
    let b = d[0] * d[1] * a;
    let b1 = [1.0 + sign * d[0] * d[0] * a, sign * b, -sign * d[0]];
    let b2 = [b, sign + d[1] * d[1] * a, -d[1]];
    let sin_t = (1.0 - cos_t * cos_t).max(0.0).sqrt();
    let (sp, cp) = (phi.sin(), phi.cos());
    let mut nd = [0.0f32; 3];
    for i in 0..3 {
        nd[i] = sin_t * cp * b1[i] + sin_t * sp * b2[i] + cos_t * d[i];
    }
    let norm = (nd[0] * nd[0] + nd[1] * nd[1] + nd[2] * nd[2])
        .sqrt()
        .max(1e-12);
    [nd[0] / norm, nd[1] / norm, nd[2] / norm]
}

// ---- results ---------------------------------------------------------------

/// Result of one artifact execution (one photon bunch).
#[derive(Debug, Clone, PartialEq)]
pub struct BunchResult {
    /// Per-DOM photo-electron counts.
    pub hits: Vec<f32>,
    /// `[n_detected, n_absorbed, n_alive, path_sum, hit_time_sum,
    /// alive_steps, 0, 0]` — see `python/compile/kernels/ref.py`.
    pub summary: [f32; 8],
    /// Host wall time of the execution (seconds).
    pub wall_s: f64,
}

impl BunchResult {
    pub fn detected(&self) -> f32 {
        self.summary[0]
    }

    pub fn total_hits(&self) -> f32 {
        self.hits.iter().sum()
    }
}

/// A compiled photon-propagation executable.
///
/// "Compilation" for the native engine is metadata validation — the MC
/// walk interprets the variant parameters directly.
pub struct PhotonExecutable {
    pub meta: VariantMeta,
}

impl PhotonExecutable {
    /// Build an executable straight from variant metadata (no artifact
    /// directory needed — used by tests and synthetic benchmarks).
    pub fn from_meta(meta: VariantMeta) -> Result<Self, EngineError> {
        if meta.num_photons == 0 || meta.num_doms == 0 || meta.num_layers == 0
        {
            return Err(EngineError(format!(
                "variant '{}' has a degenerate shape",
                meta.name
            )));
        }
        Ok(PhotonExecutable { meta })
    }

    /// Execute one bunch with the given inputs.
    pub fn run(&self, inputs: &PhotonInputs) -> Result<BunchResult, EngineError> {
        let t0 = std::time::Instant::now();
        let num_doms = self.meta.num_doms as usize;
        let num_layers = self.meta.num_layers as usize;
        if inputs.media.len() != num_layers * 4 {
            return Err(EngineError(format!(
                "media shape mismatch: {} != {} * 4",
                inputs.media.len(),
                num_layers
            )));
        }
        if inputs.doms.len() != num_doms * 3 {
            return Err(EngineError(format!(
                "dom shape mismatch: {} != {} * 3",
                inputs.doms.len(),
                num_doms
            )));
        }

        let seed = inputs.source[7] as u32;
        let r2 = inputs.params[0] * inputs.params[0];
        let z0 = inputs.params[1];
        let dz = inputs.params[2];
        let v_group = inputs.params[3];
        let eps = inputs.params[4];

        let mut hits = vec![0.0f32; num_doms];
        let (mut n_det, mut n_abs, mut n_alive) = (0u64, 0u64, 0u64);
        let mut path_sum = 0.0f64;
        let mut hit_time_sum = 0.0f64;
        let mut alive_steps = 0.0f64;

        for p in 0..self.meta.num_photons {
            let pid = p as u32;
            let mut pos =
                [inputs.source[0], inputs.source[1], inputs.source[2]];
            let mut t = inputs.source[6];

            // initial isotropic direction (RNG streams 4/5 at step 0)
            let u_cos = uniform(seed, pid, 0, STREAM_INIT_COS);
            let u_phi = uniform(seed, pid, 0, STREAM_INIT_PHI);
            let cos_t = 1.0 - 2.0 * u_cos;
            let sin_t = (1.0 - cos_t * cos_t).max(0.0).sqrt();
            let phi = TWO_PI * u_phi;
            let mut dir = [sin_t * phi.cos(), sin_t * phi.sin(), cos_t];

            // status: 0 = alive, 1 = absorbed, 2 = detected
            let mut status = 0u8;

            for k in 0..self.meta.num_steps as u32 {
                if status != 0 {
                    break;
                }
                alive_steps += 1.0;

                let li = (((z0 - pos[2]) / dz).floor() as i64)
                    .clamp(0, num_layers as i64 - 1)
                    as usize;
                let lam_s = inputs.media[li * 4];
                let lam_a = inputs.media[li * 4 + 1];
                let g = inputs.media[li * 4 + 2];

                let u_len = uniform(seed, pid, k, STREAM_LEN);
                let u_abs = uniform(seed, pid, k, STREAM_ABSORB);
                let u_cos = uniform(seed, pid, k, STREAM_COS);
                let u_phi = uniform(seed, pid, k, STREAM_PHI);

                let d = -lam_s * u_len.max(eps).ln();

                // segment–DOM closest approach; earliest hit wins
                let mut best_t = f32::INFINITY;
                let mut best_dom = usize::MAX;
                for di in 0..num_doms {
                    let rel = [
                        inputs.doms[di * 3] - pos[0],
                        inputs.doms[di * 3 + 1] - pos[1],
                        inputs.doms[di * 3 + 2] - pos[2],
                    ];
                    let ta = (rel[0] * dir[0]
                        + rel[1] * dir[1]
                        + rel[2] * dir[2])
                        .clamp(0.0, d);
                    let diff = [
                        rel[0] - ta * dir[0],
                        rel[1] - ta * dir[1],
                        rel[2] - ta * dir[2],
                    ];
                    let dist2 = diff[0] * diff[0]
                        + diff[1] * diff[1]
                        + diff[2] * diff[2];
                    if dist2 <= r2 && ta < best_t {
                        best_t = ta;
                        best_dom = di;
                    }
                }

                if best_dom != usize::MAX {
                    // detection beats absorption within the same step
                    status = 2;
                    n_det += 1;
                    hits[best_dom] += 1.0;
                    hit_time_sum += (t + best_t / v_group) as f64;
                    for i in 0..3 {
                        pos[i] += dir[i] * best_t;
                    }
                    t += best_t / v_group;
                    path_sum += best_t as f64;
                    continue;
                }

                for i in 0..3 {
                    pos[i] += dir[i] * d;
                }
                t += d / v_group;
                path_sum += d as f64;

                let survived = u_abs < (-d / lam_a).exp();
                if !survived {
                    status = 1;
                    n_abs += 1;
                    continue;
                }

                let cos_s = hg_cos_theta(g, u_cos);
                dir = rotate_dir(dir, cos_s, TWO_PI * u_phi);
            }

            if status == 0 {
                n_alive += 1;
            }
        }

        let summary = [
            n_det as f32,
            n_abs as f32,
            n_alive as f32,
            path_sum as f32,
            hit_time_sum as f32,
            alive_steps as f32,
            0.0,
            0.0,
        ];
        Ok(BunchResult { hits, summary, wall_s: t0.elapsed().as_secs_f64() })
    }

    /// Execute with default geometry/ice and the given seed.
    pub fn run_seeded(&self, seed: u32) -> Result<BunchResult, EngineError> {
        let inputs = build_inputs(&self.meta, seed, true);
        self.run(&inputs)
    }

    /// Photons propagated per execution.
    pub fn photons_per_bunch(&self) -> u64 {
        self.meta.num_photons
    }
}

/// The engine: artifact metadata + the native executor.
pub struct PhotonEngine {
    pub meta: ArtifactMeta,
}

impl PhotonEngine {
    /// Load artifact metadata (run `python -m compile.aot` to build it).
    pub fn new(artifact_dir: &Path) -> Result<Self, EngineError> {
        let meta = ArtifactMeta::load(artifact_dir).map_err(|e| {
            EngineError(format!(
                "loading artifact metadata (run `python -m compile.aot` from python/): {e}"
            ))
        })?;
        Ok(PhotonEngine { meta })
    }

    /// Execution platform label (the PJRT client reported e.g. "cpu").
    pub fn platform(&self) -> String {
        "native-mc-cpu".to_string()
    }

    /// Prepare one variant for execution.
    pub fn compile(&self, variant: &str) -> Result<PhotonExecutable, EngineError> {
        let v = self
            .meta
            .variant(variant)
            .ok_or_else(|| {
                EngineError(format!("unknown variant '{variant}'"))
            })?
            .clone();
        PhotonExecutable::from_meta(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    /// A small synthetic variant that needs no artifact directory.
    fn tiny_meta() -> VariantMeta {
        VariantMeta {
            name: "tiny".into(),
            file: "synthetic".into(),
            num_photons: 512,
            block: 128,
            num_doms: 16,
            num_steps: 64,
            num_layers: 10,
            flops_estimate: 1.0e6,
        }
    }

    #[test]
    fn conserves_photons_exactly() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let r = exe.run_seeded(7).unwrap();
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, exe.meta.num_photons);
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize);
        // every detection is one whole hit on one DOM
        assert_eq!(r.total_hits(), r.detected());
        assert!(r.hits.iter().all(|h| *h >= 0.0 && h.fract() == 0.0));
        assert!(r.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let a = exe.run_seeded(42).unwrap();
        let b = exe.run_seeded(42).unwrap();
        assert_eq!(a.hits, b.hits);
        assert_eq!(a.summary, b.summary);
        let c = exe.run_seeded(43).unwrap();
        assert_ne!(a.summary, c.summary);
    }

    #[test]
    fn physics_is_plausible() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let r = exe.run_seeded(11).unwrap();
        // with lambda_a ~100 m and ~25 m steps most photons die in 64 steps
        assert!(r.summary[1] > 0.0, "some photons must be absorbed");
        assert!(r.summary[3] > 0.0, "path length must be positive");
        assert!(r.summary[5] >= r.summary[1], "steps >= absorbed photons");
    }

    #[test]
    fn dom_at_source_detects_every_photon() {
        let meta = VariantMeta { num_doms: 1, ..tiny_meta() };
        let exe = PhotonExecutable::from_meta(meta).unwrap();
        let mut inputs = build_inputs(&exe.meta, 5, true);
        // place the single DOM on the cascade vertex: closest approach at
        // t=0 is inside r_dom for every photon, so all detect at step 0
        inputs.doms = inputs.source[0..3].to_vec();
        let r = exe.run(&inputs).unwrap();
        assert_eq!(r.detected() as u64, exe.meta.num_photons);
        assert_eq!(r.hits[0] as u64, exe.meta.num_photons);
        assert_eq!(r.summary[1], 0.0);
        assert_eq!(r.summary[2], 0.0);
    }

    #[test]
    fn counter_rng_matches_python_reference_values() {
        // uniform() is an exact multiple of 2^-24 in [0, 1)
        for (pid, step, stream) in [(0, 0, 0), (1, 3, 2), (4096, 63, 5)] {
            let u = uniform(1234, pid, step, stream);
            assert!((0.0..1.0).contains(&u));
            let scaled = u * (1u32 << 24) as f32;
            assert_eq!(scaled.fract(), 0.0, "u={u} not a multiple of 2^-24");
        }
        // decorrelation across counter coordinates
        assert_ne!(uniform(1, 0, 0, 0), uniform(2, 0, 0, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 1, 0, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 0, 1, 0));
        assert_ne!(uniform(1, 0, 0, 0), uniform(1, 0, 0, 1));
    }

    #[test]
    fn rotate_dir_preserves_unit_length() {
        let mut d = [0.0f32, 0.0, 1.0];
        for k in 0..200 {
            let u = uniform(9, 0, k, STREAM_COS);
            let phi = TWO_PI * uniform(9, 0, k, STREAM_PHI);
            d = rotate_dir(d, hg_cos_theta(0.9, u), phi);
            let n = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
            assert!((n - 1.0).abs() < 1e-4, "norm drifted to {n}");
        }
    }

    #[test]
    fn hg_sampling_is_forward_peaked() {
        // g = 0.9 must scatter forward on average; g = 0 is isotropic
        let mean = |g: f32| -> f32 {
            (0..4000)
                .map(|i| hg_cos_theta(g, uniform(3, i, 0, STREAM_COS)))
                .sum::<f32>()
                / 4000.0
        };
        assert!(mean(0.9) > 0.8, "mean={}", mean(0.9));
        assert!(mean(0.0).abs() < 0.05, "mean={}", mean(0.0));
    }

    #[test]
    fn shape_mismatch_is_error() {
        let exe = PhotonExecutable::from_meta(tiny_meta()).unwrap();
        let mut inputs = build_inputs(&exe.meta, 1, true);
        inputs.doms.pop();
        assert!(exe.run(&inputs).is_err());
    }

    // The remaining tests exercise real artifacts and are skipped when
    // they have not been built (`python -m compile.aot`).

    #[test]
    fn compile_and_run_small_variant() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        let exe = engine.compile("small").unwrap();
        let r = exe.run_seeded(7).unwrap();
        assert_eq!(r.hits.len(), exe.meta.num_doms as usize);
        let total = r.summary[0] + r.summary[1] + r.summary[2];
        assert_eq!(total as u64, exe.meta.num_photons);
        assert_eq!(r.total_hits(), r.detected());
        assert!(r.wall_s > 0.0);
    }

    #[test]
    fn unknown_variant_is_error() {
        let Some(dir) = artifact_dir() else { return };
        let engine = PhotonEngine::new(&dir).unwrap();
        assert!(engine.compile("nope").is_err());
    }

    #[test]
    fn missing_artifact_dir_is_error() {
        assert!(PhotonEngine::new(Path::new("/nonexistent-icecloud")).is_err());
    }
}
