//! Batched structure-of-arrays execution of the photon walk.
//!
//! The scalar reference in [`super::engine`] walks one photon to
//! termination before touching the next; per step it wanders a
//! 3-float-strided DOM table with one photon's state in registers.  This
//! module restructures the same physics for throughput (DESIGN.md §13):
//!
//! * **SoA state** — live photons are parallel `Vec`s (position,
//!   direction, time, path, pid), so the hot segment–DOM sweep runs
//!   DOM-outer/photon-inner over contiguous f32 arrays; by default the
//!   sweep goes through the explicit [`super::simd`] lane helpers
//!   ([`SimdMode::Lanes`], DESIGN.md §18) with a scalar-helper tail,
//!   and [`SimdMode::Off`] keeps the PR 3 scalar-helper loop;
//! * **compaction** — terminated photons are squeezed out after every
//!   step (order-preserving), so late steps only pay for the survivors;
//! * **chunked threads** — photon ids are split into contiguous ranges,
//!   one scoped `std::thread` per range, each writing outcomes into its
//!   disjoint slice of the shared outcome vector.
//!
//! Determinism: a photon's walk is a pure function of `(inputs, pid)` —
//! the RNG is a stateless counter hash, so neighbors in a bunch cannot
//! influence each other — and every float expression is the *same*
//! `#[inline]` helper the scalar walk calls.  The summary is then
//! defined as the pid-ordered sequential fold of the outcome vector
//! (`engine::reduce_outcomes`), executed single-threaded after the
//! walk.  Together that makes results bit-identical to the scalar
//! oracle for every (seed, bunch size, thread count) combination —
//! pinned by `rust/tests/engine_parity.rs` — which is also why
//! [`ExecPlan`] knobs stay out of the campaign cache key.

use super::artifact::{PhotonInputs, VariantMeta};
use super::engine::{
    reduce_outcomes, segment_test, BunchResult, PhotonOutcome, Walk, NO_DOM,
    ST_ABSORBED, ST_ALIVE, ST_DETECTED,
};
use super::simd::{self, SimdMode, LANES};
use super::EngineError;

/// Photons per SoA bunch when unspecified: ~60 B of state per photon,
/// so a bunch stays comfortably inside L2 alongside the DOM table.
pub const DEFAULT_BUNCH: usize = 4096;

/// All cores the runtime sees — the single "0 = auto" resolution shared
/// by [`ExecPlan`], `config::EngineConfig` and the sweep runner's
/// nested-parallelism budget.
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Execution plan for the batched engine: how a bunch is cut into SoA
/// sub-bunches, spread over threads, and which pass-B sweep runs.
/// Plans trade wall time only — results are bit-identical for every
/// plan, including both [`SimdMode`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecPlan {
    /// Worker threads (0 = all available cores).
    pub threads: usize,
    /// Photons per SoA sub-bunch (0 = [`DEFAULT_BUNCH`]).
    pub bunch: usize,
    /// Segment-sweep implementation (default: the lane fast path).
    pub simd: SimdMode,
}

impl Default for ExecPlan {
    /// Single-threaded, default bunch width, lane sweep: the drop-in
    /// replacement for the scalar engine (no surprise parallelism for
    /// library callers; the lane path is bit-identical, see
    /// [`SimdMode`]).
    fn default() -> Self {
        ExecPlan {
            threads: 1,
            bunch: DEFAULT_BUNCH,
            simd: SimdMode::default(),
        }
    }
}

impl ExecPlan {
    /// All available cores, default bunch width and sweep.
    pub fn auto() -> Self {
        ExecPlan { threads: 0, ..ExecPlan::default() }
    }

    /// Concrete `(threads, bunch)` for a bunch of `num_photons`.
    fn resolved(&self, num_photons: usize) -> (usize, usize) {
        let threads = if self.threads == 0 {
            available_threads()
        } else {
            self.threads
        };
        let threads = threads.clamp(1, num_photons.max(1));
        let bunch = if self.bunch == 0 { DEFAULT_BUNCH } else { self.bunch };
        (threads, bunch)
    }
}

/// Execute one bunch through the batched SoA engine.
pub(crate) fn run_batched(
    meta: &VariantMeta,
    inputs: &PhotonInputs,
    plan: ExecPlan,
) -> Result<BunchResult, EngineError> {
    let t0 = std::time::Instant::now();
    let walk = Walk::new(meta, inputs)?;
    let n = meta.num_photons as usize;
    let (threads, bunch) = plan.resolved(n);
    let mut outcomes = vec![PhotonOutcome::default(); n];

    if threads <= 1 {
        walk_range(&walk, 0, &mut outcomes, bunch, plan.simd);
    } else {
        // contiguous pid ranges, the first `rem` one photon larger
        let base = n / threads;
        let rem = n % threads;
        std::thread::scope(|scope| {
            let walk = &walk;
            let mut rest = outcomes.as_mut_slice();
            let mut pid0 = 0u32;
            for c in 0..threads {
                let size = base + usize::from(c < rem);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(size);
                rest = tail;
                let first = pid0;
                scope.spawn(move || {
                    walk_range(walk, first, head, bunch, plan.simd)
                });
                pid0 += size as u32;
            }
        });
    }

    Ok(reduce_outcomes(
        &outcomes,
        walk.num_doms(),
        t0.elapsed().as_secs_f64(),
    ))
}

/// Walk photons `[first_pid, first_pid + out.len())` in SoA sub-bunches.
fn walk_range(
    walk: &Walk,
    first_pid: u32,
    out: &mut [PhotonOutcome],
    bunch: usize,
    simd: SimdMode,
) {
    let bunch = bunch.max(1);
    let mut start = 0usize;
    while start < out.len() {
        let m = bunch.min(out.len() - start);
        walk_bunch(
            walk,
            first_pid + start as u32,
            &mut out[start..start + m],
            simd,
        );
        start += m;
    }
}

/// SoA state of the live photons of one bunch.
struct BunchState {
    pid: Vec<u32>,
    px: Vec<f32>,
    py: Vec<f32>,
    pz: Vec<f32>,
    dx: Vec<f32>,
    dy: Vec<f32>,
    dz: Vec<f32>,
    t: Vec<f32>,
    path: Vec<f64>,
}

impl BunchState {
    fn init(walk: &Walk, pid0: u32, m: usize) -> BunchState {
        let src = walk.source_pos();
        let mut s = BunchState {
            pid: (0..m).map(|i| pid0 + i as u32).collect(),
            px: vec![src[0]; m],
            py: vec![src[1]; m],
            pz: vec![src[2]; m],
            dx: vec![0.0; m],
            dy: vec![0.0; m],
            dz: vec![0.0; m],
            t: vec![walk.t0(); m],
            path: vec![0.0; m],
        };
        for i in 0..m {
            let dir = walk.init_dir(s.pid[i]);
            s.dx[i] = dir[0];
            s.dy[i] = dir[1];
            s.dz[i] = dir[2];
        }
        s
    }

    /// Drop photon `i`'s state by overwriting from photon `j` (`j >= i`).
    #[inline]
    fn copy_down(&mut self, i: usize, j: usize) {
        self.pid[i] = self.pid[j];
        self.px[i] = self.px[j];
        self.py[i] = self.py[j];
        self.pz[i] = self.pz[j];
        self.dx[i] = self.dx[j];
        self.dy[i] = self.dy[j];
        self.dz[i] = self.dz[j];
        self.t[i] = self.t[j];
        self.path[i] = self.path[j];
    }
}

/// Pass B of one step: the segment–DOM sweep, DOM-outer so the inner
/// loop runs over contiguous photon arrays; ascending DOM order +
/// strict `<` keeps the scalar walk's tie-breaking (lowest DOM index).
///
/// [`SimdMode::Lanes`] sweeps `LANES` photons per iteration through
/// the explicit-width helpers in [`super::simd`], with photons past
/// the last full lane group falling back to the shared scalar helper;
/// both forms evaluate the identical per-photon op sequence, so the
/// choice is invisible in the results (DESIGN.md §18).
#[allow(clippy::too_many_arguments)]
fn sweep_doms(
    walk: &Walk,
    s: &BunchState,
    d: &[f32],
    best_t: &mut [f32],
    best_dom: &mut [u32],
    n_active: usize,
    r2: f32,
    simd: SimdMode,
) {
    best_t[..n_active].fill(f32::INFINITY);
    best_dom[..n_active].fill(NO_DOM);
    // photons covered by full lane groups; 0 under SimdMode::Off
    let full = match simd {
        SimdMode::Off => 0,
        SimdMode::Lanes => n_active - n_active % LANES,
    };
    for di in 0..walk.num_doms() {
        let dom = walk.dom(di);
        let mut i = 0;
        while i < full {
            let (ta, dist2) = simd::segment_test_lanes(
                dom,
                &s.px[i..],
                &s.py[i..],
                &s.pz[i..],
                &s.dx[i..],
                &s.dy[i..],
                &s.dz[i..],
                &d[i..],
            );
            for l in 0..LANES {
                if dist2[l] <= r2 && ta[l] < best_t[i + l] {
                    best_t[i + l] = ta[l];
                    best_dom[i + l] = di as u32;
                }
            }
            i += LANES;
        }
        for i in full..n_active {
            let (ta, dist2) = segment_test(
                dom,
                [s.px[i], s.py[i], s.pz[i]],
                [s.dx[i], s.dy[i], s.dz[i]],
                d[i],
            );
            if dist2 <= r2 && ta < best_t[i] {
                best_t[i] = ta;
                best_dom[i] = di as u32;
            }
        }
    }
}

/// Walk one SoA bunch of `out.len()` photons starting at `pid0`.
fn walk_bunch(walk: &Walk, pid0: u32, out: &mut [PhotonOutcome], simd: SimdMode) {
    let m = out.len();
    let mut s = BunchState::init(walk, pid0, m);
    // per-step scratch, indexed like the live arrays
    let mut li = vec![0u32; m];
    let mut d = vec![0.0f32; m];
    let mut best_t = vec![0.0f32; m];
    let mut best_dom = vec![NO_DOM; m];
    let mut term = vec![ST_ALIVE; m];

    let r2 = walk.r2();
    let mut n_active = m;
    for k in 0..walk.num_steps() {
        if n_active == 0 {
            break;
        }

        // pass A: layer lookup + exponential step length
        for i in 0..n_active {
            let l = walk.layer(s.pz[i]);
            li[i] = l as u32;
            d[i] = walk.step_length(l, s.pid[i], k);
        }

        // pass B: segment–DOM sweep (lane fast path or scalar helper)
        sweep_doms(walk, &s, &d, &mut best_t, &mut best_dom, n_active, r2, simd);

        // pass C: detect / move / absorb / scatter
        for i in 0..n_active {
            let slot = (s.pid[i] - pid0) as usize;
            if best_dom[i] != NO_DOM {
                out[slot] = PhotonOutcome {
                    status: ST_DETECTED,
                    dom: best_dom[i],
                    steps: k + 1,
                    path: s.path[i] + best_t[i] as f64,
                    hit_time: (s.t[i] + best_t[i] / walk.v_group()) as f64,
                };
                term[i] = ST_DETECTED;
                continue;
            }
            s.px[i] += s.dx[i] * d[i];
            s.py[i] += s.dy[i] * d[i];
            s.pz[i] += s.dz[i] * d[i];
            s.t[i] += d[i] / walk.v_group();
            s.path[i] += d[i] as f64;
            if !walk.survives(li[i] as usize, d[i], s.pid[i], k) {
                out[slot] = PhotonOutcome {
                    status: ST_ABSORBED,
                    dom: NO_DOM,
                    steps: k + 1,
                    path: s.path[i],
                    hit_time: 0.0,
                };
                term[i] = ST_ABSORBED;
                continue;
            }
            let dir = walk.scatter(
                li[i] as usize,
                [s.dx[i], s.dy[i], s.dz[i]],
                s.pid[i],
                k,
            );
            s.dx[i] = dir[0];
            s.dy[i] = dir[1];
            s.dz[i] = dir[2];
            term[i] = ST_ALIVE;
        }

        // pass D: order-preserving compaction of terminated photons
        let mut w = 0usize;
        for i in 0..n_active {
            if term[i] == ST_ALIVE {
                if w != i {
                    s.copy_down(w, i);
                }
                w += 1;
            }
        }
        n_active = w;
    }

    // photons that outlived the step budget
    for i in 0..n_active {
        let slot = (s.pid[i] - pid0) as usize;
        out[slot] = PhotonOutcome {
            status: ST_ALIVE,
            dom: NO_DOM,
            steps: walk.num_steps(),
            path: s.path[i],
            hit_time: 0.0,
        };
    }
}
