//! PJRT runtime bridge: the Rust end of the AOT (JAX/Pallas -> HLO text)
//! pipeline. Loads `artifacts/*.hlo.txt`, compiles once on the PJRT CPU
//! client, and executes photon bunches from the coordinator's hot path —
//! Python never runs at simulation/serving time.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, PhotonInputs, VariantMeta};
pub use engine::{BunchResult, PhotonEngine, PhotonExecutable};
