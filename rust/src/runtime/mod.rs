//! Photon runtime: the Rust end of the AOT (JAX/Pallas → HLO text)
//! pipeline.  Loads `artifacts/meta.json` and executes photon bunches
//! from the coordinator's hot path — Python never runs at
//! simulation/serving time.  The execution backend is a deterministic
//! native Monte-Carlo engine that mirrors the Python oracle
//! (`python/compile/kernels/ref.py`) including its stateless counter
//! RNG; see `engine` (the physics + scalar reference), `batch` (the
//! batched SoA executor behind [`PhotonExecutable::run`]) and DESIGN.md
//! §9/§13 for how this substitutes for the PJRT CPU client in the
//! hermetic build.

pub mod artifact;
pub mod batch;
pub mod engine;
pub mod simd;

pub use artifact::{build_inputs, ArtifactMeta, PhotonInputs, VariantMeta};
pub use batch::{available_threads, ExecPlan};
pub use engine::{BunchResult, PhotonEngine, PhotonExecutable};
pub use simd::SimdMode;

/// Error raised by the photon runtime (metadata, shapes, execution).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineError(pub String);

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for EngineError {}
