//! Provider-preference policy: distribute a global GPU target across
//! regions.
//!
//! The paper's operators "heavily favored Azure during most of the
//! exercise" after validation showed it had the lowest spot price
//! ($2.9/T4-day) *and* the most spare capacity / lowest preemption.
//! `PolicyMode::Fixed` encodes that choice; `PolicyMode::Adaptive`
//! derives provider weights from observed price and preemption — the
//! ablation in DESIGN.md §8.  `PolicyMode::RiskAware` drops the
//! provider tier entirely: every region competes on market depth
//! discounted by price and the *observed* reclaim+churn rate of its
//! provider, so the paper's Azure-favoring is an emergent outcome of
//! the same evidence the operators had, not a hardcoded weight vector
//! (DESIGN.md §15).

use crate::cloud::{CloudSim, Provider, RegionId};
use crate::config::{PolicyMode, ProviderWeights};
use std::collections::BTreeMap;

/// Risk-penalty steepness per (preempt/instance-hour), shared by the
/// adaptive and risk-aware modes: at the paper's observed worst rate
/// (~0.05/h) the penalty is e^-3 ≈ 0.05.
const RISK_K: f64 = 60.0;

/// Distribute `total` GPUs across regions.
///
/// Fixed/adaptive modes split the total across providers by weight,
/// then across each provider's regions by mean market depth (what an
/// operator learns during validation).  Risk-aware mode scores every
/// region directly.  All paths use largest-remainder rounding so the
/// grand total is exact.
pub fn distribute(
    total: u32,
    fleet: &CloudSim,
    mode: &PolicyMode,
    observed: Option<&ObservedRates>,
) -> BTreeMap<RegionId, u32> {
    let weights = match mode {
        PolicyMode::Fixed(w) => *w,
        PolicyMode::Adaptive => adaptive_weights(fleet, observed),
        PolicyMode::RiskAware => {
            return distribute_risk_aware(total, fleet, observed)
        }
    };
    let norm = weights.aws + weights.gcp + weights.azure;
    let mut out = BTreeMap::new();
    if total == 0 || norm <= 0.0 {
        for (rid, _) in fleet.regions() {
            out.insert(rid, 0);
        }
        return out;
    }
    for provider in Provider::ALL {
        let w = match provider {
            Provider::Aws => weights.aws,
            Provider::Gcp => weights.gcp,
            Provider::Azure => weights.azure,
        } / norm;
        let provider_total = (total as f64 * w).round() as u32;
        let regions: Vec<(RegionId, f64)> = fleet
            .regions()
            .filter(|(_, r)| r.spec().provider == provider)
            .map(|(rid, r)| (rid, r.spec().base_capacity))
            .collect();
        for (rid, n) in apportion(provider_total, &regions) {
            out.insert(rid, n);
        }
    }
    out
}

/// Largest-remainder apportionment of `target` units across scored
/// items: exact total, deterministic tie-break by id.
fn apportion(target: u32, scores: &[(RegionId, f64)]) -> Vec<(RegionId, u32)> {
    let score_sum: f64 = scores.iter().map(|(_, s)| s).sum();
    // guard only the all-zero case: clamping small-but-positive sums
    // (e.g. risk scores crushed by a heavy observed-reclaim penalty)
    // to 1.0 would silently shrink every share and lose the target
    let denom = if score_sum > 0.0 { score_sum } else { 1.0 };
    let mut fracs: Vec<(RegionId, u32, f64)> = scores
        .iter()
        .map(|(rid, score)| {
            let share = target as f64 * score / denom;
            let base = share.floor() as u32;
            (*rid, base, share - base as f64)
        })
        .collect();
    let assigned: u32 = fracs.iter().map(|(_, b, _)| b).sum();
    fracs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));
    let mut remainder = target.saturating_sub(assigned);
    fracs
        .into_iter()
        .map(|(rid, base, _)| {
            let extra = if remainder > 0 {
                remainder -= 1;
                1
            } else {
                0
            };
            (rid, base + extra)
        })
        .collect()
}

/// Region-level risk pricing: score every region by
/// `depth × exp(-K × observed_reclaim_rate) / price` and apportion the
/// whole target across all regions in one pass.  With no observations
/// yet this reduces to cheapest-deepest-first — which already favors
/// Azure ($2.9/T4-day, deepest markets); once the campaign observes
/// reclaim+churn the risky providers are discounted further.
fn distribute_risk_aware(
    total: u32,
    fleet: &CloudSim,
    observed: Option<&ObservedRates>,
) -> BTreeMap<RegionId, u32> {
    let scores: Vec<(RegionId, f64)> = fleet
        .regions()
        .map(|(rid, r)| {
            let spec = r.spec();
            let rate = observed
                .map(|o| o.preempt_per_hour[provider_index(spec.provider)])
                .unwrap_or(0.0);
            let penalty = (-RISK_K * rate).exp();
            (rid, spec.base_capacity * penalty / spec.price_per_day())
        })
        .collect();
    if total == 0 || scores.iter().all(|(_, s)| *s <= 0.0) {
        return fleet.regions().map(|(rid, _)| (rid, 0)).collect();
    }
    apportion(total, &scores).into_iter().collect()
}

/// Observed per-provider operating rates (filled in by the campaign from
/// fleet statistics during validation).
#[derive(Debug, Clone, Copy, Default)]
pub struct ObservedRates {
    /// Preemptions per instance-hour, per provider (aws, gcp, azure).
    pub preempt_per_hour: [f64; 3],
}

/// Adaptive weights: favor cheap and stable providers.
///
/// weight ∝ (1 / price_per_day) * exp(-k * preempt_rate); with no
/// observations this reduces to cheapest-first.
fn adaptive_weights(
    fleet: &CloudSim,
    observed: Option<&ObservedRates>,
) -> ProviderWeights {
    let mut price = [0.0f64; 3];
    let mut count = [0u32; 3];
    for (_, r) in fleet.regions() {
        let i = provider_index(r.spec().provider);
        price[i] += r.spec().price_per_day();
        count[i] += 1;
    }
    let mut w = [0.0f64; 3];
    for i in 0..3 {
        if count[i] == 0 {
            continue;
        }
        let avg_price = price[i] / count[i] as f64;
        let penalty = observed
            .map(|o| (-RISK_K * o.preempt_per_hour[i]).exp())
            .unwrap_or(1.0);
        w[i] = penalty / avg_price;
    }
    ProviderWeights { aws: w[0], gcp: w[1], azure: w[2] }
}

pub fn provider_index(p: Provider) -> usize {
    p.index()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::providers;
    use crate::util::rng::Rng;

    fn fleet() -> CloudSim {
        CloudSim::new(providers::all_regions(), Rng::new(1))
    }

    fn paper_mode() -> PolicyMode {
        PolicyMode::Fixed(ProviderWeights { aws: 0.15, gcp: 0.15, azure: 0.7 })
    }

    fn provider_total(
        fleet: &CloudSim,
        targets: &BTreeMap<RegionId, u32>,
        p: Provider,
    ) -> u32 {
        fleet
            .regions()
            .filter(|(_, r)| r.spec().provider == p)
            .map(|(rid, _)| targets.get(&rid).copied().unwrap_or(0))
            .sum()
    }

    #[test]
    fn totals_are_exact() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        let sum: u32 = t.values().sum();
        assert_eq!(sum, 2000);
    }

    #[test]
    fn azure_gets_the_lions_share() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        let gcp = provider_total(&f, &t, Provider::Gcp);
        assert_eq!(az, 1400);
        assert_eq!(aws, 300);
        assert_eq!(gcp, 300);
    }

    #[test]
    fn regions_weighted_by_depth() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        // azure/eastus (cap 420) must get more than azure/australiaeast (100)
        let eastus = f.regions().find(|(_, r)| r.spec().name == "azure/eastus").unwrap().0;
        let aus = f
            .regions()
            .find(|(_, r)| r.spec().name == "azure/australiaeast")
            .unwrap()
            .0;
        assert!(t[&eastus] > t[&aus] * 2);
    }

    #[test]
    fn zero_total_zeroes_everything() {
        let f = fleet();
        let t = distribute(0, &f, &paper_mode(), None);
        assert!(t.values().all(|v| *v == 0));
        assert_eq!(t.len(), f.num_regions());
    }

    #[test]
    fn adaptive_prefers_cheap_without_observations() {
        let f = fleet();
        let t = distribute(1000, &f, &PolicyMode::Adaptive, None);
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        assert!(az > aws, "azure ({az}) cheaper than aws ({aws})");
    }

    #[test]
    fn adaptive_penalizes_preempting_provider() {
        let f = fleet();
        // observation: azure preempts heavily, aws is calm
        let obs = ObservedRates { preempt_per_hour: [0.0, 0.0, 0.05] };
        let t = distribute(1000, &f, &PolicyMode::Adaptive, Some(&obs));
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        assert!(aws > az, "aws ({aws}) must beat unstable azure ({az})");
    }

    #[test]
    fn deterministic() {
        let f = fleet();
        assert_eq!(
            distribute(777, &f, &paper_mode(), None),
            distribute(777, &f, &paper_mode(), None)
        );
    }

    #[test]
    fn risk_aware_totals_are_exact() {
        let f = fleet();
        for total in [0u32, 1, 7, 777, 2000] {
            let t = distribute(total, &f, &PolicyMode::RiskAware, None);
            assert_eq!(t.values().sum::<u32>(), total, "total={total}");
            assert_eq!(t.len(), f.num_regions());
        }
    }

    #[test]
    fn risk_aware_azure_favoring_is_emergent() {
        // no hardcoded weights: with no observations the score is
        // depth/price, and Azure (cheapest, deepest) must still win
        let f = fleet();
        let t = distribute(2000, &f, &PolicyMode::RiskAware, None);
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        let gcp = provider_total(&f, &t, Provider::Gcp);
        assert!(
            az > aws && az > gcp,
            "azure ({az}) must lead aws ({aws}) / gcp ({gcp})"
        );
    }

    #[test]
    fn risk_aware_discounts_observed_reclaim_churn() {
        let f = fleet();
        let calm = distribute(1000, &f, &PolicyMode::RiskAware, None);
        // observation: azure reclaims+churns heavily, others are calm
        let obs = ObservedRates { preempt_per_hour: [0.0, 0.0, 0.08] };
        let risky = distribute(1000, &f, &PolicyMode::RiskAware, Some(&obs));
        let az_calm = provider_total(&f, &calm, Provider::Azure);
        let az_risky = provider_total(&f, &risky, Provider::Azure);
        assert!(
            az_risky < az_calm / 2,
            "observed risk must shift share away from azure \
             ({az_calm} -> {az_risky})"
        );
        // the displaced share lands on the calm providers, total exact
        assert_eq!(risky.values().sum::<u32>(), 1000);
        assert!(
            provider_total(&f, &risky, Provider::Aws)
                > provider_total(&f, &calm, Provider::Aws)
        );
    }

    #[test]
    fn risk_aware_totals_survive_crushing_penalties() {
        // regression: when every region's score is penalty-crushed
        // below a combined sum of 1.0, the apportionment must still
        // hand out the exact target (a clamped denominator used to
        // collapse a 2000-GPU ramp to ~one instance per region)
        let f = fleet();
        let obs = ObservedRates { preempt_per_hour: [0.2, 0.2, 0.2] };
        let t = distribute(2000, &f, &PolicyMode::RiskAware, Some(&obs));
        assert_eq!(t.values().sum::<u32>(), 2000);
    }

    #[test]
    fn risk_aware_deterministic() {
        let f = fleet();
        let obs = ObservedRates { preempt_per_hour: [0.01, 0.02, 0.005] };
        assert_eq!(
            distribute(999, &f, &PolicyMode::RiskAware, Some(&obs)),
            distribute(999, &f, &PolicyMode::RiskAware, Some(&obs))
        );
    }
}
