//! Provider-preference policy: distribute a global GPU target across
//! regions.
//!
//! The paper's operators "heavily favored Azure during most of the
//! exercise" after validation showed it had the lowest spot price
//! ($2.9/T4-day) *and* the most spare capacity / lowest preemption.
//! `PolicyMode::Fixed` encodes that choice; `PolicyMode::Adaptive`
//! derives weights from observed price and preemption — the ablation in
//! DESIGN.md §8.

use crate::cloud::{CloudSim, Provider, RegionId};
use crate::config::{PolicyMode, ProviderWeights};
use std::collections::BTreeMap;

/// Distribute `total` GPUs across regions.
///
/// Within a provider, regions receive shares proportional to their mean
/// market depth (what an operator learns during validation), with
/// largest-remainder rounding so the provider total is exact.
pub fn distribute(
    total: u32,
    fleet: &CloudSim,
    mode: &PolicyMode,
    observed: Option<&ObservedRates>,
) -> BTreeMap<RegionId, u32> {
    let weights = match mode {
        PolicyMode::Fixed(w) => *w,
        PolicyMode::Adaptive => adaptive_weights(fleet, observed),
    };
    let norm = weights.aws + weights.gcp + weights.azure;
    let mut out = BTreeMap::new();
    if total == 0 || norm <= 0.0 {
        for (rid, _) in fleet.regions() {
            out.insert(rid, 0);
        }
        return out;
    }
    for provider in Provider::ALL {
        let w = match provider {
            Provider::Aws => weights.aws,
            Provider::Gcp => weights.gcp,
            Provider::Azure => weights.azure,
        } / norm;
        let provider_total = (total as f64 * w).round() as u32;
        let regions: Vec<(RegionId, f64)> = fleet
            .regions()
            .filter(|(_, r)| r.spec().provider == provider)
            .map(|(rid, r)| (rid, r.spec().base_capacity))
            .collect();
        let cap_sum: f64 = regions.iter().map(|(_, c)| c).sum();
        // largest-remainder apportionment
        let mut assigned = 0u32;
        let mut fracs: Vec<(RegionId, u32, f64)> = regions
            .iter()
            .map(|(rid, cap)| {
                let share = provider_total as f64 * cap / cap_sum.max(1.0);
                let base = share.floor() as u32;
                (*rid, base, share - base as f64)
            })
            .collect();
        assigned += fracs.iter().map(|(_, b, _)| b).sum::<u32>();
        fracs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));
        let mut remainder = provider_total.saturating_sub(assigned);
        for (rid, base, _) in fracs {
            let extra = if remainder > 0 {
                remainder -= 1;
                1
            } else {
                0
            };
            out.insert(rid, base + extra);
        }
    }
    out
}

/// Observed per-provider operating rates (filled in by the campaign from
/// fleet statistics during validation).
#[derive(Debug, Clone, Copy, Default)]
pub struct ObservedRates {
    /// Preemptions per instance-hour, per provider (aws, gcp, azure).
    pub preempt_per_hour: [f64; 3],
}

/// Adaptive weights: favor cheap and stable providers.
///
/// weight ∝ (1 / price_per_day) * exp(-k * preempt_rate); with no
/// observations this reduces to cheapest-first.
fn adaptive_weights(
    fleet: &CloudSim,
    observed: Option<&ObservedRates>,
) -> ProviderWeights {
    const K: f64 = 60.0; // penalty steepness per (preempt/instance-hour)
    let mut price = [0.0f64; 3];
    let mut count = [0u32; 3];
    for (_, r) in fleet.regions() {
        let i = provider_index(r.spec().provider);
        price[i] += r.spec().price_per_day();
        count[i] += 1;
    }
    let mut w = [0.0f64; 3];
    for i in 0..3 {
        if count[i] == 0 {
            continue;
        }
        let avg_price = price[i] / count[i] as f64;
        let penalty = observed
            .map(|o| (-K * o.preempt_per_hour[i]).exp())
            .unwrap_or(1.0);
        w[i] = penalty / avg_price;
    }
    ProviderWeights { aws: w[0], gcp: w[1], azure: w[2] }
}

pub fn provider_index(p: Provider) -> usize {
    match p {
        Provider::Aws => 0,
        Provider::Gcp => 1,
        Provider::Azure => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::providers;
    use crate::util::rng::Rng;

    fn fleet() -> CloudSim {
        CloudSim::new(providers::all_regions(), Rng::new(1))
    }

    fn paper_mode() -> PolicyMode {
        PolicyMode::Fixed(ProviderWeights { aws: 0.15, gcp: 0.15, azure: 0.7 })
    }

    fn provider_total(
        fleet: &CloudSim,
        targets: &BTreeMap<RegionId, u32>,
        p: Provider,
    ) -> u32 {
        fleet
            .regions()
            .filter(|(_, r)| r.spec().provider == p)
            .map(|(rid, _)| targets.get(&rid).copied().unwrap_or(0))
            .sum()
    }

    #[test]
    fn totals_are_exact() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        let sum: u32 = t.values().sum();
        assert_eq!(sum, 2000);
    }

    #[test]
    fn azure_gets_the_lions_share() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        let gcp = provider_total(&f, &t, Provider::Gcp);
        assert_eq!(az, 1400);
        assert_eq!(aws, 300);
        assert_eq!(gcp, 300);
    }

    #[test]
    fn regions_weighted_by_depth() {
        let f = fleet();
        let t = distribute(2000, &f, &paper_mode(), None);
        // azure/eastus (cap 420) must get more than azure/australiaeast (100)
        let eastus = f.regions().find(|(_, r)| r.spec().name == "azure/eastus").unwrap().0;
        let aus = f
            .regions()
            .find(|(_, r)| r.spec().name == "azure/australiaeast")
            .unwrap()
            .0;
        assert!(t[&eastus] > t[&aus] * 2);
    }

    #[test]
    fn zero_total_zeroes_everything() {
        let f = fleet();
        let t = distribute(0, &f, &paper_mode(), None);
        assert!(t.values().all(|v| *v == 0));
        assert_eq!(t.len(), f.num_regions());
    }

    #[test]
    fn adaptive_prefers_cheap_without_observations() {
        let f = fleet();
        let t = distribute(1000, &f, &PolicyMode::Adaptive, None);
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        assert!(az > aws, "azure ({az}) cheaper than aws ({aws})");
    }

    #[test]
    fn adaptive_penalizes_preempting_provider() {
        let f = fleet();
        // observation: azure preempts heavily, aws is calm
        let obs = ObservedRates { preempt_per_hour: [0.0, 0.0, 0.05] };
        let t = distribute(1000, &f, &PolicyMode::Adaptive, Some(&obs));
        let az = provider_total(&f, &t, Provider::Azure);
        let aws = provider_total(&f, &t, Provider::Aws);
        assert!(aws > az, "aws ({aws}) must beat unstable azure ({az})");
    }

    #[test]
    fn deterministic() {
        let f = fleet();
        assert_eq!(
            distribute(777, &f, &paper_mode(), None),
            distribute(777, &f, &paper_mode(), None)
        );
    }
}
