//! The operators' ramp plan: staged scale-up with holds.
//!
//! §IV: "we ramped up in steps to 400, 900, 1.2k, 1.6k and finally to 2k
//! GPUs, sustaining at each step for extended periods of time to validate
//! the stability of the system before moving higher."

use crate::config::RampStep;
use crate::sim::SimTime;

/// Evaluates the ramp plan against the clock.
#[derive(Debug, Clone)]
pub struct RampPlan {
    steps: Vec<RampStep>,
}

impl RampPlan {
    pub fn new(steps: Vec<RampStep>) -> Self {
        assert!(!steps.is_empty(), "ramp plan needs at least one step");
        RampPlan { steps }
    }

    /// Desired total at time `t` (the last step holds indefinitely).
    pub fn target_at(&self, t: SimTime) -> u32 {
        let mut elapsed: SimTime = 0;
        for step in &self.steps {
            elapsed += step.hold_s;
            if t < elapsed {
                return step.target;
            }
        }
        self.steps.last().unwrap().target
    }

    /// Index of the active step at `t`.
    pub fn step_index_at(&self, t: SimTime) -> usize {
        let mut elapsed: SimTime = 0;
        for (i, step) in self.steps.iter().enumerate() {
            elapsed += step.hold_s;
            if t < elapsed {
                return i;
            }
        }
        self.steps.len() - 1
    }

    /// Times at which the target changes (for figure annotations).
    pub fn transitions(&self) -> Vec<(SimTime, u32)> {
        let mut out = Vec::new();
        let mut elapsed: SimTime = 0;
        for step in &self.steps {
            out.push((elapsed, step.target));
            elapsed += step.hold_s;
        }
        out
    }

    pub fn peak(&self) -> u32 {
        self.steps.iter().map(|s| s.target).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CampaignConfig;
    use crate::sim::DAY;

    fn paper_plan() -> RampPlan {
        RampPlan::new(CampaignConfig::default().ramp)
    }

    #[test]
    fn staircase_matches_paper() {
        let p = paper_plan();
        assert_eq!(p.target_at(0), 50);
        assert_eq!(p.target_at(DAY + 1), 400);
        assert_eq!(p.target_at(3 * DAY + 1), 900);
        assert_eq!(p.target_at(5 * DAY + 1), 1200);
        assert_eq!(p.target_at(7 * DAY + 1), 1600);
        assert_eq!(p.target_at(9 * DAY + 1), 2000);
        assert_eq!(p.target_at(13 * DAY), 2000);
        assert_eq!(p.peak(), 2000);
    }

    #[test]
    fn last_step_holds_forever() {
        let p = paper_plan();
        assert_eq!(p.target_at(SimTime::MAX / 2), 2000);
    }

    #[test]
    fn step_boundaries_exact() {
        let p = RampPlan::new(vec![
            RampStep { target: 10, hold_s: 100 },
            RampStep { target: 20, hold_s: 100 },
        ]);
        assert_eq!(p.target_at(99), 10);
        assert_eq!(p.target_at(100), 20);
        assert_eq!(p.step_index_at(99), 0);
        assert_eq!(p.step_index_at(100), 1);
    }

    #[test]
    fn transitions_list() {
        let p = paper_plan();
        let tr = p.transitions();
        assert_eq!(tr[0], (0, 50));
        assert_eq!(tr[1], (DAY, 400));
        assert_eq!(tr.len(), 6);
    }
}
