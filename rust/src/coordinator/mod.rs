//! The campaign coordinator: the paper's operational layer.
//!
//! Ramp plan (staged 400/900/1.2k/1.6k/2k scale-up), provider-preference
//! target distribution, outage response, budget-aware resume, and the
//! campaign loop that composes every substrate.

pub mod campaign;
pub mod outage;
pub mod policy;
pub mod rampplan;
pub mod scenario;

pub use campaign::{Campaign, CampaignResult, ProviderWork, RealComputeStats};
pub use outage::{OutageState, OutageTransition};
pub use policy::{distribute, ObservedRates};
pub use rampplan::RampPlan;
pub use scenario::ScenarioConfig;
