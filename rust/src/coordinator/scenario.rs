//! Scenario overrides: one named "what if" on top of a base campaign.
//!
//! The paper's exercise is a single operating point — one budget, one
//! ramp plan, one outage, one keepalive.  A [`ScenarioConfig`] captures a
//! *deviation* from that point as data, so the sweep subsystem
//! (`crate::sweep`) can replay many variants of the same campaign from
//! one base [`CampaignConfig`] without duplicating it.  Every field is
//! optional: `None` inherits the base; the double-`Option` on `outage`
//! distinguishes "inherit" (`None`) from "force no outage"
//! (`Some(None)`).

use crate::config::{
    CampaignConfig, CheckpointPolicy, NatOverride, OutageSpec, PolicyMode,
    RampStep,
};
use crate::sim::SimTime;
use crate::util::json::Json;

/// A named set of overrides applied on top of a base campaign config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScenarioConfig {
    /// Scenario label (table rows, output file names).
    pub name: String,
    pub seed: Option<u64>,
    pub duration_s: Option<SimTime>,
    pub budget_usd: Option<f64>,
    /// Churn-preemption hazard multiplier (busier spot markets).
    pub preempt_multiplier: Option<f64>,
    pub keepalive_s: Option<u64>,
    pub nat_override: Option<NatOverride>,
    /// `Some(None)` disables the outage; `Some(Some(spec))` reschedules it.
    pub outage: Option<Option<OutageSpec>>,
    pub ramp: Option<Vec<RampStep>>,
    pub onprem_slots: Option<u32>,
    pub policy: Option<PolicyMode>,
    /// Job checkpoint/restart policy (`CheckpointPolicy::None` forces
    /// the paper's restart-from-scratch baseline over the base's).
    pub checkpoint: Option<CheckpointPolicy>,
    /// GPU slots carved from each cloud instance (fractional-GPU
    /// busy-hours accounting, arXiv:2205.09232).
    pub gpu_slots_per_instance: Option<u32>,
    /// Checkpoint image size in GB (restore transfer cost,
    /// arXiv:2308.07999).
    pub checkpoint_size_gb: Option<f64>,
    /// Bandwidth for checkpoint restores, megabit/s.
    pub checkpoint_transfer_mbps: Option<f64>,
}

impl ScenarioConfig {
    /// An all-inherit scenario with the given name.
    pub fn named(name: &str) -> Self {
        ScenarioConfig { name: name.to_string(), ..Default::default() }
    }

    /// Materialize the concrete campaign config for this scenario.
    pub fn apply(&self, base: &CampaignConfig) -> CampaignConfig {
        let mut c = base.clone();
        if let Some(v) = self.seed {
            c.seed = v;
        }
        if let Some(v) = self.duration_s {
            c.duration_s = v;
        }
        if let Some(v) = self.budget_usd {
            c.budget_usd = v;
        }
        if let Some(v) = self.preempt_multiplier {
            c.preempt_multiplier = v;
        }
        if let Some(v) = self.keepalive_s {
            c.keepalive_s = v;
        }
        if let Some(v) = self.nat_override {
            c.nat_override = v;
        }
        if let Some(v) = self.outage {
            c.outage = v;
        }
        if let Some(v) = &self.ramp {
            c.ramp = v.clone();
        }
        if let Some(v) = self.onprem_slots {
            c.onprem.slots = v;
        }
        if let Some(v) = self.policy {
            c.policy = v;
        }
        if let Some(v) = self.checkpoint {
            c.checkpoint = v;
        }
        if let Some(v) = self.gpu_slots_per_instance {
            c.gpu_slots_per_instance = v;
        }
        if let Some(v) = self.checkpoint_size_gb {
            c.checkpoint_size_gb = v;
        }
        if let Some(v) = self.checkpoint_transfer_mbps {
            c.checkpoint_transfer_mbps = v;
        }
        c
    }

    /// Canonical serialization of the *override set* (deterministic key
    /// order, only the fields this scenario actually sets).  Includes
    /// the name because sweep responses carry it per row; two requests
    /// that differ only in scenario labels produce different documents
    /// and therefore different cache keys — see `crate::server::cache`.
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::from(self.name.as_str()));
        if let Some(v) = self.seed {
            o.set("seed", Json::from(v));
        }
        if let Some(v) = self.duration_s {
            o.set("duration_s", Json::from(v));
        }
        if let Some(v) = self.budget_usd {
            o.set("budget_usd", Json::from(v));
        }
        if let Some(v) = self.preempt_multiplier {
            o.set("preempt_multiplier", Json::from(v));
        }
        if let Some(v) = self.keepalive_s {
            o.set("keepalive_s", Json::from(v));
        }
        if let Some(v) = &self.nat_override {
            o.set("nat_override", v.canonical_json());
        }
        if let Some(outage) = &self.outage {
            // `Some(None)` (force no outage) serializes as null so it
            // stays distinct from an absent key (inherit the base)
            o.set(
                "outage",
                match outage {
                    None => Json::Null,
                    Some(spec) => spec.canonical_json(),
                },
            );
        }
        if let Some(ramp) = &self.ramp {
            o.set(
                "ramp",
                Json::Arr(ramp.iter().map(RampStep::canonical_json).collect()),
            );
        }
        if let Some(v) = self.onprem_slots {
            o.set("onprem_slots", Json::from(v as u64));
        }
        if let Some(v) = &self.policy {
            o.set("policy", v.canonical_json());
        }
        if let Some(v) = &self.checkpoint {
            o.set("checkpoint", v.canonical_json());
        }
        if let Some(v) = self.gpu_slots_per_instance {
            o.set("gpu_slots_per_instance", Json::from(v as u64));
        }
        if let Some(v) = self.checkpoint_size_gb {
            o.set("checkpoint_size_gb", Json::from(v));
        }
        if let Some(v) = self.checkpoint_transfer_mbps {
            o.set("checkpoint_transfer_mbps", Json::from(v));
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DAY;

    #[test]
    fn empty_scenario_inherits_everything() {
        let base = CampaignConfig::default();
        let c = ScenarioConfig::named("baseline").apply(&base);
        assert_eq!(c.seed, base.seed);
        assert_eq!(c.budget_usd, base.budget_usd);
        assert_eq!(c.duration_s, base.duration_s);
        assert_eq!(c.outage, base.outage);
        assert_eq!(c.ramp, base.ramp);
    }

    #[test]
    fn overrides_replace_base_fields() {
        let base = CampaignConfig::default();
        let mut s = ScenarioConfig::named("tweaked");
        s.seed = Some(7);
        s.budget_usd = Some(1_000.0);
        s.preempt_multiplier = Some(4.0);
        s.keepalive_s = Some(300);
        s.outage = Some(None);
        s.ramp = Some(vec![RampStep { target: 10, hold_s: DAY }]);
        s.onprem_slots = Some(3);
        s.nat_override = Some(NatOverride::Disabled);
        let c = s.apply(&base);
        assert_eq!(c.seed, 7);
        assert_eq!(c.budget_usd, 1_000.0);
        assert_eq!(c.preempt_multiplier, 4.0);
        assert_eq!(c.keepalive_s, 300);
        assert_eq!(c.outage, None);
        assert_eq!(c.ramp.len(), 1);
        assert_eq!(c.onprem.slots, 3);
        assert_eq!(c.nat_override, NatOverride::Disabled);
        // untouched fields still inherit
        assert_eq!(c.tick_s, base.tick_s);
        assert_eq!(c.overhead_fraction, base.overhead_fraction);
    }

    #[test]
    fn outage_double_option_semantics() {
        let mut base = CampaignConfig::default();
        assert!(base.outage.is_some());
        // inherit
        let inherit = ScenarioConfig::named("x").apply(&base);
        assert_eq!(inherit.outage, base.outage);
        // force-disable
        let mut off = ScenarioConfig::named("off");
        off.outage = Some(None);
        assert_eq!(off.apply(&base).outage, None);
        // reschedule on a base without one
        base.outage = None;
        let mut resched = ScenarioConfig::named("resched");
        resched.outage =
            Some(Some(OutageSpec { at_s: DAY, duration_s: 3_600 }));
        assert_eq!(
            resched.apply(&base).outage,
            Some(OutageSpec { at_s: DAY, duration_s: 3_600 })
        );
    }

    #[test]
    fn canonical_json_covers_only_set_fields() {
        let s = ScenarioConfig::named("bare");
        let text = s.canonical_json().to_string_compact();
        assert_eq!(text, r#"{"name":"bare"}"#);

        let mut s = ScenarioConfig::named("full");
        s.seed = Some(9);
        s.budget_usd = Some(100.0);
        s.outage = Some(None);
        let text = s.canonical_json().to_string_compact();
        assert!(text.contains("\"seed\":9"), "{text}");
        assert!(text.contains("\"budget_usd\":100"), "{text}");
        assert!(text.contains("\"outage\":null"), "{text}");
        assert!(!text.contains("keepalive"), "{text}");
    }

    #[test]
    fn canonical_json_distinguishes_inherit_from_no_outage() {
        let inherit = ScenarioConfig::named("x");
        let mut off = ScenarioConfig::named("x");
        off.outage = Some(None);
        assert_ne!(
            inherit.canonical_json().to_string_compact(),
            off.canonical_json().to_string_compact()
        );
    }

    #[test]
    fn checkpoint_override_applies_and_splits_cache_keys() {
        let base = CampaignConfig::default();
        assert_eq!(base.checkpoint, CheckpointPolicy::None);

        // set a policy on top of the paper baseline
        let mut on = ScenarioConfig::named("ckpt");
        on.checkpoint = Some(CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 120,
        });
        let c = on.apply(&base);
        assert_eq!(
            c.checkpoint,
            CheckpointPolicy::Interval { every_s: 1800, resume_overhead_s: 120 }
        );

        // force the paper baseline over a checkpointing base
        let mut ck_base = base.clone();
        ck_base.checkpoint =
            CheckpointPolicy::Interval { every_s: 600, resume_overhead_s: 60 };
        let mut off = ScenarioConfig::named("ckpt");
        off.checkpoint = Some(CheckpointPolicy::None);
        assert_eq!(off.apply(&ck_base).checkpoint, CheckpointPolicy::None);
        // inherit when unset
        let inherit = ScenarioConfig::named("ckpt").apply(&ck_base);
        assert_eq!(inherit.checkpoint, ck_base.checkpoint);

        // same name, different checkpoint policy -> different documents
        // (and therefore different serve cache keys)
        let inherit_doc =
            ScenarioConfig::named("ckpt").canonical_json().to_string_compact();
        let on_doc = on.canonical_json().to_string_compact();
        let off_doc = off.canonical_json().to_string_compact();
        assert_ne!(inherit_doc, on_doc);
        assert_ne!(inherit_doc, off_doc);
        assert_ne!(on_doc, off_doc);
        assert!(on_doc.contains("\"checkpoint\""), "{on_doc}");
        assert!(on_doc.contains("\"every_s\":1800"), "{on_doc}");
    }

    #[test]
    fn new_knob_overrides_apply_and_split_cache_keys() {
        let base = CampaignConfig::default();
        let mut s = ScenarioConfig::named("carved");
        s.gpu_slots_per_instance = Some(4);
        s.checkpoint_size_gb = Some(2.5);
        s.checkpoint_transfer_mbps = Some(500.0);
        let c = s.apply(&base);
        assert_eq!(c.gpu_slots_per_instance, 4);
        assert_eq!(c.checkpoint_size_gb, 2.5);
        assert_eq!(c.checkpoint_transfer_mbps, 500.0);
        // unset inherits the base defaults
        let inherit = ScenarioConfig::named("carved").apply(&base);
        assert_eq!(inherit.gpu_slots_per_instance, 1);
        assert_eq!(inherit.checkpoint_size_gb, 0.0);
        // the overrides appear in (and split) the canonical document
        let doc = s.canonical_json().to_string_compact();
        assert!(doc.contains("\"gpu_slots_per_instance\":4"), "{doc}");
        assert!(doc.contains("\"checkpoint_size_gb\":2.5"), "{doc}");
        assert!(doc.contains("\"checkpoint_transfer_mbps\":500"), "{doc}");
        assert_ne!(
            doc,
            ScenarioConfig::named("carved")
                .canonical_json()
                .to_string_compact()
        );
    }

    #[test]
    fn canonical_json_distinguishes_names() {
        assert_ne!(
            ScenarioConfig::named("a").canonical_json().to_string_compact(),
            ScenarioConfig::named("b").canonical_json().to_string_compact()
        );
    }
}
