//! The campaign runner: the paper's two-week exercise, end to end.
//!
//! Composes every substrate — cloud fleet, HTCondor pool, CE + glidein
//! factory, CloudBank ledger, IceCube workload, monitoring — and advances
//! them on a one-minute tick for the configured duration.  The operator
//! logic (ramp plan, Azure-favoring distribution, outage response,
//! budget-aware resume) lives here, because in the paper it was humans
//! doing exactly this loop.

use crate::cloud::{
    providers, BillingMeter, CloudEvent, CloudSim, Provider,
};
use crate::cloudbank::Ledger;
use crate::condor::pool::PoolEvent;
use crate::condor::startd::{SlotId, Startd};
use crate::condor::CondorPool;
use crate::config::{CampaignConfig, NatOverride};
use crate::coordinator::outage::{OutageState, OutageTransition};
use crate::coordinator::policy::{self, ObservedRates};
use crate::coordinator::rampplan::RampPlan;
use crate::monitoring::Monitor;
use crate::net::NatProfile;
use crate::osg::{
    ComputeElement, GlideinFactory, GlideinFrontend, OsgRegistry, UsageAccounting,
};
use crate::runtime::PhotonExecutable;
use crate::sim::{SimTime, Ticker};
use crate::util::rng::Rng;
use crate::workload::{register_onprem, JobGenerator};
use crate::{sim_info, sim_warn};

/// Statistics from real-compute sampling (PJRT executions).
#[derive(Debug, Clone, Copy, Default)]
pub struct RealComputeStats {
    pub bunches: u64,
    pub photons: u64,
    pub detected: f64,
    pub wall_s: f64,
    pub flops: f64,
}

impl RealComputeStats {
    pub fn photons_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 { self.photons as f64 / self.wall_s } else { 0.0 }
    }

    pub fn flops_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 { self.flops / self.wall_s } else { 0.0 }
    }
}

/// Per-provider settled/unsettled work at campaign end (wall seconds
/// on cloud slots).  The conservation identity the accounting keeps:
/// `goodput + badput + inflight == busy_hours × gpu_slots_per_instance
/// × 3600` for every provider (pinned in
/// `rust/tests/integration_campaign.rs`; with the default whole-GPU
/// accounting the slots factor is 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct ProviderWork {
    pub goodput_s: u64,
    pub badput_s: u64,
    /// Wall seconds of attempts still running when the campaign ended
    /// (neither goodput nor badput yet).
    pub inflight_s: u64,
}

/// Everything the experiments need from a finished campaign.
pub struct CampaignResult {
    pub monitor: Monitor,
    pub usage: UsageAccounting,
    pub ledger: Ledger,
    pub meter: BillingMeter,
    pub pool_stats: crate::condor::PoolStats,
    pub schedd_stats: crate::condor::ScheddStats,
    /// (launches, preemptions, instance-hours) per provider in
    /// `[aws, gcp, azure]` order.
    pub provider_ops: [(u64, u64, f64); 3],
    /// Goodput/badput/in-flight wall seconds per provider (same order).
    pub provider_work: [ProviderWork; 3],
    pub onprem_slots: u32,
    pub real_compute: RealComputeStats,
    /// Ramp transitions + outage window, for figure annotation.
    pub ramp_transitions: Vec<(SimTime, u32)>,
    pub outage_window: Option<(SimTime, SimTime)>,
    pub duration_s: SimTime,
}

/// The assembled campaign.
pub struct Campaign {
    pub config: CampaignConfig,
    fleet: CloudSim,
    pool: CondorPool,
    ce: ComputeElement,
    factory: GlideinFactory,
    frontend: GlideinFrontend,
    #[allow(dead_code)]
    registry: OsgRegistry,
    ledger: Ledger,
    meter: BillingMeter,
    generator: JobGenerator,
    usage: UsageAccounting,
    monitor: Monitor,
    ramp: RampPlan,
    outage: OutageState,
    post_outage: bool,
    control: Ticker,
    sampler: Ticker,
    onprem_slots: u32,
    /// Real-compute sampling (None = analytic-only campaign).
    real_exe: Option<PhotonExecutable>,
    real_stats: RealComputeStats,
    completions_seen: u64,
    budget_exhausted: bool,
}

impl Campaign {
    pub fn new(config: CampaignConfig) -> Self {
        Self::with_engine(config, None)
    }

    /// Attach a compiled photon executable for real-compute sampling.
    pub fn with_engine(
        config: CampaignConfig,
        real_exe: Option<PhotonExecutable>,
    ) -> Self {
        // real-compute bunches execute with the campaign's engine knobs
        // (threads/bunch change wall time only, never results)
        let real_exe = real_exe.map(|exe| exe.with_plan(config.engine.plan()));
        let root = Rng::new(config.seed);
        // scenario knobs rewrite the region catalog before the fleet is
        // built: busier spot markets and/or different NAT infrastructure
        let mut specs = providers::all_regions();
        for spec in &mut specs {
            spec.churn_per_hour *= config.preempt_multiplier;
            match config.nat_override {
                NatOverride::ProviderDefault => {}
                NatOverride::IdleTimeout(t) => {
                    spec.nat = NatProfile {
                        idle_timeout_s: Some(t),
                        label: "scenario-nat",
                    };
                }
                NatOverride::Disabled => {
                    spec.nat = NatProfile::permissive("scenario-no-nat");
                }
            }
        }
        let fleet = CloudSim::new(specs, root.derive("fleet"));
        // effective_checkpoint folds the checkpoint-image transfer
        // time (checkpoint_size_gb / checkpoint_transfer_mbps) into
        // the per-resume overhead the schedd charges as wasted hours
        let mut pool = CondorPool::new()
            .with_negotiation_period(config.negotiation_period_s)
            .with_checkpoint(config.effective_checkpoint());
        let mut onprem_rng = root.derive("onprem");
        let onprem_slots =
            register_onprem(&mut pool, &config.onprem, &mut onprem_rng, 0);

        let mut registry = OsgRegistry::new();
        registry
            .register_resource("icecube-cloud-ce", Provider::Azure, &["icecube"])
            .expect("registry accepts the CE");
        let ce = ComputeElement::new("icecube-cloud-ce", Provider::Azure, &["icecube"]);
        let factory =
            GlideinFactory::new("icecube", fleet.regions().map(|(r, _)| r));
        let frontend = GlideinFrontend::default();

        let ledger = Ledger::new(
            crate::cloudbank::AccountSet::paper_setup(0),
            config.budget_usd,
            &config.alert_thresholds,
        );
        let meter = BillingMeter::with_overhead(config.overhead_fraction)
            .with_gpu_slots(config.gpu_slots_per_instance);

        let flops_per_bunch = real_exe
            .as_ref()
            .map(|e| e.meta.flops_estimate)
            .unwrap_or(config.flops_per_bunch);
        let generator = JobGenerator::new(
            config.generator.clone(),
            flops_per_bunch,
            root.derive("workload"),
        );

        let ramp = RampPlan::new(config.ramp.clone());
        let outage = OutageState::new(config.outage);
        let control = Ticker::new(config.control_period_s, 0);
        let sampler = Ticker::new(config.sample_every_s, 0);

        Campaign {
            config,
            fleet,
            pool,
            ce,
            factory,
            frontend,
            registry,
            ledger,
            meter,
            generator,
            usage: UsageAccounting::new(),
            monitor: Monitor::new(),
            ramp,
            outage,
            post_outage: false,
            control,
            sampler,
            onprem_slots,
            real_exe,
            real_stats: RealComputeStats::default(),
            completions_seen: 0,
            budget_exhausted: false,
        }
    }

    /// Desired total cloud GPUs at `now`, applying operator judgment.
    fn desired_total(&self, now: SimTime) -> u32 {
        if self.outage.is_active() || self.budget_exhausted {
            return 0;
        }
        if self.post_outage {
            // the paper: resumed at 1k GPUs with ~20% of budget left
            return self.config.post_outage_target;
        }
        self.ramp.target_at(now)
    }

    fn observed_rates(&self) -> ObservedRates {
        let mut obs = ObservedRates::default();
        let mut hours = [0.0f64; 3];
        let mut preempts = [0u64; 3];
        for (rid, region) in self.fleet.regions() {
            let i = policy::provider_index(region.spec().provider);
            let (_, p) = self.fleet.region_stats(rid);
            preempts[i] += p;
            hours[i] += self.meter.provider(region.spec().provider).instance_hours;
        }
        for i in 0..3 {
            if hours[i] > 0.0 {
                obs.preempt_per_hour[i] = preempts[i] as f64 / hours[i];
            }
        }
        obs
    }

    fn control_cycle(&mut self, now: SimTime) {
        // budget guardrail
        if self.ledger.remaining_fraction() <= self.config.budget_reserve_fraction
            && !self.budget_exhausted
        {
            self.budget_exhausted = true;
            sim_warn!(now, "operator", "budget reserve reached; deprovisioning");
        }
        let total = self.desired_total(now);
        let observed = self.observed_rates();
        let targets = policy::distribute(total, &self.fleet, &self.config.policy, Some(&observed));
        // scale-ups silently fail while the CE is down (paper behaviour);
        // scale-downs always apply
        let _ = self.factory.apply_targets(&targets, &mut self.ce, &mut self.fleet, now);
        // frontend demand is recorded for monitoring (manual mode ignores it)
        self.frontend.demand(&self.pool.schedd);
        // CloudBank ingest
        self.ledger.sync_from_meter(&self.meter, now);
    }

    fn handle_cloud_events(&mut self, events: Vec<CloudEvent>, now: SimTime) {
        for ev in events {
            match ev {
                CloudEvent::Launched(_) => {}
                CloudEvent::BecameRunning(id) => {
                    if self.outage.is_active() {
                        continue; // cannot reach the CE to register
                    }
                    let region = self.fleet.instance(id).region;
                    let spec = self.fleet.region(region).spec();
                    let startd = Startd::new(
                        SlotId::Cloud(id),
                        "cloud",
                        Some(spec.provider),
                        spec.name,
                        spec.nat,
                        self.config.keepalive_s,
                        now,
                    );
                    self.pool.add_startd(startd, now);
                }
                CloudEvent::Preempted(id, _) | CloudEvent::Terminated(id) => {
                    let mut events = Vec::new();
                    self.pool.remove_startd(SlotId::Cloud(id), now, &mut events);
                }
            }
        }
    }

    fn handle_pool_events(&mut self, events: Vec<PoolEvent>, _now: SimTime) {
        for ev in events {
            if let PoolEvent::JobCompleted(_) = ev {
                self.completions_seen += 1;
                if let (Some(exe), Some(rc)) =
                    (&self.real_exe, &self.config.real_compute)
                {
                    if self.completions_seen % rc.every_n_completions == 0 {
                        let seed = (self.completions_seen % u32::MAX as u64) as u32;
                        if let Ok(r) = exe.run_seeded(seed) {
                            self.real_stats.bunches += 1;
                            self.real_stats.photons += exe.photons_per_bunch();
                            self.real_stats.detected += r.detected() as f64;
                            self.real_stats.wall_s += r.wall_s;
                            self.real_stats.flops += exe.meta.flops_estimate;
                        }
                    }
                }
            }
        }
    }

    fn sample(&mut self, now: SimTime) {
        let counts = self.fleet.counts();
        self.monitor.sample("gpus.total", now, counts.live() as f64);
        self.monitor.sample("gpus.running", now, counts.running as f64);
        self.monitor.sample("gpus.target", now, counts.target as f64);
        for p in Provider::ALL {
            let c = self.fleet.counts_by_provider(p);
            self.monitor
                .sample(&format!("gpus.{}", p.name()), now, c.live() as f64);
        }
        self.monitor
            .sample("jobs.idle", now, self.pool.schedd.idle_count() as f64);
        self.monitor
            .sample("jobs.running", now, self.pool.schedd.running_count() as f64);
        self.monitor.sample(
            "jobs.running.cloud",
            now,
            self.pool.running_by_tag("cloud") as f64,
        );
        self.monitor.sample(
            "jobs.running.onprem",
            now,
            self.pool.running_by_tag("onprem") as f64,
        );
        self.monitor
            .sample("budget.spent", now, self.ledger.total_spent());
        self.monitor.sample(
            "budget.remaining_fraction",
            now,
            self.ledger.remaining_fraction(),
        );
        self.monitor
            .sample("spend.rate_per_day", now, self.ledger.spend_rate_per_day());
    }

    /// Operator reaction to the outage beginning: the WMS is dark, jobs
    /// on workers are lost, and "we quickly de-provisioned all the
    /// worker instances" (paper behaviour).
    fn outage_began(&mut self, now: SimTime) {
        sim_warn!(now, "outage", "network outage at the CE-hosting provider; WMS down");
        self.ce.set_available(false);
        let mut events = Vec::new();
        self.pool.begin_outage(now, &mut events);
        self.factory.deprovision_all(&mut self.fleet);
    }

    /// Operator reaction to the outage resolving: the CE is reachable
    /// again, and with ~20% of budget left the fleet resumes low.
    fn outage_ended(&mut self, now: SimTime) {
        sim_info!(
            now,
            "outage",
            "outage resolved; resuming at {} GPUs",
            self.config.post_outage_target
        );
        self.ce.set_available(true);
        self.pool.end_outage();
        if self.ledger.remaining_fraction()
            <= self.config.low_budget_resume_fraction
        {
            self.post_outage = true;
        }
    }

    /// Advance one tick.
    pub fn tick(&mut self, now: SimTime) {
        // 1. outage schedule + operator response
        match self.outage.advance(now) {
            OutageTransition::Began => self.outage_began(now),
            OutageTransition::Ended => self.outage_ended(now),
            OutageTransition::BeganAndEnded => {
                // a control tick coarser than the window: the outage
                // came and went between observations, but its effects
                // are real — the full begin AND end reactions fire
                // within this one tick
                sim_warn!(
                    now,
                    "outage",
                    "CE-host outage began and ended within one tick; \
                     applying full begin/end reaction"
                );
                self.outage_began(now);
                self.outage_ended(now);
            }
            OutageTransition::None => {}
        }

        // 2. control loops on their own cadence
        if self.control.due(now) {
            self.control_cycle(now);
        }

        // 3. cloud dynamics
        let cloud_events = self.fleet.tick(now, self.config.tick_s);
        self.handle_cloud_events(cloud_events, now);

        // 4. workload backlog
        let workers = self.pool.num_startds();
        self.generator.replenish(&mut self.pool.schedd, workers, now);

        // 5. workload management plane
        let mut pool_events = Vec::new();
        self.pool.tick(now, &mut pool_events);
        self.handle_pool_events(pool_events, now);

        // 6. metering + usage accounting
        self.meter.accrue(&self.fleet, self.config.tick_s);
        self.meter
            .accrue_busy(self.pool.busy_by_provider(), self.config.tick_s);
        let (cloud_busy, onprem_busy) = self.pool.running_cloud_onprem();
        self.usage.accrue(now, self.config.tick_s, cloud_busy, onprem_busy);

        // 7. monitoring samples
        if self.sampler.due(now) {
            self.sample(now);
        }
    }

    /// Run the whole campaign and return the results.
    pub fn run(mut self) -> CampaignResult {
        let ticks = self.config.num_ticks();
        for step in 0..ticks {
            let now = step * self.config.tick_s;
            self.tick(now);
        }
        self.finish()
    }

    /// Finalize without running (used by tests that drive ticks manually).
    pub fn finish(mut self) -> CampaignResult {
        let now = self.config.duration_s;
        self.ledger.sync_from_meter(&self.meter, now);
        let mut provider_ops = [(0u64, 0u64, 0.0f64); 3];
        for (rid, region) in self.fleet.regions() {
            let i = policy::provider_index(region.spec().provider);
            let (l, p) = self.fleet.region_stats(rid);
            provider_ops[i].0 += l;
            provider_ops[i].1 += p;
        }
        for p in Provider::ALL {
            provider_ops[policy::provider_index(p)].2 =
                self.meter.provider(p).instance_hours;
        }
        // accrual covered [0, num_ticks × tick_s); measure in-flight
        // wall to the same horizon so busy == good + bad + inflight
        // holds exactly per provider
        let accrued_until = self.config.num_ticks() * self.config.tick_s;
        let inflight = self.pool.inflight_by_provider(accrued_until);
        let mut provider_work = [ProviderWork::default(); 3];
        for i in 0..3 {
            provider_work[i] = ProviderWork {
                goodput_s: self.pool.stats.goodput_by_provider[i],
                badput_s: self.pool.stats.badput_by_provider[i],
                inflight_s: inflight[i],
            };
        }
        CampaignResult {
            monitor: self.monitor,
            usage: self.usage,
            ledger: self.ledger,
            meter: self.meter,
            pool_stats: self.pool.stats,
            schedd_stats: self.pool.schedd.stats,
            provider_ops,
            provider_work,
            onprem_slots: self.onprem_slots,
            real_compute: self.real_stats,
            ramp_transitions: self.ramp.transitions(),
            outage_window: self.outage.window(),
            duration_s: self.config.duration_s,
        }
    }

    // accessors used by integration tests
    pub fn fleet(&self) -> &CloudSim {
        &self.fleet
    }

    pub fn pool(&self) -> &CondorPool {
        &self.pool
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{DAY, HOUR, MINUTE};

    /// A shrunk two-day campaign for fast unit testing.
    fn small_config() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 2 * DAY;
        c.ramp = vec![
            crate::config::RampStep { target: 30, hold_s: 6 * HOUR },
            crate::config::RampStep { target: 80, hold_s: 30 * DAY },
        ];
        c.outage = Some(crate::config::OutageSpec {
            at_s: DAY,
            duration_s: 2 * HOUR,
        });
        c.post_outage_target = 40;
        c.low_budget_resume_fraction = 1.1; // always resume low in tests
        c.onprem.slots = 60;
        c.generator.min_backlog = 200;
        c.budget_usd = 5_000.0;
        c
    }

    #[test]
    fn campaign_runs_and_produces_shape() {
        let result = Campaign::new(small_config()).run();
        let gpus = result.monitor.get("gpus.total").unwrap();
        assert!(!gpus.is_empty());
        // ramp reached ~80 before the outage
        let pre_outage_max = gpus
            .points
            .iter()
            .filter(|(t, _)| *t < DAY)
            .map(|(_, v)| *v)
            .fold(0.0, f64::max);
        assert!(pre_outage_max >= 70.0, "pre_outage_max={pre_outage_max}");
        // during the outage the fleet must collapse to ~0
        let outage_min = gpus
            .points
            .iter()
            .filter(|(t, _)| *t > DAY + HOUR && *t < DAY + 2 * HOUR)
            .map(|(_, v)| *v)
            .fold(f64::INFINITY, f64::min);
        assert!(outage_min <= 5.0, "outage_min={outage_min}");
        // after the outage it resumes at the reduced target
        let last = gpus.last().unwrap();
        assert!(last > 30.0 && last < 55.0, "post-outage level={last}");
    }

    #[test]
    fn jobs_flow_and_accounting_accrues() {
        let result = Campaign::new(small_config()).run();
        assert!(result.schedd_stats.completed > 100);
        assert!(result.usage.total_onprem_gpu_hours() > 0.0);
        assert!(result.usage.total_cloud_gpu_hours() > 0.0);
        assert!(result.ledger.total_spent() > 0.0);
        assert!(result.meter.gpu_days() > 0.0);
    }

    #[test]
    fn outage_interrupts_jobs() {
        let result = Campaign::new(small_config()).run();
        assert!(result.schedd_stats.interrupted > 0);
        assert!(result.schedd_stats.badput_s > 0);
    }

    #[test]
    fn no_outage_config_never_collapses() {
        let mut c = small_config();
        c.outage = None;
        let result = Campaign::new(c).run();
        let gpus = result.monitor.get("gpus.total").unwrap();
        let late_min = gpus
            .points
            .iter()
            .filter(|(t, _)| *t > DAY)
            .map(|(_, v)| *v)
            .fold(f64::INFINITY, f64::min);
        assert!(late_min > 50.0, "late_min={late_min}");
    }

    #[test]
    fn deterministic_replay() {
        let a = Campaign::new(small_config()).run();
        let b = Campaign::new(small_config()).run();
        assert_eq!(a.schedd_stats.completed, b.schedd_stats.completed);
        assert_eq!(a.ledger.total_spent(), b.ledger.total_spent());
        assert_eq!(
            a.monitor.get("gpus.total").unwrap().points,
            b.monitor.get("gpus.total").unwrap().points
        );
    }

    #[test]
    fn tiny_budget_halts_provisioning() {
        let mut c = small_config();
        c.budget_usd = 20.0; // exhausted within hours
        c.outage = None;
        let result = Campaign::new(c).run();
        let gpus = result.monitor.get("gpus.total").unwrap();
        assert!(gpus.last().unwrap() == 0.0, "fleet must drain on empty budget");
        assert!(result.ledger.remaining_fraction() < 0.1);
    }

    #[test]
    fn keepalive_misconfiguration_produces_nat_drops() {
        let mut c = small_config();
        c.keepalive_s = 300; // the §IV misconfiguration
        c.outage = None;
        c.duration_s = 12 * HOUR;
        let result = Campaign::new(c).run();
        assert!(
            result.pool_stats.nat_drops > 50,
            "azure workers must churn, got {}",
            result.pool_stats.nat_drops
        );
    }

    #[test]
    fn tuned_keepalive_has_zero_nat_drops() {
        let mut c = small_config();
        c.outage = None;
        c.duration_s = 12 * HOUR;
        let result = Campaign::new(c).run();
        assert_eq!(result.pool_stats.nat_drops, 0);
    }

    #[test]
    fn ticks_are_one_minute_by_default() {
        assert_eq!(CampaignConfig::default().tick_s, MINUTE);
    }

    #[test]
    fn nat_override_disabled_prevents_keepalive_storm() {
        // the §IV misconfiguration, but on NAT-free infrastructure
        let mut c = small_config();
        c.keepalive_s = 300;
        c.nat_override = crate::config::NatOverride::Disabled;
        c.outage = None;
        c.duration_s = 12 * HOUR;
        let result = Campaign::new(c).run();
        assert_eq!(result.pool_stats.nat_drops, 0);
    }

    #[test]
    fn nat_override_timeout_applies_everywhere() {
        // a 120 s idle timeout breaks even the tuned 60 s keepalive? no —
        // 60 < 120 survives; but a 200 s keepalive dies on every region.
        let mut c = small_config();
        c.keepalive_s = 200;
        c.nat_override = crate::config::NatOverride::IdleTimeout(120);
        c.outage = None;
        c.duration_s = 12 * HOUR;
        let result = Campaign::new(c).run();
        assert!(result.pool_stats.nat_drops > 0);
    }

    #[test]
    fn coarse_tick_cannot_skip_a_short_outage() {
        // regression: a 10-minute tick over a 5-minute outage window
        // used to skip the whole outage — no jobs lost, no operator
        // reaction, the campaign finished at full ramp as if §IV never
        // happened.  The catch-up transition must fire the full
        // begin/end response: here the post-outage resume drops the
        // fleet from the 80-GPU ramp to the 40-GPU resume target.
        let mut c = small_config();
        c.tick_s = 10 * MINUTE;
        // window strictly inside one tick: [DAY+61, DAY+361) contains
        // no multiple of 600
        c.outage = Some(crate::config::OutageSpec {
            at_s: DAY + 61,
            duration_s: 5 * MINUTE,
        });
        let result = Campaign::new(c).run();
        let last = result
            .monitor
            .get("gpus.total")
            .unwrap()
            .last()
            .unwrap();
        assert!(
            last > 20.0 && last < 60.0,
            "post-outage resume target must be in effect, fleet={last}"
        );
        assert!(
            result.schedd_stats.interrupted > 0,
            "the skipped-window outage must cost running jobs"
        );
    }

    #[test]
    fn preempt_multiplier_raises_churn() {
        let run = |m: f64| {
            let mut c = small_config();
            c.outage = None;
            c.preempt_multiplier = m;
            let r = Campaign::new(c).run();
            r.provider_ops.iter().map(|(_, p, _)| *p).sum::<u64>()
        };
        let base = run(1.0);
        let hot = run(25.0);
        assert!(hot > base, "hot={hot} base={base}");
    }
}
