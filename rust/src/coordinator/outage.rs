//! Outage injection: the CE-host provider network failure of §IV.

use crate::config::OutageSpec;
use crate::sim::SimTime;

/// Phase transitions the campaign must react to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutageTransition {
    None,
    /// The outage just began at this tick.
    Began,
    /// The outage just ended at this tick.
    Ended,
    /// A single tick jumped over the whole window: the outage both
    /// began and ended since the last observation.  The campaign must
    /// apply the full begin→end reaction (jobs were lost, the operator
    /// response fires) — before this catch-up transition existed, a
    /// control tick coarser than the window silently skipped the
    /// outage and `occurred` stayed false forever.
    BeganAndEnded,
}

/// Tracks the scheduled outage window.
#[derive(Debug, Clone)]
pub struct OutageState {
    spec: Option<OutageSpec>,
    active: bool,
    /// True once the outage has come and gone.
    pub occurred: bool,
}

impl OutageState {
    pub fn new(spec: Option<OutageSpec>) -> Self {
        OutageState { spec, active: false, occurred: false }
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Advance to `now`; returns the transition (if any) at this tick.
    pub fn advance(&mut self, now: SimTime) -> OutageTransition {
        let Some(spec) = self.spec else {
            return OutageTransition::None;
        };
        let end = spec.at_s + spec.duration_s;
        if !self.active && !self.occurred && now >= spec.at_s {
            if now < end {
                self.active = true;
                return OutageTransition::Began;
            }
            // the tick straddled (or landed exactly on the end of) the
            // whole window without ever observing it active
            self.occurred = true;
            return OutageTransition::BeganAndEnded;
        }
        if self.active && now >= end {
            self.active = false;
            self.occurred = true;
            return OutageTransition::Ended;
        }
        OutageTransition::None
    }

    pub fn window(&self) -> Option<(SimTime, SimTime)> {
        self.spec.map(|s| (s.at_s, s.at_s + s.duration_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut o = OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(0), OutageTransition::None);
        assert_eq!(o.advance(99), OutageTransition::None);
        assert_eq!(o.advance(100), OutageTransition::Began);
        assert!(o.is_active());
        assert_eq!(o.advance(120), OutageTransition::None);
        assert_eq!(o.advance(150), OutageTransition::Ended);
        assert!(!o.is_active());
        assert!(o.occurred);
        // does not re-trigger
        assert_eq!(o.advance(200), OutageTransition::None);
    }

    #[test]
    fn none_spec_never_fires() {
        let mut o = OutageState::new(None);
        for t in 0..1000 {
            assert_eq!(o.advance(t), OutageTransition::None);
        }
    }

    #[test]
    fn coarse_ticks_still_catch_window() {
        // tick lands inside the window, end caught later
        let mut o = OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(130), OutageTransition::Began);
        assert_eq!(o.advance(400), OutageTransition::Ended);
    }

    #[test]
    fn tick_straddling_whole_window_fires_catchup() {
        // regression: a 10-minute tick over a 5-minute window used to
        // skip the outage entirely (no transition, occurred == false)
        let mut o =
            OutageState::new(Some(OutageSpec { at_s: 620, duration_s: 300 }));
        assert_eq!(o.advance(600), OutageTransition::None);
        assert_eq!(o.advance(1200), OutageTransition::BeganAndEnded);
        assert!(!o.is_active());
        assert!(o.occurred);
        // never re-fires
        assert_eq!(o.advance(1800), OutageTransition::None);
    }

    #[test]
    fn tick_landing_exactly_on_end_fires_catchup() {
        // the window is [at, at + duration): a first observation at
        // exactly `end` never saw it active and must still catch up
        let mut o =
            OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(50), OutageTransition::None);
        assert_eq!(o.advance(150), OutageTransition::BeganAndEnded);
        assert!(o.occurred);
        assert_eq!(o.advance(200), OutageTransition::None);
    }

    #[test]
    fn tick_inside_window_still_fires_began_then_ended() {
        // the catch-up path must not swallow the normal split lifecycle
        let mut o =
            OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(149), OutageTransition::Began);
        assert_eq!(o.advance(150), OutageTransition::Ended);
        assert!(o.occurred);
    }
}
