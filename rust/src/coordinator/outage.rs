//! Outage injection: the CE-host provider network failure of §IV.

use crate::config::OutageSpec;
use crate::sim::SimTime;

/// Phase transitions the campaign must react to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutageTransition {
    None,
    /// The outage just began at this tick.
    Began,
    /// The outage just ended at this tick.
    Ended,
}

/// Tracks the scheduled outage window.
#[derive(Debug, Clone)]
pub struct OutageState {
    spec: Option<OutageSpec>,
    active: bool,
    /// True once the outage has come and gone.
    pub occurred: bool,
}

impl OutageState {
    pub fn new(spec: Option<OutageSpec>) -> Self {
        OutageState { spec, active: false, occurred: false }
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Advance to `now`; returns the transition (if any) at this tick.
    pub fn advance(&mut self, now: SimTime) -> OutageTransition {
        let Some(spec) = self.spec else {
            return OutageTransition::None;
        };
        let end = spec.at_s + spec.duration_s;
        if !self.active && !self.occurred && now >= spec.at_s && now < end {
            self.active = true;
            return OutageTransition::Began;
        }
        if self.active && now >= end {
            self.active = false;
            self.occurred = true;
            return OutageTransition::Ended;
        }
        OutageTransition::None
    }

    pub fn window(&self) -> Option<(SimTime, SimTime)> {
        self.spec.map(|s| (s.at_s, s.at_s + s.duration_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut o = OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(0), OutageTransition::None);
        assert_eq!(o.advance(99), OutageTransition::None);
        assert_eq!(o.advance(100), OutageTransition::Began);
        assert!(o.is_active());
        assert_eq!(o.advance(120), OutageTransition::None);
        assert_eq!(o.advance(150), OutageTransition::Ended);
        assert!(!o.is_active());
        assert!(o.occurred);
        // does not re-trigger
        assert_eq!(o.advance(200), OutageTransition::None);
    }

    #[test]
    fn none_spec_never_fires() {
        let mut o = OutageState::new(None);
        for t in 0..1000 {
            assert_eq!(o.advance(t), OutageTransition::None);
        }
    }

    #[test]
    fn coarse_ticks_still_catch_window() {
        // tick lands inside the window, end caught later
        let mut o = OutageState::new(Some(OutageSpec { at_s: 100, duration_s: 50 }));
        assert_eq!(o.advance(130), OutageTransition::Began);
        assert_eq!(o.advance(400), OutageTransition::Ended);
    }
}
