//! # icecloud
//!
//! Reproduction of *"Expanding IceCube GPU computing into the Clouds"*
//! (Sfiligoi et al., eScience 2021): a multi-cloud spot-GPU provisioning
//! stack integrated into an OSG/HTCondor-style workload management system,
//! replayed on a deterministic discrete-event simulator, with the IceCube
//! photon-propagation workload compiled AOT (JAX + Pallas → HLO text) and
//! executed from Rust through the PJRT CPU client.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record of every figure and table.

pub mod cloud;
pub mod cloudbank;
pub mod condor;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod monitoring;
pub mod net;
pub mod osg;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
