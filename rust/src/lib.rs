//! # icecloud
//!
//! Reproduction of *"Expanding IceCube GPU computing into the Clouds"*
//! (Sfiligoi et al., eScience 2021): a multi-cloud spot-GPU provisioning
//! stack integrated into an OSG/HTCondor-style workload management system,
//! replayed on a deterministic discrete-event simulator, with the IceCube
//! photon-propagation workload modeled after the AOT (JAX + Pallas) kernels
//! and executed by a native Monte-Carlo engine that mirrors the Python
//! oracle (`python/compile/kernels/ref.py`).
//!
//! Beyond the single paper replay, the [`sweep`] subsystem runs scenario
//! matrices — budgets, spot-market weather, NAT infrastructure, ramp
//! plans — as parallel deterministic replays and reduces them to one
//! cost-vs-EFLOP-hours comparison table, and the [`server`] subsystem
//! (`icecloud serve`) exposes those sweeps as a zero-dependency HTTP
//! service with a content-addressed result cache.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record of every figure and table.

pub mod cloud;
pub mod cloudbank;
pub mod condor;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod monitoring;
pub mod net;
pub mod osg;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;
