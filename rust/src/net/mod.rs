//! Network substrate: NAT gateways and long-lived TCP connections.
//!
//! This module exists to reproduce the paper's §IV operational finding:
//! Azure's default NAT drops *idle* outbound TCP flows after 4 minutes,
//! while the default OSG/HTCondor keepalive interval was 5 minutes — so
//! every job-management connection silently died between keepalives and
//! user jobs were constantly preempted until the keepalive was lowered.
//!
//! The model: a [`Connection`] carries `last_activity`; traversing a
//! [`NatProfile`] with `idle_timeout_s` means a send after a gap larger
//! than the timeout *fails* (the mapping is gone — the sender only finds
//! out when it next writes, exactly like a silently-dropped TCP flow).

use crate::sim::SimTime;

/// NAT behaviour on the path of a connection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NatProfile {
    /// Idle seconds after which the address mapping is discarded.
    /// `None` = no NAT on path (or a NAT without idle expiry).
    pub idle_timeout_s: Option<u64>,
    /// Human-readable label for logs ("azure-default-nat", ...).
    pub label: &'static str,
}

impl NatProfile {
    /// Azure default outbound NAT: 4-minute idle timeout (the culprit).
    pub fn azure_default() -> Self {
        NatProfile { idle_timeout_s: Some(240), label: "azure-default-nat" }
    }

    /// Cloud NAT without an aggressive idle timeout (AWS/GCP behaved fine
    /// with the 5-minute OSG default in the paper's validation runs).
    pub fn permissive(label: &'static str) -> Self {
        NatProfile { idle_timeout_s: None, label }
    }

    /// Would a mapping idle for `gap` seconds have been dropped?
    pub fn drops_after(&self, gap: u64) -> bool {
        match self.idle_timeout_s {
            Some(t) => gap > t,
            None => false,
        }
    }
}

/// Outcome of attempting a send on a [`Connection`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendOutcome {
    /// Delivered; the connection's activity clock resets.
    Delivered,
    /// The NAT dropped the mapping during the idle gap; the connection is
    /// now dead and must be re-established.
    DroppedByNat,
    /// Connection was already dead (previous drop or explicit sever).
    NotConnected,
}

/// A long-lived management connection (startd→collector, startd→schedd).
#[derive(Debug, Clone)]
pub struct Connection {
    pub nat: NatProfile,
    pub established_at: SimTime,
    pub last_activity: SimTime,
    pub alive: bool,
    /// Total successful sends (stats / tests).
    pub delivered: u64,
    /// Total sends that found the mapping dropped.
    pub nat_drops: u64,
}

impl Connection {
    pub fn establish(now: SimTime, nat: NatProfile) -> Self {
        Connection {
            nat,
            established_at: now,
            last_activity: now,
            alive: true,
            delivered: 0,
            nat_drops: 0,
        }
    }

    /// Attempt to send at `now`.
    pub fn try_send(&mut self, now: SimTime) -> SendOutcome {
        if !self.alive {
            return SendOutcome::NotConnected;
        }
        let gap = now.saturating_sub(self.last_activity);
        if self.nat.drops_after(gap) {
            self.alive = false;
            self.nat_drops += 1;
            return SendOutcome::DroppedByNat;
        }
        self.last_activity = now;
        self.delivered += 1;
        SendOutcome::Delivered
    }

    /// Sever the connection from outside (e.g. a region network outage).
    pub fn sever(&mut self) {
        self.alive = false;
    }

    /// Re-establish after a drop (the caller models reconnect latency).
    pub fn reconnect(&mut self, now: SimTime) {
        self.alive = true;
        self.established_at = now;
        self.last_activity = now;
    }

    pub fn idle_for(&self, now: SimTime) -> u64 {
        now.saturating_sub(self.last_activity)
    }
}

/// Will a keepalive loop of period `keepalive_s` survive this NAT?
///
/// This predicate *is* the paper's incident in one line: the OSG default
/// `keepalive_s = 300` does not survive Azure's 240 s idle timeout.
pub fn keepalive_survives(nat: &NatProfile, keepalive_s: u64) -> bool {
    !nat.drops_after(keepalive_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permissive_nat_never_drops() {
        let nat = NatProfile::permissive("aws");
        let mut c = Connection::establish(0, nat);
        for t in [1000u64, 1_000_000, 2_000_000] {
            assert_eq!(c.try_send(t), SendOutcome::Delivered);
        }
        assert_eq!(c.nat_drops, 0);
    }

    #[test]
    fn azure_nat_drops_after_240s_idle() {
        let mut c = Connection::establish(0, NatProfile::azure_default());
        assert_eq!(c.try_send(240), SendOutcome::Delivered); // exactly at limit
        assert_eq!(c.try_send(481), SendOutcome::DroppedByNat); // 241 s gap
        assert!(!c.alive);
        assert_eq!(c.try_send(482), SendOutcome::NotConnected);
    }

    #[test]
    fn keepalive_300_fails_on_azure_default() {
        // The §IV incident: OSG default 5-min keepalive vs Azure 4-min NAT.
        let azure = NatProfile::azure_default();
        assert!(!keepalive_survives(&azure, 300));
        assert!(keepalive_survives(&azure, 240));
        assert!(keepalive_survives(&azure, 60));
        let aws = NatProfile::permissive("aws");
        assert!(keepalive_survives(&aws, 300));
    }

    #[test]
    fn reconnect_restores_flow() {
        let mut c = Connection::establish(0, NatProfile::azure_default());
        assert_eq!(c.try_send(500), SendOutcome::DroppedByNat);
        c.reconnect(510);
        assert_eq!(c.try_send(520), SendOutcome::Delivered);
        assert_eq!(c.nat_drops, 1);
        assert_eq!(c.delivered, 1);
    }

    #[test]
    fn sever_kills_connection() {
        let mut c = Connection::establish(0, NatProfile::permissive("gcp"));
        c.sever();
        assert_eq!(c.try_send(1), SendOutcome::NotConnected);
    }

    #[test]
    fn idle_tracking() {
        let mut c = Connection::establish(100, NatProfile::permissive("x"));
        assert_eq!(c.idle_for(160), 60);
        c.try_send(160);
        assert_eq!(c.idle_for(170), 10);
    }

    #[test]
    fn steady_keepalive_under_timeout_survives_forever() {
        let mut c = Connection::establish(0, NatProfile::azure_default());
        let mut t = 0;
        for _ in 0..1000 {
            t += 60; // 1-minute keepalives
            assert_eq!(c.try_send(t), SendOutcome::Delivered);
        }
    }

    #[test]
    fn steady_keepalive_over_timeout_dies_on_second_send() {
        let mut c = Connection::establish(0, NatProfile::azure_default());
        assert_eq!(c.try_send(300), SendOutcome::DroppedByNat);
    }
}
