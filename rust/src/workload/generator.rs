//! Job backlog generator.
//!
//! IceCube's production queue always has more simulation work than GPUs
//! ("plenty of work queued" is the operating regime that makes doubling
//! capacity useful).  The generator keeps the schedd's idle queue topped
//! up to a multiple of the worker population so the negotiator is never
//! starved, without materializing millions of job records up front.

use super::icecube::{job_spec, JobSpec, RuntimeModel};
use crate::condor::job::{gpu_job_ad, gpu_requirements};
use crate::condor::Schedd;
use crate::sim::SimTime;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Keep idle queue at least this multiple of the worker count.
    pub backlog_factor: f64,
    /// Floor for the idle queue even with no workers yet.
    pub min_backlog: usize,
    /// Memory request carried in the job ad (MB).
    pub request_memory_mb: i64,
    pub runtimes: RuntimeModel,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            backlog_factor: 1.5,
            min_backlog: 500,
            request_memory_mb: 8192,
            runtimes: RuntimeModel::default(),
        }
    }
}

/// The backlog maintainer.
pub struct JobGenerator {
    pub config: GeneratorConfig,
    rng: Rng,
    flops_per_bunch: f64,
    pub submitted: u64,
}

impl JobGenerator {
    pub fn new(config: GeneratorConfig, flops_per_bunch: f64, rng: Rng) -> Self {
        JobGenerator { config, rng, flops_per_bunch, submitted: 0 }
    }

    /// Sample one job spec (used directly by unit benches too).
    pub fn sample_spec(&mut self) -> JobSpec {
        let runtime = self.config.runtimes.sample(&mut self.rng);
        job_spec(runtime, self.flops_per_bunch)
    }

    /// Top the idle queue up to the configured backlog.
    /// Returns how many jobs were submitted.
    pub fn replenish(
        &mut self,
        schedd: &mut Schedd,
        workers: usize,
        now: SimTime,
    ) -> usize {
        let want = ((workers as f64 * self.config.backlog_factor) as usize)
            .max(self.config.min_backlog);
        let idle = schedd.idle_count();
        if idle >= want {
            return 0;
        }
        let n = want - idle;
        for _ in 0..n {
            let spec = self.sample_spec();
            schedd.submit(
                "icecube",
                spec.runtime_s,
                spec.flops,
                spec.bunches,
                gpu_job_ad("icecube", self.config.request_memory_mb),
                gpu_requirements(),
                now,
            );
        }
        self.submitted += n as u64;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn generator() -> JobGenerator {
        JobGenerator::new(GeneratorConfig::default(), 1e12, Rng::new(5))
    }

    #[test]
    fn fills_to_min_backlog() {
        let mut g = generator();
        let mut s = Schedd::new();
        let n = g.replenish(&mut s, 0, 0);
        assert_eq!(n, 500);
        assert_eq!(s.idle_count(), 500);
    }

    #[test]
    fn scales_with_worker_count() {
        let mut g = generator();
        let mut s = Schedd::new();
        g.replenish(&mut s, 2000, 0);
        assert_eq!(s.idle_count(), 3000);
    }

    #[test]
    fn no_overfill_when_queue_deep() {
        let mut g = generator();
        let mut s = Schedd::new();
        g.replenish(&mut s, 1000, 0);
        let before = s.idle_count();
        let n = g.replenish(&mut s, 100, 1);
        assert_eq!(n, 0);
        assert_eq!(s.idle_count(), before);
    }

    #[test]
    fn submitted_jobs_are_icecube_gpu_jobs() {
        let mut g = generator();
        let mut s = Schedd::new();
        g.replenish(&mut s, 0, 7);
        let job = s.job(crate::condor::JobId(0));
        assert_eq!(job.owner, "icecube");
        assert!(job.runtime_s >= 600);
        assert!(job.flops > 0.0);
        assert!(job.bunches >= 1);
        assert_eq!(job.submitted_at, 7);
    }

    #[test]
    fn deterministic_given_seed() {
        let sample = |seed| {
            let mut g = JobGenerator::new(
                GeneratorConfig::default(), 1e12, Rng::new(seed));
            (0..32).map(|_| g.sample_spec().runtime_s).collect::<Vec<_>>()
        };
        assert_eq!(sample(9), sample(9));
        assert_ne!(sample(9), sample(10));
    }
}
