//! The on-prem GPU pool baseline.
//!
//! IceCube's existing (non-cloud) GPU capacity across OSG sites: in 2020
//! OSG delivered ~8M GPU-hours (~910 GPU-equivalents year-round); during
//! the two-week exercise IceCube's on-prem share averaged ~1.1k busy
//! GPUs.  These workers join the same pool and run the same queue — the
//! Fig-2 baseline against which the cloud doubling is measured.

use crate::condor::startd::{SlotId, Startd};
use crate::condor::CondorPool;
use crate::net::NatProfile;
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// Configuration of the static on-prem pool.
#[derive(Debug, Clone)]
pub struct OnPremConfig {
    /// Number of on-prem GPU slots.
    pub slots: u32,
    /// Keepalive used by on-prem workers (no NAT issue on-prem).
    pub keepalive_s: u64,
    /// Fraction of slots that are effectively available (site downtimes,
    /// other VOs winning shares).
    pub availability: f64,
}

impl Default for OnPremConfig {
    fn default() -> Self {
        OnPremConfig { slots: 1150, keepalive_s: 300, availability: 0.97 }
    }
}

/// Register the on-prem workers with the pool.
/// Returns the number of slots actually brought up.
pub fn register_onprem(
    pool: &mut CondorPool,
    config: &OnPremConfig,
    rng: &mut Rng,
    now: SimTime,
) -> u32 {
    let mut up = 0;
    for i in 0..config.slots {
        if !rng.chance(config.availability) {
            continue;
        }
        let slot = SlotId::OnPrem(i);
        let startd = Startd::new(
            slot,
            "onprem",
            None,
            "osg/onprem",
            NatProfile::permissive("onprem"),
            config.keepalive_s,
            now,
        );
        pool.add_startd(startd, now);
        up += 1;
    }
    up
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registers_about_availability_fraction() {
        let mut pool = CondorPool::new();
        let mut rng = Rng::new(3);
        let up = register_onprem(&mut pool, &OnPremConfig::default(), &mut rng, 0);
        assert!(up > 1050 && up <= 1150, "up={up}");
        assert_eq!(pool.num_startds(), up as usize);
    }

    #[test]
    fn onprem_slots_are_tagged() {
        let mut pool = CondorPool::new();
        let mut rng = Rng::new(3);
        register_onprem(
            &mut pool,
            &OnPremConfig { slots: 10, availability: 1.0, ..Default::default() },
            &mut rng,
            0,
        );
        let d = pool.startd(SlotId::OnPrem(0)).unwrap();
        assert_eq!(d.pool_tag, "onprem");
        assert!(d.provider.is_none());
    }

    #[test]
    fn full_availability_registers_all() {
        let mut pool = CondorPool::new();
        let mut rng = Rng::new(4);
        let cfg = OnPremConfig { slots: 100, availability: 1.0, ..Default::default() };
        assert_eq!(register_onprem(&mut pool, &cfg, &mut rng, 0), 100);
    }
}
