//! IceCube GPU workload model: photon-propagation job parameters.
//!
//! The real workload is ray-tracing detector simulation (ppc/clsim):
//! long-lived, restartable, GPU-bound jobs.  We model job runtimes on a
//! T4 as lognormal (median ~1 h, clamped to [10 min, 4 h]) and derive the
//! job's fp32 FLOP content from the achieved-efficiency fraction of T4
//! peak — so wall-hour and EFLOP-hour accounting stay mutually
//! consistent.

use crate::osg::accounting::T4_FP32_TFLOPS;
use crate::util::rng::Rng;

/// Fraction of T4 fp32 peak the photon code sustains (ray tracing is
/// memory/branch heavy; ppc-class codes land around this range).
pub const ACHIEVED_EFFICIENCY: f64 = 0.35;

/// Job runtime distribution (T4-seconds).
#[derive(Debug, Clone)]
pub struct RuntimeModel {
    pub median_s: f64,
    pub sigma: f64,
    pub min_s: u64,
    pub max_s: u64,
}

impl Default for RuntimeModel {
    fn default() -> Self {
        RuntimeModel {
            median_s: 3600.0,
            sigma: 0.45,
            min_s: 600,
            max_s: 4 * 3600,
        }
    }
}

impl RuntimeModel {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let v = rng.lognormal(self.median_s, self.sigma);
        (v as u64).clamp(self.min_s, self.max_s)
    }
}

/// Parameters of one generated IceCube job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    /// Ground-truth execution time on a T4.
    pub runtime_s: u64,
    /// Total fp32 FLOPs performed.
    pub flops: f64,
    /// Photon bunches propagated (one bunch = one artifact execution).
    pub bunches: u32,
}

/// Derive a job spec from a sampled runtime.
///
/// `flops_per_bunch` comes from the AOT artifact metadata so the number
/// of bunches matches what the compiled kernel actually computes.
pub fn job_spec(runtime_s: u64, flops_per_bunch: f64) -> JobSpec {
    let flops = runtime_s as f64 * T4_FP32_TFLOPS * 1e12 * ACHIEVED_EFFICIENCY;
    let bunches = (flops / flops_per_bunch).ceil().max(1.0) as u32;
    JobSpec { runtime_s, flops, bunches }
}

/// fp32 EFLOP-hours contained in `flops` FLOPs executed over `runtime_s`.
pub fn eflop_hours_of(flops: f64) -> f64 {
    // FLOPs = FLOP; EFLOP-hours = FLOP / 1e18 / 3600 * 3600... the paper's
    // metric is capacity: rate (EFLOPS) x hours = FLOP / 1e18 / 3600
    flops / 1e18 / 3600.0
}

/// Checkpointable progress of an IceCube job: photon propagation is
/// restartable at bunch granularity, so a job that has run `progress_s`
/// seconds of ground-truth work with checkpoints every `every_s`
/// seconds can resume at the last completed checkpoint boundary.
///
/// Because progress resumes *at* a boundary, iterating this (interrupt,
/// salvage, resume, interrupt, ...) keeps the checkpointed position a
/// multiple of `every_s` — the monotonicity `condor::Schedd` relies on.
pub fn salvageable_progress(progress_s: u64, every_s: u64) -> u64 {
    if every_s == 0 {
        return 0;
    }
    (progress_s / every_s) * every_s
}

/// Fraction of the job's ground-truth runtime already safely
/// checkpointed (plot/report helper).
pub fn completed_fraction(completed_s: u64, runtime_s: u64) -> f64 {
    if runtime_s == 0 {
        return 0.0;
    }
    (completed_s.min(runtime_s)) as f64 / runtime_s as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtimes_respect_bounds() {
        let m = RuntimeModel::default();
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let r = m.sample(&mut rng);
            assert!(r >= m.min_s && r <= m.max_s);
        }
    }

    #[test]
    fn runtime_median_near_target() {
        let m = RuntimeModel::default();
        let mut rng = Rng::new(2);
        let mut xs: Vec<u64> = (0..20_001).map(|_| m.sample(&mut rng)).collect();
        xs.sort_unstable();
        let median = xs[xs.len() / 2] as f64;
        assert!((median / m.median_s - 1.0).abs() < 0.05, "median={median}");
    }

    #[test]
    fn flops_scale_with_runtime() {
        let a = job_spec(3600, 1e12);
        let b = job_spec(7200, 1e12);
        assert!((b.flops / a.flops - 2.0).abs() < 1e-9);
        assert!(b.bunches > a.bunches);
    }

    #[test]
    fn one_hour_job_flop_content() {
        // 1h on T4 at 35% of 8.1 TFLOPS = 1.02e16 FLOP
        let spec = job_spec(3600, 1e12);
        let expected = 3600.0 * 8.1e12 * 0.35;
        assert!((spec.flops - expected).abs() / expected < 1e-12);
    }

    #[test]
    fn bunches_at_least_one() {
        let spec = job_spec(600, 1e30);
        assert_eq!(spec.bunches, 1);
    }

    #[test]
    fn salvage_floors_to_checkpoint_boundary() {
        assert_eq!(salvageable_progress(0, 600), 0);
        assert_eq!(salvageable_progress(599, 600), 0);
        assert_eq!(salvageable_progress(600, 600), 600);
        assert_eq!(salvageable_progress(3599, 600), 3000);
        // degenerate interval: nothing is checkpointable
        assert_eq!(salvageable_progress(5000, 0), 0);
        // resuming at a boundary keeps positions on the grid
        let base = salvageable_progress(1700, 600);
        assert_eq!(salvageable_progress(base + 650, 600), 1800);
    }

    #[test]
    fn completed_fraction_bounds() {
        assert_eq!(completed_fraction(0, 3600), 0.0);
        assert_eq!(completed_fraction(1800, 3600), 0.5);
        assert_eq!(completed_fraction(7200, 3600), 1.0);
        assert_eq!(completed_fraction(10, 0), 0.0);
    }

    #[test]
    fn eflop_hours_roundtrip_with_paper() {
        // 16k GPU-days at 100% efficiency would be 3.11 EFLOP-hours;
        // job-content accounting must reproduce that at efficiency 1.0
        let gpu_hours = 16_000.0 * 24.0;
        let flops = gpu_hours * 3600.0 * T4_FP32_TFLOPS * 1e12;
        let eflop_h = eflop_hours_of(flops);
        assert!((eflop_h - 3.1104).abs() < 1e-3, "{eflop_h}");
    }
}
