//! IceCube workload substrate: photon-propagation job model, backlog
//! generator, and the on-prem baseline pool.

pub mod generator;
pub mod icecube;
pub mod onprem;

pub use generator::{GeneratorConfig, JobGenerator};
pub use icecube::{job_spec, JobSpec, RuntimeModel, ACHIEVED_EFFICIENCY};
pub use onprem::{register_onprem, OnPremConfig};
