//! The parallel sweep runner: N scenarios over M worker threads.
//!
//! Campaign replays are embarrassingly parallel — every replay owns its
//! clocks, RNG streams, fleet, pool and ledger (no global simulation
//! state) — so the runner is a plain work-stealing loop: an atomic
//! next-index counter, scoped `std::thread` workers, and a slot-per-
//! scenario result vector.  Summaries land at their scenario's index, so
//! the output order (and content) is independent of thread count and
//! scheduling — the property `rust/tests/sweep_determinism.rs` pins.
//! The runner is agnostic to where the scenario list came from:
//! hand-written `[scenario.<name>]` tables and `[grid]` cartesian
//! products (`super::grid`) arrive as the same `Vec<ScenarioConfig>`.

use crate::cloudbank::BudgetSnapshot;
use crate::config::CampaignConfig;
use crate::coordinator::{Campaign, CampaignResult, ScenarioConfig};
use crate::osg::UsageAccounting;
use crate::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One scenario replay reduced to a comparison-table row.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSummary {
    pub name: String,
    pub seed: u64,
    pub duration_days: f64,
    /// CloudBank roll-up at campaign end (budget + per-provider spend).
    pub snapshot: BudgetSnapshot,
    pub gpu_days: f64,
    pub eflop_hours: f64,
    /// Cost per fp32 EFLOP-hour (NaN when nothing was delivered).
    pub cost_per_eflop_hour: f64,
    pub peak_gpus: f64,
    pub mean_gpus: f64,
    pub completed: u64,
    pub interrupted: u64,
    pub goodput_fraction: f64,
    pub nat_drops: u64,
    pub preemptions: u64,
    /// Job starts that resumed from a checkpoint instead of zero.
    pub resumes: u64,
    /// Billed cloud instance-hours that ended as job goodput.
    pub goodput_hours: f64,
    /// Billed cloud instance-hours that did not: idle/boot/drain time,
    /// lost attempt tails, restore overheads, and work still in flight
    /// at campaign end (HEPCloud-style wasted-hours accounting).
    pub wasted_hours: f64,
    pub expansion_factor: f64,
    pub alerts: usize,
}

impl ScenarioSummary {
    pub fn cost_usd(&self) -> f64 {
        self.snapshot.spent_usd
    }
}

/// Reduce one finished replay to its summary row.
pub fn summarize(
    name: &str,
    cfg: &CampaignConfig,
    result: &CampaignResult,
) -> ScenarioSummary {
    let gpu_hours = result.meter.total_instance_hours();
    let eflop_hours = UsageAccounting::eflop_hours(gpu_hours);
    let cost = result.ledger.total_spent();
    let gpus = result
        .monitor
        .get("gpus.total")
        .map(|s| s.summary());
    let good = result.schedd_stats.goodput_s as f64;
    let bad = result.schedd_stats.badput_s as f64;
    // the wall-hour split of the cloud bill: what the billed
    // instance-hours actually bought (schedd goodput covers on-prem
    // slots too, so the cloud split comes from provider_work)
    let goodput_hours = result
        .provider_work
        .iter()
        .map(|w| w.goodput_s as f64)
        .sum::<f64>()
        / 3600.0;
    let wasted_hours = (gpu_hours - goodput_hours).max(0.0);
    ScenarioSummary {
        name: name.to_string(),
        seed: cfg.seed,
        duration_days: cfg.duration_s as f64 / 86_400.0,
        snapshot: result.ledger.snapshot(cfg.duration_s),
        gpu_days: gpu_hours / 24.0,
        eflop_hours,
        cost_per_eflop_hour: if eflop_hours > 0.0 {
            cost / eflop_hours
        } else {
            f64::NAN
        },
        peak_gpus: gpus.map(|s| s.max).unwrap_or(0.0),
        mean_gpus: gpus.map(|s| s.mean).unwrap_or(0.0),
        completed: result.schedd_stats.completed,
        interrupted: result.schedd_stats.interrupted,
        goodput_fraction: if good + bad > 0.0 {
            good / (good + bad)
        } else {
            1.0
        },
        nat_drops: result.pool_stats.nat_drops,
        preemptions: result.provider_ops.iter().map(|(_, p, _)| *p).sum(),
        resumes: result.schedd_stats.resumes,
        goodput_hours,
        wasted_hours,
        expansion_factor: result.usage.expansion_factor(),
        alerts: result.ledger.alerts().len(),
    }
}

/// Replay one *already-applied* config to its summary row.  This is
/// the fleet's unit of work: a coordinator leases `(name, cfg)` pairs
/// and a worker needs no scenario-merge logic — just this function.
pub fn run_unit(name: &str, cfg: &CampaignConfig) -> ScenarioSummary {
    let result = Campaign::new(cfg.clone()).run();
    summarize(name, cfg, &result)
}

/// Replay one scenario against `base` to its summary row.  This is the
/// single underlying unit of work shared by every driver: the one-shot
/// CLI sweep below, the persistent replay pool behind `icecloud serve`
/// (`crate::server::jobs`), and — via [`run_unit`] on the applied
/// config — the distributed fleet (`crate::server::fleet`).
pub fn run_scenario(
    base: &CampaignConfig,
    scenario: &ScenarioConfig,
) -> ScenarioSummary {
    let cfg = scenario.apply(base);
    run_unit(&scenario.name, &cfg)
}

// ---------------------------------------------------------------------------
// Wire codec for fleet result transport
// ---------------------------------------------------------------------------
//
// The fleet's correctness story is "any worker produces byte-identical
// results", so the row encoding must be *lossless*: `Json::Num` is an
// f64 whose writer emits NaN as `null` and whose parser would round
// large u64s — both would break the hash compare.  Every f64 (and the
// u64 seed, which may exceed 2^53) therefore travels as its exact
// 64-bit pattern in 16 lowercase hex chars; small counters stay plain
// numbers.  `summary_from_wire(summary_to_wire(row)) == row` holds for
// every row, including NaN fields like `cost_per_eflop_hour`.

fn bits_to_json(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn u64_to_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn bits_from_json(j: &Json, what: &str) -> Result<f64, String> {
    u64_from_json(j, what).map(f64::from_bits)
}

fn u64_from_json(j: &Json, what: &str) -> Result<u64, String> {
    let s = j
        .as_str()
        .ok_or_else(|| format!("{what} must be a 16-hex-char string"))?;
    if s.len() != 16 {
        return Err(format!("{what} must be a 16-hex-char string"));
    }
    u64::from_str_radix(s, 16)
        .map_err(|_| format!("{what} must be a 16-hex-char string"))
}

fn wire_field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("wire row missing '{key}'"))
}

fn wire_u64(j: &Json, key: &str) -> Result<u64, String> {
    wire_field(j, key)?
        .as_u64()
        .ok_or_else(|| format!("wire row '{key}' must be a non-negative integer"))
}

/// Encode a summary row for fleet transport (lossless, deterministic).
pub fn summary_to_wire(row: &ScenarioSummary) -> Json {
    let mut snap = Json::obj();
    snap.set("at", Json::from(row.snapshot.at));
    snap.set("budget_usd", bits_to_json(row.snapshot.budget_usd));
    snap.set("spent_usd", bits_to_json(row.snapshot.spent_usd));
    snap.set("aws_usd", bits_to_json(row.snapshot.aws_usd));
    snap.set("gcp_usd", bits_to_json(row.snapshot.gcp_usd));
    snap.set("azure_usd", bits_to_json(row.snapshot.azure_usd));

    let mut o = Json::obj();
    o.set("name", Json::from(row.name.as_str()));
    o.set("seed", u64_to_json(row.seed));
    o.set("duration_days", bits_to_json(row.duration_days));
    o.set("snapshot", snap);
    o.set("gpu_days", bits_to_json(row.gpu_days));
    o.set("eflop_hours", bits_to_json(row.eflop_hours));
    o.set("cost_per_eflop_hour", bits_to_json(row.cost_per_eflop_hour));
    o.set("peak_gpus", bits_to_json(row.peak_gpus));
    o.set("mean_gpus", bits_to_json(row.mean_gpus));
    o.set("completed", Json::from(row.completed));
    o.set("interrupted", Json::from(row.interrupted));
    o.set("goodput_fraction", bits_to_json(row.goodput_fraction));
    o.set("nat_drops", Json::from(row.nat_drops));
    o.set("preemptions", Json::from(row.preemptions));
    o.set("resumes", Json::from(row.resumes));
    o.set("goodput_hours", bits_to_json(row.goodput_hours));
    o.set("wasted_hours", bits_to_json(row.wasted_hours));
    o.set("expansion_factor", bits_to_json(row.expansion_factor));
    o.set("alerts", Json::from(row.alerts));
    o
}

/// Decode a fleet wire row.  Strict: every field required, every hex
/// pattern exact — a malformed row must be rejected, never guessed at.
pub fn summary_from_wire(j: &Json) -> Result<ScenarioSummary, String> {
    let snap = wire_field(j, "snapshot")?;
    Ok(ScenarioSummary {
        name: wire_field(j, "name")?
            .as_str()
            .ok_or("wire row 'name' must be a string")?
            .to_string(),
        seed: u64_from_json(wire_field(j, "seed")?, "seed")?,
        duration_days: bits_from_json(wire_field(j, "duration_days")?, "duration_days")?,
        snapshot: BudgetSnapshot {
            at: wire_u64(snap, "at")?,
            budget_usd: bits_from_json(wire_field(snap, "budget_usd")?, "budget_usd")?,
            spent_usd: bits_from_json(wire_field(snap, "spent_usd")?, "spent_usd")?,
            aws_usd: bits_from_json(wire_field(snap, "aws_usd")?, "aws_usd")?,
            gcp_usd: bits_from_json(wire_field(snap, "gcp_usd")?, "gcp_usd")?,
            azure_usd: bits_from_json(wire_field(snap, "azure_usd")?, "azure_usd")?,
        },
        gpu_days: bits_from_json(wire_field(j, "gpu_days")?, "gpu_days")?,
        eflop_hours: bits_from_json(wire_field(j, "eflop_hours")?, "eflop_hours")?,
        cost_per_eflop_hour: bits_from_json(
            wire_field(j, "cost_per_eflop_hour")?,
            "cost_per_eflop_hour",
        )?,
        peak_gpus: bits_from_json(wire_field(j, "peak_gpus")?, "peak_gpus")?,
        mean_gpus: bits_from_json(wire_field(j, "mean_gpus")?, "mean_gpus")?,
        completed: wire_u64(j, "completed")?,
        interrupted: wire_u64(j, "interrupted")?,
        goodput_fraction: bits_from_json(
            wire_field(j, "goodput_fraction")?,
            "goodput_fraction",
        )?,
        nat_drops: wire_u64(j, "nat_drops")?,
        preemptions: wire_u64(j, "preemptions")?,
        resumes: wire_u64(j, "resumes")?,
        goodput_hours: bits_from_json(wire_field(j, "goodput_hours")?, "goodput_hours")?,
        wasted_hours: bits_from_json(wire_field(j, "wasted_hours")?, "wasted_hours")?,
        expansion_factor: bits_from_json(
            wire_field(j, "expansion_factor")?,
            "expansion_factor",
        )?,
        alerts: wire_u64(j, "alerts")? as usize,
    })
}

/// Engine threads each of `workers` concurrent replays may use without
/// oversubscribing the machine: `workers × engine-threads ≤ cores`
/// (minimum 1).  Both parallel drivers — [`run_matrix`] here and the
/// server's `ReplayPool` — clamp the base config's
/// [`engine`](CampaignConfig::engine) knob through this before fanning
/// out, so a sweep of N scenarios with real compute enabled cannot
/// explode into N × cores photon threads.
pub fn engine_thread_budget(workers: usize) -> usize {
    (crate::runtime::available_threads() / workers.max(1)).max(1)
}

/// Replay every scenario of the matrix against `base` on up to
/// `threads` worker threads; returns one summary per scenario, in
/// matrix order, independent of thread count.  The base config's
/// engine threads are clamped to the nested-parallelism budget
/// (results are engine-thread-invariant, so this never changes rows).
pub fn run_matrix(
    base: &CampaignConfig,
    scenarios: &[ScenarioConfig],
    threads: usize,
) -> Vec<ScenarioSummary> {
    let workers = threads.max(1).min(scenarios.len().max(1));
    let mut base = base.clone();
    base.engine.clamp_threads(engine_thread_budget(workers));
    let base = &base;
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<ScenarioSummary>>> =
        (0..scenarios.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= scenarios.len() {
                    break;
                }
                *slots[i].lock().unwrap() =
                    Some(run_scenario(base, &scenarios[i]));
            });
        }
    });

    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("every scenario index was claimed and completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};

    fn small_base() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 6 * HOUR;
        c.ramp = vec![RampStep { target: 25, hold_s: 60 * DAY }];
        c.outage = None;
        c.onprem.slots = 15;
        c.generator.min_backlog = 80;
        c
    }

    #[test]
    fn runs_every_scenario_in_order() {
        let base = small_base();
        let scenarios = vec![
            ScenarioConfig::named("one"),
            {
                let mut s = ScenarioConfig::named("two");
                s.budget_usd = Some(10.0);
                s
            },
            {
                let mut s = ScenarioConfig::named("three");
                s.onprem_slots = Some(0);
                s
            },
        ];
        let rows = run_matrix(&base, &scenarios, 2);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "one");
        assert_eq!(rows[1].name, "two");
        assert_eq!(rows[2].name, "three");
        // every replay produced a populated summary
        assert!(rows[0].completed > 0);
        assert!(rows[0].peak_gpus > 0.0);
        assert!(rows[0].cost_usd() > 0.0);
        // the $10 budget drains the fleet early: strictly cheaper
        assert!(rows[1].cost_usd() < rows[0].cost_usd());
        // no on-prem slots => expansion factor has no baseline
        assert!(rows[2].expansion_factor.is_nan());
    }

    #[test]
    fn single_scenario_single_thread() {
        let base = small_base();
        let rows =
            run_matrix(&base, &[ScenarioConfig::named("solo")], 1);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].duration_days, 0.25);
        assert_eq!(rows[0].seed, base.seed);
    }

    #[test]
    fn empty_matrix_is_empty() {
        assert!(run_matrix(&small_base(), &[], 4).is_empty());
    }

    #[test]
    fn engine_budget_divides_cores_among_workers() {
        let cores = crate::runtime::available_threads();
        assert_eq!(engine_thread_budget(1), cores);
        assert_eq!(engine_thread_budget(cores), 1);
        // more workers than cores still leaves one engine thread each
        assert_eq!(engine_thread_budget(cores * 4), 1);
        assert_eq!(engine_thread_budget(0), cores);
        // the invariant the budget encodes: workers × engine ≤ cores
        for workers in 1..=cores * 2 {
            assert!(workers * engine_thread_budget(workers) <= cores.max(workers));
        }
    }

    #[test]
    fn engine_threads_do_not_change_rows() {
        let mut loud = small_base();
        loud.engine.threads = 64; // clamped inside run_matrix
        let quiet = small_base();
        let scenarios = [ScenarioConfig::named("x")];
        assert_eq!(
            run_matrix(&loud, &scenarios, 2),
            run_matrix(&quiet, &scenarios, 2)
        );
    }

    #[test]
    fn run_unit_matches_run_scenario() {
        let base = small_base();
        let mut s = ScenarioConfig::named("unit");
        s.budget_usd = Some(25.0);
        let via_scenario = run_scenario(&base, &s);
        let via_unit = run_unit("unit", &s.apply(&base));
        assert_eq!(via_scenario, via_unit);
    }

    #[test]
    fn wire_codec_round_trips_a_real_row() {
        let base = small_base();
        let row = run_scenario(&base, &ScenarioConfig::named("wire"));
        let wire = summary_to_wire(&row);
        // the wire bytes survive a JSON parse/re-render cycle exactly
        let parsed =
            crate::util::json::parse(&wire.to_string_compact()).unwrap();
        assert_eq!(
            parsed.to_string_compact(),
            wire.to_string_compact(),
            "wire encoding must be parse-stable"
        );
        let back = summary_from_wire(&parsed).unwrap();
        assert_eq!(back, row);
    }

    #[test]
    fn wire_codec_preserves_nan_and_extreme_values() {
        let base = small_base();
        let mut row = run_scenario(&base, &ScenarioConfig::named("nan"));
        row.cost_per_eflop_hour = f64::NAN;
        row.expansion_factor = f64::INFINITY;
        row.goodput_fraction = -0.0;
        row.seed = u64::MAX; // > 2^53: would be mangled by a plain Num
        let wire = summary_to_wire(&row);
        let parsed =
            crate::util::json::parse(&wire.to_string_compact()).unwrap();
        let back = summary_from_wire(&parsed).unwrap();
        assert!(back.cost_per_eflop_hour.is_nan());
        assert_eq!(
            back.cost_per_eflop_hour.to_bits(),
            row.cost_per_eflop_hour.to_bits()
        );
        assert_eq!(back.expansion_factor, f64::INFINITY);
        assert_eq!(back.goodput_fraction.to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.seed, u64::MAX);
    }

    #[test]
    fn wire_codec_rejects_malformed_rows() {
        let base = small_base();
        let row = run_scenario(&base, &ScenarioConfig::named("strict"));
        let good = summary_to_wire(&row);

        // missing field
        let mut missing = good.clone();
        if let crate::util::json::Json::Obj(m) = &mut missing {
            m.remove("gpu_days");
        }
        assert!(summary_from_wire(&missing).is_err());

        // truncated hex pattern
        let mut short = good.clone();
        short.set("gpu_days", crate::util::json::Json::from("abc"));
        assert!(summary_from_wire(&short).is_err());

        // non-hex pattern of the right length
        let mut junk = good.clone();
        junk.set("seed", crate::util::json::Json::from("zzzzzzzzzzzzzzzz"));
        assert!(summary_from_wire(&junk).is_err());

        // counter with a fraction
        let mut frac = good;
        frac.set("completed", crate::util::json::Json::from(1.5));
        assert!(summary_from_wire(&frac).is_err());
    }

    #[test]
    fn oversubscribed_threads_are_clamped() {
        let base = small_base();
        let rows = run_matrix(
            &base,
            &[ScenarioConfig::named("a"), ScenarioConfig::named("b")],
            64,
        );
        assert_eq!(rows.len(), 2);
        // identical scenarios produce identical summaries
        let mut b = rows[1].clone();
        b.name = "a".into();
        assert_eq!(rows[0], b);
    }
}
