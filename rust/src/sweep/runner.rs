//! The parallel sweep runner: N scenarios over M worker threads.
//!
//! Campaign replays are embarrassingly parallel — every replay owns its
//! clocks, RNG streams, fleet, pool and ledger (no global simulation
//! state) — so the runner is a plain work-stealing loop: an atomic
//! next-index counter, scoped `std::thread` workers, and a slot-per-
//! scenario result vector.  Summaries land at their scenario's index, so
//! the output order (and content) is independent of thread count and
//! scheduling — the property `rust/tests/sweep_determinism.rs` pins.

use crate::cloudbank::BudgetSnapshot;
use crate::config::CampaignConfig;
use crate::coordinator::{Campaign, CampaignResult, ScenarioConfig};
use crate::osg::UsageAccounting;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One scenario replay reduced to a comparison-table row.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSummary {
    pub name: String,
    pub seed: u64,
    pub duration_days: f64,
    /// CloudBank roll-up at campaign end (budget + per-provider spend).
    pub snapshot: BudgetSnapshot,
    pub gpu_days: f64,
    pub eflop_hours: f64,
    /// Cost per fp32 EFLOP-hour (NaN when nothing was delivered).
    pub cost_per_eflop_hour: f64,
    pub peak_gpus: f64,
    pub mean_gpus: f64,
    pub completed: u64,
    pub interrupted: u64,
    pub goodput_fraction: f64,
    pub nat_drops: u64,
    pub preemptions: u64,
    /// Job starts that resumed from a checkpoint instead of zero.
    pub resumes: u64,
    /// Billed cloud instance-hours that ended as job goodput.
    pub goodput_hours: f64,
    /// Billed cloud instance-hours that did not: idle/boot/drain time,
    /// lost attempt tails, restore overheads, and work still in flight
    /// at campaign end (HEPCloud-style wasted-hours accounting).
    pub wasted_hours: f64,
    pub expansion_factor: f64,
    pub alerts: usize,
}

impl ScenarioSummary {
    pub fn cost_usd(&self) -> f64 {
        self.snapshot.spent_usd
    }
}

/// Reduce one finished replay to its summary row.
pub fn summarize(
    name: &str,
    cfg: &CampaignConfig,
    result: &CampaignResult,
) -> ScenarioSummary {
    let gpu_hours = result.meter.total_instance_hours();
    let eflop_hours = UsageAccounting::eflop_hours(gpu_hours);
    let cost = result.ledger.total_spent();
    let gpus = result
        .monitor
        .get("gpus.total")
        .map(|s| s.summary());
    let good = result.schedd_stats.goodput_s as f64;
    let bad = result.schedd_stats.badput_s as f64;
    // the wall-hour split of the cloud bill: what the billed
    // instance-hours actually bought (schedd goodput covers on-prem
    // slots too, so the cloud split comes from provider_work)
    let goodput_hours = result
        .provider_work
        .iter()
        .map(|w| w.goodput_s as f64)
        .sum::<f64>()
        / 3600.0;
    let wasted_hours = (gpu_hours - goodput_hours).max(0.0);
    ScenarioSummary {
        name: name.to_string(),
        seed: cfg.seed,
        duration_days: cfg.duration_s as f64 / 86_400.0,
        snapshot: result.ledger.snapshot(cfg.duration_s),
        gpu_days: gpu_hours / 24.0,
        eflop_hours,
        cost_per_eflop_hour: if eflop_hours > 0.0 {
            cost / eflop_hours
        } else {
            f64::NAN
        },
        peak_gpus: gpus.map(|s| s.max).unwrap_or(0.0),
        mean_gpus: gpus.map(|s| s.mean).unwrap_or(0.0),
        completed: result.schedd_stats.completed,
        interrupted: result.schedd_stats.interrupted,
        goodput_fraction: if good + bad > 0.0 {
            good / (good + bad)
        } else {
            1.0
        },
        nat_drops: result.pool_stats.nat_drops,
        preemptions: result.provider_ops.iter().map(|(_, p, _)| *p).sum(),
        resumes: result.schedd_stats.resumes,
        goodput_hours,
        wasted_hours,
        expansion_factor: result.usage.expansion_factor(),
        alerts: result.ledger.alerts().len(),
    }
}

/// Replay one scenario against `base` to its summary row.  This is the
/// single underlying unit of work shared by every driver: the one-shot
/// CLI sweep below, and the persistent replay pool behind
/// `icecloud serve` (`crate::server::jobs`).
pub fn run_scenario(
    base: &CampaignConfig,
    scenario: &ScenarioConfig,
) -> ScenarioSummary {
    let cfg = scenario.apply(base);
    let result = Campaign::new(cfg.clone()).run();
    summarize(&scenario.name, &cfg, &result)
}

/// Engine threads each of `workers` concurrent replays may use without
/// oversubscribing the machine: `workers × engine-threads ≤ cores`
/// (minimum 1).  Both parallel drivers — [`run_matrix`] here and the
/// server's `ReplayPool` — clamp the base config's
/// [`engine`](CampaignConfig::engine) knob through this before fanning
/// out, so a sweep of N scenarios with real compute enabled cannot
/// explode into N × cores photon threads.
pub fn engine_thread_budget(workers: usize) -> usize {
    (crate::runtime::available_threads() / workers.max(1)).max(1)
}

/// Replay every scenario of the matrix against `base` on up to
/// `threads` worker threads; returns one summary per scenario, in
/// matrix order, independent of thread count.  The base config's
/// engine threads are clamped to the nested-parallelism budget
/// (results are engine-thread-invariant, so this never changes rows).
pub fn run_matrix(
    base: &CampaignConfig,
    scenarios: &[ScenarioConfig],
    threads: usize,
) -> Vec<ScenarioSummary> {
    let workers = threads.max(1).min(scenarios.len().max(1));
    let mut base = base.clone();
    base.engine.clamp_threads(engine_thread_budget(workers));
    let base = &base;
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<ScenarioSummary>>> =
        (0..scenarios.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= scenarios.len() {
                    break;
                }
                *slots[i].lock().unwrap() =
                    Some(run_scenario(base, &scenarios[i]));
            });
        }
    });

    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("every scenario index was claimed and completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RampStep;
    use crate::sim::{DAY, HOUR};

    fn small_base() -> CampaignConfig {
        let mut c = CampaignConfig::default();
        c.duration_s = 6 * HOUR;
        c.ramp = vec![RampStep { target: 25, hold_s: 60 * DAY }];
        c.outage = None;
        c.onprem.slots = 15;
        c.generator.min_backlog = 80;
        c
    }

    #[test]
    fn runs_every_scenario_in_order() {
        let base = small_base();
        let scenarios = vec![
            ScenarioConfig::named("one"),
            {
                let mut s = ScenarioConfig::named("two");
                s.budget_usd = Some(10.0);
                s
            },
            {
                let mut s = ScenarioConfig::named("three");
                s.onprem_slots = Some(0);
                s
            },
        ];
        let rows = run_matrix(&base, &scenarios, 2);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "one");
        assert_eq!(rows[1].name, "two");
        assert_eq!(rows[2].name, "three");
        // every replay produced a populated summary
        assert!(rows[0].completed > 0);
        assert!(rows[0].peak_gpus > 0.0);
        assert!(rows[0].cost_usd() > 0.0);
        // the $10 budget drains the fleet early: strictly cheaper
        assert!(rows[1].cost_usd() < rows[0].cost_usd());
        // no on-prem slots => expansion factor has no baseline
        assert!(rows[2].expansion_factor.is_nan());
    }

    #[test]
    fn single_scenario_single_thread() {
        let base = small_base();
        let rows =
            run_matrix(&base, &[ScenarioConfig::named("solo")], 1);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].duration_days, 0.25);
        assert_eq!(rows[0].seed, base.seed);
    }

    #[test]
    fn empty_matrix_is_empty() {
        assert!(run_matrix(&small_base(), &[], 4).is_empty());
    }

    #[test]
    fn engine_budget_divides_cores_among_workers() {
        let cores = crate::runtime::available_threads();
        assert_eq!(engine_thread_budget(1), cores);
        assert_eq!(engine_thread_budget(cores), 1);
        // more workers than cores still leaves one engine thread each
        assert_eq!(engine_thread_budget(cores * 4), 1);
        assert_eq!(engine_thread_budget(0), cores);
        // the invariant the budget encodes: workers × engine ≤ cores
        for workers in 1..=cores * 2 {
            assert!(workers * engine_thread_budget(workers) <= cores.max(workers));
        }
    }

    #[test]
    fn engine_threads_do_not_change_rows() {
        let mut loud = small_base();
        loud.engine.threads = 64; // clamped inside run_matrix
        let quiet = small_base();
        let scenarios = [ScenarioConfig::named("x")];
        assert_eq!(
            run_matrix(&loud, &scenarios, 2),
            run_matrix(&quiet, &scenarios, 2)
        );
    }

    #[test]
    fn oversubscribed_threads_are_clamped() {
        let base = small_base();
        let rows = run_matrix(
            &base,
            &[ScenarioConfig::named("a"), ScenarioConfig::named("b")],
            64,
        );
        assert_eq!(rows.len(), 2);
        // identical scenarios produce identical summaries
        let mut b = rows[1].clone();
        b.name = "a".into();
        assert_eq!(rows[0], b);
    }
}
