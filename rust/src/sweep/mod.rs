//! Scenario-sweep subsystem: many campaign replays, in parallel, one
//! comparison table.
//!
//! The paper reports a single operating point (one budget, one ramp, one
//! outage); the interesting operational science is in the *what-ifs* —
//! different budgets, busier spot markets, different NAT infrastructure,
//! alternative ramp plans (HEPCloud's AWS investigation and the
//! whole-GPU-accounting follow-ups sweep exactly these axes).  This
//! module runs a matrix of [`ScenarioConfig`] overrides over one base
//! campaign on `std::thread` workers and reduces every replay to a
//! [`ScenarioSummary`] row (cost, GPU-days, EFLOP-hours, preemptions,
//! NAT drops, goodput).
//!
//! Determinism is load-bearing: each replay owns its entire world —
//! `sim::EventQueue`/`sim::Ticker` clocks, `util::rng::Rng` streams,
//! fleet, pool, ledger — with no process-global simulation state, so a
//! matrix produces byte-identical summaries regardless of worker-thread
//! count or scheduling order.  `rust/tests/sweep_determinism.rs` pins
//! both properties.
//!
//! [`ScenarioConfig`]: crate::coordinator::ScenarioConfig

//! Scenarios come from three spec shapes sharing one parse path
//! ([`parse_spec_json`]): the built-in matrix, explicit
//! `[scenario.<name>]` tables, and `[grid]` cartesian products
//! ([`grid`]).

pub mod grid;
pub mod matrix;
pub mod runner;

pub use matrix::{
    builtin_matrix, parse_spec, parse_spec_json,
    parse_spec_json_with_limit, parse_spec_with_limit,
};
pub use runner::{
    engine_thread_budget, run_matrix, run_scenario, run_unit,
    summary_from_wire, summary_to_wire, summarize, ScenarioSummary,
};
