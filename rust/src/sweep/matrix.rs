//! Sweep matrices: the built-in scenario set and the TOML spec format.
//!
//! A matrix spec is a TOML document with one `[scenario.<name>]` table
//! per scenario (plus an optional `[base]` table of campaign overrides,
//! applied through `CampaignConfig::apply_toml`):
//!
//! ```toml
//! [base]
//! duration_days = 4.0
//!
//! [scenario.baseline]
//!
//! [scenario.half-budget]
//! budget_usd = 29000.0
//!
//! [scenario.churn-x4]
//! preempt_multiplier = 4.0
//!
//! [scenario.keepalive-300]
//! keepalive_s = 300
//!
//! [scenario.no-outage]
//! outage_disabled = true
//! ```
//!
//! Scenario keys: `seed`, `duration_days`, `budget_usd`,
//! `preempt_multiplier`, `keepalive_s`, `nat_disabled`,
//! `nat_idle_timeout_s`, `outage_disabled`, `outage_at_days`,
//! `outage_duration_hours`, `ramp_targets` + `ramp_hold_days`,
//! `onprem_slots`, `policy` (`"paper"` | `"uniform"` | `"adaptive"` |
//! `"risk-aware"`), `checkpoint_every_s` (+ optional
//! `checkpoint_resume_overhead_s`) or `checkpoint_disabled`,
//! `gpu_slots_per_instance`, `checkpoint_size_gb`,
//! `checkpoint_transfer_mbps`.  This list is derived from — and
//! pinned by a test against — the typed knob registry
//! (`crate::config::registry`), which owns the whitelist, the typed
//! parsing and the validation; run `icecloud knobs` for the live
//! table.  Scenarios from a spec run in name order (the parse is a
//! sorted map), so a matrix file always produces the same row order.
//!
//! A spec may also (or instead) carry a `[grid]` table declaring
//! per-axis value lists over the same keys; it expands to the cartesian
//! product with synthesized names before any explicit scenarios (see
//! `super::grid`).

use crate::config::{
    CampaignConfig, CheckpointPolicy, NatOverride, PolicyMode, RampStep,
    DEFAULT_RESUME_OVERHEAD_S,
};
use crate::coordinator::ScenarioConfig;
use crate::sim::DAY;
use crate::util::json::Json;
use crate::util::toml;

/// The default what-if matrix: ten scenarios spanning the axes the paper
/// (and its follow-up literature) cares about.
pub fn builtin_matrix() -> Vec<ScenarioConfig> {
    let mut out = Vec::new();

    // 1. the paper's operating point, unchanged
    out.push(ScenarioConfig::named("baseline"));

    // 2. the counterfactual everyone asks first: no day-11 CE outage
    let mut s = ScenarioConfig::named("no-outage");
    s.outage = Some(None);
    out.push(s);

    // 3-4. budget sweep: what does half / a quarter of $58k deliver?
    let mut s = ScenarioConfig::named("budget-half");
    s.budget_usd = Some(29_000.0);
    out.push(s);
    let mut s = ScenarioConfig::named("budget-quarter");
    s.budget_usd = Some(14_500.0);
    out.push(s);

    // 5-6. spot-market weather: busier churn on every provider
    let mut s = ScenarioConfig::named("churn-x4");
    s.preempt_multiplier = Some(4.0);
    out.push(s);
    let mut s = ScenarioConfig::named("churn-x10");
    s.preempt_multiplier = Some(10.0);
    out.push(s);

    // 7. re-live §IV: the OSG-default keepalive on Azure's default NAT
    let mut s = ScenarioConfig::named("keepalive-300");
    s.keepalive_s = Some(300);
    out.push(s);

    // 8. fixed infrastructure instead of fixed configuration
    let mut s = ScenarioConfig::named("no-nat");
    s.keepalive_s = Some(300);
    s.nat_override = Some(NatOverride::Disabled);
    out.push(s);

    // 9. skip the validation staircase, go straight to peak
    let mut s = ScenarioConfig::named("ramp-aggressive");
    s.ramp = Some(vec![RampStep { target: 2000, hold_s: 60 * DAY }]);
    out.push(s);

    // 10. let the policy engine pick providers from observed rates
    let mut s = ScenarioConfig::named("policy-adaptive");
    s.policy = Some(PolicyMode::Adaptive);
    out.push(s);

    // 11-14. the PR 5 fidelity axes: checkpointing on/off × risk-aware
    // provisioning on/off (the baseline is the off/off corner), plus
    // checkpointing under the busy-market weather of scenario 5 — the
    // checkpoint={none,interval} × preempt={1,4} plane the wasted-hours
    // acceptance test sweeps
    let paper_ckpt = CheckpointPolicy::Interval {
        every_s: 1800,
        resume_overhead_s: DEFAULT_RESUME_OVERHEAD_S,
    };
    let mut s = ScenarioConfig::named("checkpoint-30m");
    s.checkpoint = Some(paper_ckpt);
    out.push(s);
    let mut s = ScenarioConfig::named("policy-risk-aware");
    s.policy = Some(PolicyMode::RiskAware);
    out.push(s);
    let mut s = ScenarioConfig::named("checkpoint-risk-aware");
    s.checkpoint = Some(paper_ckpt);
    s.policy = Some(PolicyMode::RiskAware);
    out.push(s);
    let mut s = ScenarioConfig::named("churn-x4-checkpoint");
    s.preempt_multiplier = Some(4.0);
    s.checkpoint = Some(paper_ckpt);
    out.push(s);

    out
}

/// Parse a matrix spec: applies the optional `[base]` table to `base`
/// and returns the scenarios in name order.
pub fn parse_spec(
    text: &str,
    base: &mut CampaignConfig,
) -> Result<Vec<ScenarioConfig>, String> {
    parse_spec_with_limit(text, base, None)
}

/// [`parse_spec`] with a caller-side scenario budget threaded into
/// `[grid]` expansion (see [`parse_spec_json_with_limit`]).
pub fn parse_spec_with_limit(
    text: &str,
    base: &mut CampaignConfig,
    scenario_limit: Option<usize>,
) -> Result<Vec<ScenarioConfig>, String> {
    let doc = toml::parse(text).map_err(|e| e.to_string())?;
    parse_spec_json_with_limit(&doc, base, scenario_limit)
}

/// Parse an already-decoded spec document (the TOML and JSON wire
/// formats share one tree shape: an optional `base` table, an optional
/// `grid` table of axis value lists, and an optional `scenario` table
/// of named override sets — at least one of the latter two).
/// `icecloud serve` feeds JSON request bodies straight through this
/// path, so grid specs work over `POST /sweep` with no router changes.
///
/// Row order: grid-expanded scenarios first (cartesian product order,
/// see `super::grid`), then explicit `[scenario.<name>]` tables in name
/// order.  The order is part of the content-addressed cache key, so it
/// must stay deterministic.
pub fn parse_spec_json(
    doc: &Json,
    base: &mut CampaignConfig,
) -> Result<Vec<ScenarioConfig>, String> {
    parse_spec_json_with_limit(doc, base, None)
}

/// [`parse_spec_json`] with a caller-side scenario budget.  The server
/// passes its per-request scenario limit here so a `[grid]` in an
/// untrusted body is refused from the O(axes) product check — before
/// any cell is materialized — rather than expanded in full and only
/// then counted against the limit.  `None` (the CLI paths) leaves the
/// grid's own cap as the sole pre-materialization bound.
pub fn parse_spec_json_with_limit(
    doc: &Json,
    base: &mut CampaignConfig,
    scenario_limit: Option<usize>,
) -> Result<Vec<ScenarioConfig>, String> {
    if let Some(b) = doc.get("base") {
        base.apply_toml(b)?;
    }
    let mut out = match doc.get("grid") {
        Some(g) => super::grid::expand(g, scenario_limit)?,
        None => Vec::new(),
    };
    match doc.get("scenario") {
        None => {
            if out.is_empty() {
                return Err("matrix spec has no [scenario.<name>] \
                            tables or [grid] section"
                    .into());
            }
        }
        Some(t) => {
            let tables = t
                .as_obj()
                .ok_or("matrix spec's 'scenario' is not a table")?;
            if tables.is_empty() && out.is_empty() {
                return Err("matrix spec defines zero scenarios".into());
            }
            let synthesized: std::collections::BTreeSet<&str> =
                out.iter().map(|s| s.name.as_str()).collect();
            for (name, body) in tables {
                if synthesized.contains(name.as_str()) {
                    return Err(format!(
                        "[scenario.{name}] collides with a \
                         grid-synthesized scenario name"
                    ));
                }
                out.push(crate::config::registry::parse_scenario(
                    name, body,
                )?);
            }
        }
    }
    Ok(out)
}

/// Load a matrix spec from a file.
pub fn from_toml_file(
    path: &str,
    base: &mut CampaignConfig,
) -> Result<Vec<ScenarioConfig>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_spec(&text, base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OutageSpec;
    use crate::sim::HOUR;

    #[test]
    fn builtin_matrix_is_big_enough_and_unique() {
        let m = builtin_matrix();
        assert!(m.len() >= 8, "need >= 8 scenarios, have {}", m.len());
        let mut names: Vec<&str> = m.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), m.len(), "scenario names must be unique");
        // the baseline really is the base config
        let base = CampaignConfig::default();
        let applied = m[0].apply(&base);
        assert_eq!(applied.budget_usd, base.budget_usd);
        assert_eq!(applied.ramp, base.ramp);
    }

    #[test]
    fn spec_parses_scenarios_in_name_order() {
        let mut base = CampaignConfig::default();
        let spec = r#"
[base]
duration_days = 2.0

[scenario.c-third]
budget_usd = 1000.0

[scenario.a-first]
keepalive_s = 300
nat_disabled = true

[scenario.b-second]
preempt_multiplier = 4.0
outage_disabled = true
policy = "adaptive"
"#;
        let scenarios = parse_spec(spec, &mut base).unwrap();
        assert_eq!(base.duration_s, 2 * DAY);
        let names: Vec<&str> =
            scenarios.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["a-first", "b-second", "c-third"]);
        assert_eq!(scenarios[0].keepalive_s, Some(300));
        assert_eq!(scenarios[0].nat_override, Some(NatOverride::Disabled));
        assert_eq!(scenarios[1].preempt_multiplier, Some(4.0));
        assert_eq!(scenarios[1].outage, Some(None));
        assert_eq!(scenarios[1].policy, Some(PolicyMode::Adaptive));
        assert_eq!(scenarios[2].budget_usd, Some(1000.0));
    }

    #[test]
    fn spec_parses_ramp_and_outage() {
        let mut base = CampaignConfig::default();
        let spec = r#"
[scenario.custom]
ramp_targets = [100, 500]
ramp_hold_days = [1.0, 5.0]
outage_at_days = 3.0
outage_duration_hours = 6.0
nat_idle_timeout_s = 120
onprem_slots = 10
seed = 77
"#;
        let s = &parse_spec(spec, &mut base).unwrap()[0];
        assert_eq!(
            s.ramp,
            Some(vec![
                RampStep { target: 100, hold_s: DAY },
                RampStep { target: 500, hold_s: 5 * DAY },
            ])
        );
        assert_eq!(
            s.outage,
            Some(Some(OutageSpec { at_s: 3 * DAY, duration_s: 6 * HOUR }))
        );
        assert_eq!(s.nat_override, Some(NatOverride::IdleTimeout(120)));
        assert_eq!(s.onprem_slots, Some(10));
        assert_eq!(s.seed, Some(77));
    }

    #[test]
    fn empty_or_malformed_specs_rejected() {
        let mut base = CampaignConfig::default();
        assert!(parse_spec("x = 1", &mut base).is_err());
        assert!(parse_spec("[scenario.a]\npolicy = \"nope\"", &mut base)
            .is_err());
    }

    #[test]
    fn typo_keys_rejected_not_silently_ignored() {
        let mut base = CampaignConfig::default();
        let err = parse_spec(
            "[scenario.a]\npreempt_multipler = 10.0",
            &mut base,
        )
        .unwrap_err();
        assert!(err.contains("preempt_multipler"), "err={err}");
    }

    #[test]
    fn mistyped_values_rejected_not_silently_ignored() {
        let mut base = CampaignConfig::default();
        // a string where a number belongs must not replay the baseline
        // under the scenario's name
        for spec in [
            "[scenario.a]\nbudget_usd = \"29000\"",
            "[scenario.a]\nkeepalive_s = 300.5",
            "[scenario.a]\nnat_disabled = \"true\"",
            "[scenario.a]\nseed = -4",
            "[scenario.a]\npolicy = 7",
            "[scenario.a]\nramp_targets = 100",
        ] {
            assert!(
                parse_spec(spec, &mut base).is_err(),
                "spec {spec:?} must be rejected"
            );
        }
    }

    #[test]
    fn mistyped_or_excess_ramp_holds_rejected() {
        let mut base = CampaignConfig::default();
        let err = parse_spec(
            "[scenario.a]\nramp_targets = [100, 500]\n\
             ramp_hold_days = [1.0, \"2\"]",
            &mut base,
        )
        .unwrap_err();
        assert!(err.contains("ramp_hold_days[1]"), "err={err}");
        // more holds than targets is a pairing bug, not padding
        assert!(parse_spec(
            "[scenario.a]\nramp_targets = [100]\n\
             ramp_hold_days = [1.0, 2.0]",
            &mut base
        )
        .is_err());
        // fewer holds than targets still defaults the tail
        let s = &parse_spec(
            "[scenario.a]\nramp_targets = [100, 500]\n\
             ramp_hold_days = [1.0]",
            &mut base,
        )
        .unwrap()[0];
        let ramp = s.ramp.as_ref().unwrap();
        assert_eq!(ramp[0].hold_s, DAY);
        assert_eq!(ramp[1].hold_s, 2 * DAY);
    }

    #[test]
    fn corrupting_casts_rejected_not_saturated() {
        let mut base = CampaignConfig::default();
        // each of these used to pass `f64 as u64` / `u64 as u32` and
        // silently run a corrupted campaign under a citable name:
        // negative durations saturated to 0, oversized integers
        // truncated modulo 2^32
        for spec in [
            "[scenario.a]\nduration_days = -1.0",
            "[scenario.a]\noutage_at_days = -3.0",
            "[scenario.a]\noutage_at_days = 1.0\n\
             outage_duration_hours = -2.0",
            "[scenario.a]\nramp_targets = [100]\n\
             ramp_hold_days = [-1.0]",
            "[scenario.a]\nramp_targets = [4294967297]",
            "[scenario.a]\nonprem_slots = 4294967297",
            // out-of-range positive: 3e18 days of seconds > u64::MAX
            "[scenario.a]\nduration_days = 3.0e18",
        ] {
            assert!(
                parse_spec(spec, &mut base).is_err(),
                "spec {spec:?} must be rejected"
            );
        }
        // non-finite values can't be written in TOML; go through JSON
        for (key, v) in [
            ("duration_days", f64::NAN),
            ("duration_days", f64::INFINITY),
            ("outage_at_days", f64::NEG_INFINITY),
        ] {
            let mut body = std::collections::BTreeMap::new();
            body.insert(key.to_string(), Json::Num(v));
            let err =
                crate::config::registry::parse_scenario("a", &Json::Obj(body))
                    .unwrap_err();
            assert!(err.contains(key), "err={err}");
        }
    }

    #[test]
    fn dangling_outage_duration_rejected() {
        let mut base = CampaignConfig::default();
        // a lone duration used to validate and then silently vanish
        let err = parse_spec(
            "[scenario.a]\noutage_duration_hours = 2.0",
            &mut base,
        )
        .unwrap_err();
        assert!(err.contains("outage_at_days"), "err={err}");
    }

    #[test]
    fn conflicting_nat_keys_rejected() {
        let mut base = CampaignConfig::default();
        assert!(parse_spec(
            "[scenario.a]\nnat_disabled = true\nnat_idle_timeout_s = 120",
            &mut base
        )
        .is_err());
    }

    #[test]
    fn bad_ramp_entries_rejected() {
        let mut base = CampaignConfig::default();
        assert!(parse_spec(
            "[scenario.a]\nramp_targets = [100.5, 500]",
            &mut base
        )
        .is_err());
        assert!(
            parse_spec("[scenario.a]\nramp_targets = []", &mut base).is_err()
        );
    }

    #[test]
    fn json_documents_parse_like_toml() {
        let mut base_toml = CampaignConfig::default();
        let mut base_json = CampaignConfig::default();
        let from_toml = parse_spec(
            "[base]\nduration_days = 2.0\n\n[scenario.a]\nbudget_usd = 5.0",
            &mut base_toml,
        )
        .unwrap();
        let doc = crate::util::json::parse(
            r#"{"base": {"duration_days": 2.0},
                "scenario": {"a": {"budget_usd": 5.0}}}"#,
        )
        .unwrap();
        let from_json = parse_spec_json(&doc, &mut base_json).unwrap();
        assert_eq!(from_toml, from_json);
        assert_eq!(base_toml.duration_s, base_json.duration_s);
    }

    #[test]
    fn builtin_matrix_spans_checkpoint_and_risk_axes() {
        let m = builtin_matrix();
        let get = |name: &str| {
            m.iter().find(|s| s.name == name).unwrap_or_else(|| {
                panic!("builtin matrix missing scenario '{name}'")
            })
        };
        // the checkpoint × risk-aware 2×2 (baseline is off/off)
        assert!(get("baseline").checkpoint.is_none());
        assert!(matches!(
            get("checkpoint-30m").checkpoint,
            Some(CheckpointPolicy::Interval { every_s: 1800, .. })
        ));
        assert_eq!(
            get("policy-risk-aware").policy,
            Some(PolicyMode::RiskAware)
        );
        let both = get("checkpoint-risk-aware");
        assert!(both.checkpoint.is_some() && both.policy.is_some());
        // the checkpoint × preempt plane of the wasted-hours acceptance
        let hot = get("churn-x4-checkpoint");
        assert_eq!(hot.preempt_multiplier, Some(4.0));
        assert!(hot.checkpoint.is_some());
        assert_eq!(get("churn-x4").checkpoint, None);
    }

    #[test]
    fn spec_parses_checkpoint_keys() {
        let mut base = CampaignConfig::default();
        let spec = r#"
[scenario.ckpt]
checkpoint_every_s = 900
checkpoint_resume_overhead_s = 30

[scenario.ckpt-default-overhead]
checkpoint_every_s = 600

[scenario.ckpt-off]
checkpoint_disabled = true
"#;
        let scenarios = parse_spec(spec, &mut base).unwrap();
        assert_eq!(
            scenarios[0].checkpoint,
            Some(CheckpointPolicy::Interval {
                every_s: 900,
                resume_overhead_s: 30,
            })
        );
        assert_eq!(
            scenarios[1].checkpoint,
            Some(CheckpointPolicy::Interval {
                every_s: 600,
                resume_overhead_s: DEFAULT_RESUME_OVERHEAD_S,
            })
        );
        assert_eq!(scenarios[2].checkpoint, Some(CheckpointPolicy::None));

        // degenerate / conflicting / mistyped spellings are errors
        for bad in [
            "[scenario.a]\ncheckpoint_every_s = 0",
            "[scenario.a]\ncheckpoint_every_s = \"900\"",
            "[scenario.a]\ncheckpoint_resume_overhead_s = 30",
            "[scenario.a]\ncheckpoint_disabled = true\ncheckpoint_every_s = 900",
            "[scenario.a]\ncheckpoint_disabled = 1",
        ] {
            assert!(
                parse_spec(bad, &mut base).is_err(),
                "'{bad}' must be rejected"
            );
        }
    }

    #[test]
    fn spec_parses_registry_new_axes() {
        // the PR 10 registry-entry axes flow through the same spec
        // surface as every older knob — no matrix-side plumbing
        let mut base = CampaignConfig::default();
        let spec = r#"
[scenario.carved]
gpu_slots_per_instance = 4
checkpoint_every_s = 900
checkpoint_size_gb = 2.5
checkpoint_transfer_mbps = 500.0
"#;
        let s = &parse_spec(spec, &mut base).unwrap()[0];
        assert_eq!(s.gpu_slots_per_instance, Some(4));
        assert_eq!(s.checkpoint_size_gb, Some(2.5));
        assert_eq!(s.checkpoint_transfer_mbps, Some(500.0));
        // and their validation rejects the corrupting spellings
        for bad in [
            "[scenario.a]\ngpu_slots_per_instance = 0",
            "[scenario.a]\ngpu_slots_per_instance = 4294967297",
            "[scenario.a]\ncheckpoint_size_gb = -1.0",
            "[scenario.a]\ncheckpoint_transfer_mbps = 0.0",
        ] {
            assert!(
                parse_spec(bad, &mut base).is_err(),
                "'{bad}' must be rejected"
            );
        }
    }
}
