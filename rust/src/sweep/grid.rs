//! Cartesian-product grid expansion: the `[grid]` spec section.
//!
//! A `[grid]` table declares per-axis value lists over the same
//! whitelisted scenario keys that `[scenario.<name>]` tables accept
//! (the grid-eligible entries of `crate::config::registry::KNOBS`):
//!
//! ```toml
//! [grid]
//! preempt_multiplier = [1.0, 2.0, 4.0, 10.0]
//! budget_usd = [14500.0, 29000.0, 58000.0, 116000.0]
//! keepalive_s = [60, 120, 240, 300]
//! ```
//!
//! expands to the 64-cell cartesian product.  Every cell gets a
//! deterministic synthesized name, `axis=value/axis=value/...`, with
//! axes in sorted (BTreeMap) order and the *last* sorted axis varying
//! fastest — so a grid spec always produces the same scenario list in
//! the same order, which keeps the content-addressed result cache keys
//! stable across runs and thread counts.
//!
//! Name uniqueness falls out of construction: duplicate values within
//! an axis are rejected, so no two cells can render the same name.
//! Axis values must be scalars (the TOML subset has no nested arrays),
//! which rules out the array-valued registry entries
//! (`ramp_targets`/`ramp_hold_days`, `grid_axis: false`) as axes —
//! those stay in `[base]` or explicit `[scenario.<name>]` tables.
//!
//! Expansion is capped and the cap is checked from the axis lengths
//! *before* any scenario is materialized, so an oversized grid costs
//! O(axes) to reject — important because grid specs arrive over
//! `POST /sweep` from untrusted clients.  Three limits stack:
//!
//! * `[grid] max_scenarios` (default [`DEFAULT_MAX_SCENARIOS`]) — the
//!   spec's own knob, raisable for big local studies;
//! * [`HARD_MAX_SCENARIOS`] — a compile-time ceiling the spec cannot
//!   override, so `max_scenarios` in a hostile document can never buy
//!   an allocation large enough to abort the process;
//! * the caller's `scenario_limit` — the server threads its per-request
//!   scenario budget in here, so an untrusted grid is refused from the
//!   axis-length product alone, never expanded first and counted later.

use crate::coordinator::ScenarioConfig;
use crate::util::json::{require_u64, Json};
use std::collections::{BTreeMap, BTreeSet};

/// Default ceiling on how many scenarios one `[grid]` may expand to.
/// High enough for a serious parameter study (a 16×16×16 cube), low
/// enough that a typo'd axis can't wedge a server with millions of
/// replays.  Raise per-spec with `[grid] max_scenarios`, up to
/// [`HARD_MAX_SCENARIOS`].
pub const DEFAULT_MAX_SCENARIOS: u64 = 4096;

/// Absolute ceiling on `[grid] max_scenarios` itself.  The spec's knob
/// is client-supplied on the server path, so it cannot be the only
/// bound: without this, `max_scenarios = u64::MAX` plus a few long axes
/// would pass the product check and reach the output allocation with a
/// multi-TB request, and allocation failure aborts the process.  2^20
/// cells is far beyond any sweep the replay pool could service anyway.
pub const HARD_MAX_SCENARIOS: u64 = 1 << 20;

/// Expand a `[grid]` table to its cartesian product of scenarios.
///
/// Each cell is fed through `crate::config::registry::parse_scenario`,
/// so grid values get exactly the same strict validation (type checks,
/// range checks, conflicting-key checks) as hand-written scenarios.
///
/// `scenario_limit` is the caller's own scenario budget (the server
/// passes its per-request limit; the CLI passes `None`).  It bounds the
/// axis-length product *before* materialization alongside the spec's
/// cap, and — unlike `[grid] max_scenarios` — the spec cannot raise it.
pub fn expand(
    grid: &Json,
    scenario_limit: Option<usize>,
) -> Result<Vec<ScenarioConfig>, String> {
    let table = grid.as_obj().ok_or("[grid] is not a table")?;
    let mut cap = DEFAULT_MAX_SCENARIOS;
    // BTreeMap iteration order = sorted axis names: the name synthesis
    // and product order below inherit determinism from this
    let mut axes: Vec<(&str, &[Json])> = Vec::new();
    for (key, val) in table {
        if key == "max_scenarios" {
            cap = require_u64(val, "[grid] max_scenarios")?;
            if cap == 0 {
                return Err(
                    "[grid] max_scenarios must be positive".into()
                );
            }
            if cap > HARD_MAX_SCENARIOS {
                return Err(format!(
                    "[grid] max_scenarios = {cap} exceeds the hard \
                     ceiling of {HARD_MAX_SCENARIOS}"
                ));
            }
            continue;
        }
        match crate::config::registry::lookup(key) {
            Some(k) if !k.grid_axis => {
                return Err(format!(
                    "[grid] cannot sweep '{key}': array-valued axes \
                     are not supported; set it in [base] or an \
                     explicit [scenario.<name>] table"
                ));
            }
            Some(_) => {}
            None => {
                return Err(format!("[grid] has unknown axis '{key}'"));
            }
        }
        let values = val.as_arr().ok_or_else(|| {
            format!("[grid] axis '{key}' must be an array of values")
        })?;
        if values.is_empty() {
            return Err(format!("[grid] axis '{key}' has no values"));
        }
        let mut seen = BTreeSet::new();
        for v in values {
            if !matches!(v, Json::Str(_) | Json::Num(_) | Json::Bool(_))
            {
                return Err(format!(
                    "[grid] axis '{key}' values must be scalars"
                ));
            }
            // duplicate values would synthesize duplicate names (and
            // replay identical cells); rejecting them here is what
            // makes cell names unique by construction
            if !seen.insert(value_label(v)) {
                return Err(format!(
                    "[grid] axis '{key}' repeats value {}",
                    value_label(v)
                ));
            }
        }
        axes.push((key.as_str(), values));
    }
    if axes.is_empty() {
        return Err("[grid] declares no axes".into());
    }
    let cells = axes
        .iter()
        .fold(1u128, |n, (_, vs)| n.saturating_mul(vs.len() as u128));
    // the caller's budget binds regardless of what the (possibly
    // hostile) spec set max_scenarios to; both are checked against the
    // O(axes) product, before any cell exists
    if let Some(limit) = scenario_limit {
        if cells > limit as u128 {
            return Err(format!(
                "[grid] expands to {cells} scenarios, over this \
                 request's limit of {limit}"
            ));
        }
    }
    if cells > cap as u128 {
        return Err(format!(
            "[grid] expands to {cells} scenarios, over the cap of \
             {cap}; raise [grid] max_scenarios if that is intended"
        ));
    }

    // odometer over the sorted axes; the last axis varies fastest
    let mut idx = vec![0usize; axes.len()];
    let mut out = Vec::with_capacity(cells as usize);
    loop {
        let mut body = BTreeMap::new();
        let mut name = String::new();
        for (ai, (key, values)) in axes.iter().enumerate() {
            let v = &values[idx[ai]];
            if ai > 0 {
                name.push('/');
            }
            name.push_str(key);
            name.push('=');
            name.push_str(&value_label(v));
            body.insert((*key).to_string(), v.clone());
        }
        out.push(crate::config::registry::parse_scenario(
            &name,
            &Json::Obj(body),
        )?);
        let mut ai = axes.len();
        loop {
            if ai == 0 {
                return Ok(out);
            }
            ai -= 1;
            idx[ai] += 1;
            if idx[ai] < axes[ai].1.len() {
                break;
            }
            idx[ai] = 0;
        }
    }
}

/// Render one axis value for a synthesized scenario name.  Numbers go
/// through the JSON writer (`29000.0` → `29000`, `1.5` → `1.5`), so the
/// label is deterministic and round-trips with the emitted result rows.
fn value_label(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string_compact(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    fn grid_of(spec: &str) -> Json {
        let doc = toml::parse(spec).unwrap();
        doc.get("grid").cloned().unwrap()
    }

    #[test]
    fn product_counts_and_names_are_deterministic() {
        let g = grid_of(
            "[grid]\n\
             preempt_multiplier = [1.0, 2.0, 4.0, 10.0]\n\
             budget_usd = [14500.0, 29000.0, 58000.0, 116000.0]\n\
             keepalive_s = [60, 120, 240, 300]\n",
        );
        let a = expand(&g, None).unwrap();
        assert_eq!(a.len(), 64);
        let mut names: Vec<&str> =
            a.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 64, "names must be unique");
        // byte-identical re-expansion
        let b = expand(&g, None).unwrap();
        assert_eq!(a, b);
        // sorted-axis name order, last axis (preempt_multiplier)
        // fastest
        assert_eq!(
            a[0].name,
            "budget_usd=14500/keepalive_s=60/preempt_multiplier=1"
        );
        assert_eq!(
            a[1].name,
            "budget_usd=14500/keepalive_s=60/preempt_multiplier=2"
        );
        assert_eq!(
            a[4].name,
            "budget_usd=14500/keepalive_s=120/preempt_multiplier=1"
        );
        assert_eq!(
            a[63].name,
            "budget_usd=116000/keepalive_s=300/preempt_multiplier=10"
        );
        // values really flow into the configs
        assert_eq!(a[0].budget_usd, Some(14500.0));
        assert_eq!(a[0].keepalive_s, Some(60));
        assert_eq!(a[0].preempt_multiplier, Some(1.0));
        assert_eq!(a[63].preempt_multiplier, Some(10.0));
    }

    #[test]
    fn string_bool_and_fractional_labels() {
        let g = grid_of(
            "[grid]\n\
             policy = [\"paper\", \"adaptive\"]\n\
             outage_disabled = [true]\n\
             preempt_multiplier = [1.5]\n",
        );
        let s = expand(&g, None).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(
            s[0].name,
            "outage_disabled=true/policy=paper/preempt_multiplier=1.5"
        );
        assert_eq!(
            s[1].name,
            "outage_disabled=true/policy=adaptive/\
             preempt_multiplier=1.5"
        );
        assert_eq!(s[0].outage, Some(None));
    }

    #[test]
    fn default_cap_rejects_oversized_grids() {
        // 17 x 17 x 17 = 4913 > 4096, rejected before materializing
        let mut spec = String::from("[grid]\n");
        for key in ["seed", "keepalive_s", "checkpoint_every_s"] {
            let vals: Vec<String> =
                (1..=17).map(|i| i.to_string()).collect();
            spec.push_str(&format!(
                "{key} = [{}]\n",
                vals.join(", ")
            ));
        }
        let err = expand(&grid_of(&spec), None).unwrap_err();
        assert!(err.contains("4913"), "err={err}");
        assert!(err.contains("4096"), "err={err}");
    }

    #[test]
    fn explicit_cap_overrides_default() {
        let base = "[grid]\nmax_scenarios = 8\n";
        let over = format!(
            "{base}seed = [1, 2, 3]\nkeepalive_s = [60, 120, 240]\n"
        );
        let err = expand(&grid_of(&over), None).unwrap_err();
        assert!(err.contains("cap of 8"), "err={err}");
        let under = format!(
            "{base}seed = [1, 2]\nkeepalive_s = [60, 120, 240, 300]\n"
        );
        assert_eq!(expand(&grid_of(&under), None).unwrap().len(), 8);
        assert!(expand(
            &grid_of("[grid]\nmax_scenarios = 0\nseed = [1]"),
            None
        )
        .is_err());
    }

    #[test]
    fn caller_limit_binds_before_materialization() {
        // 2 x 4 = 8 cells: fine standalone, over a caller limit of 4
        let g = grid_of(
            "[grid]\nseed = [1, 2]\n\
             keepalive_s = [60, 120, 240, 300]\n",
        );
        assert_eq!(expand(&g, None).unwrap().len(), 8);
        assert_eq!(expand(&g, Some(8)).unwrap().len(), 8);
        let err = expand(&g, Some(4)).unwrap_err();
        assert!(err.contains("limit of 4"), "err={err}");

        // raising the spec's own cap does NOT lift the caller's limit
        let g = grid_of(
            "[grid]\nmax_scenarios = 1000000\nseed = [1, 2]\n\
             keepalive_s = [60, 120, 240, 300]\n",
        );
        let err = expand(&g, Some(4)).unwrap_err();
        assert!(err.contains("limit of 4"), "err={err}");
    }

    #[test]
    fn max_scenarios_cannot_exceed_hard_ceiling() {
        for cap in ["1048577", "18446744073709551615"] {
            let g = grid_of(&format!(
                "[grid]\nmax_scenarios = {cap}\nseed = [1]\n"
            ));
            let err = expand(&g, None).unwrap_err();
            assert!(err.contains("hard ceiling"), "err={err}");
        }
        // exactly at the ceiling is allowed
        let g = grid_of(&format!(
            "[grid]\nmax_scenarios = {HARD_MAX_SCENARIOS}\nseed = [1]\n"
        ));
        assert_eq!(expand(&g, None).unwrap().len(), 1);
    }

    #[test]
    fn malformed_grids_rejected() {
        for spec in [
            // unknown axis
            "[grid]\nbudgett_usd = [1.0]\n",
            // array-valued axes unsupported
            "[grid]\nramp_targets = [100]\n",
            "[grid]\nramp_hold_days = [1.0]\n",
            // non-array axis value
            "[grid]\nseed = 7\n",
            // empty axis
            "[grid]\nseed = []\n",
            // duplicate values in one axis
            "[grid]\nseed = [1, 1]\n",
            // no axes at all
            "[grid]\nmax_scenarios = 16\n",
            "[grid]\n",
            // invalid value flows through the shared strict parser
            "[grid]\nduration_days = [-1.0]\n",
            "[grid]\nonprem_slots = [4294967297]\n",
            "[grid]\npolicy = [\"bogus\"]\n",
        ] {
            assert!(
                expand(&grid_of(spec), None).is_err(),
                "grid {spec:?} must be rejected"
            );
        }
        assert!(expand(&Json::from("nope"), None).is_err());
    }

    #[test]
    fn duplicate_labels_across_types_rejected() {
        // 60 and 60.0 render to the same label and would collide
        let g = grid_of("[grid]\nkeepalive_s = [60, 60.0]\n");
        let err = expand(&g, None).unwrap_err();
        assert!(err.contains("repeats"), "err={err}");
    }

    #[test]
    fn new_registry_axes_expand_like_any_other() {
        // gpu_slots_per_instance and the checkpoint-transfer pair are
        // single registry entries; the grid expander needed no changes
        // to sweep them
        let g = grid_of(
            "[grid]\ngpu_slots_per_instance = [1, 2, 4]\n\
             checkpoint_size_gb = [0.5, 2.0]\n",
        );
        let cells = expand(&g, None).unwrap();
        assert_eq!(cells.len(), 6);
        assert_eq!(
            cells[0].name,
            "checkpoint_size_gb=0.5/gpu_slots_per_instance=1"
        );
        assert_eq!(cells[0].checkpoint_size_gb, Some(0.5));
        assert_eq!(cells[0].gpu_slots_per_instance, Some(1));
        // sorted axes, last varies fastest; 2.0 renders "2" (the
        // shared write_num formatting)
        assert_eq!(
            cells[5].name,
            "checkpoint_size_gb=2/gpu_slots_per_instance=4"
        );
        assert_eq!(cells[5].gpu_slots_per_instance, Some(4));
        let g = grid_of(
            "[grid]\ncheckpoint_transfer_mbps = [100.0, 1000.0]\n",
        );
        let cells = expand(&g, None).unwrap();
        assert_eq!(cells[0].checkpoint_transfer_mbps, Some(100.0));
        // cell values still pass the registry validators
        let g = grid_of("[grid]\ngpu_slots_per_instance = [0]\n");
        assert!(expand(&g, None).is_err());
        let g = grid_of("[grid]\ncheckpoint_transfer_mbps = [-1.0]\n");
        assert!(expand(&g, None).is_err());
    }
}
