//! Named time series store (the campaign's monitoring database).

use crate::sim::SimTime;
use std::collections::BTreeMap;

/// One series: (t, value) samples in time order.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub points: Vec<(SimTime, f64)>,
}

impl TimeSeries {
    pub fn push(&mut self, t: SimTime, v: f64) {
        debug_assert!(
            self.points.last().map(|(pt, _)| *pt <= t).unwrap_or(true),
            "samples must be time-ordered"
        );
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|(_, v)| *v)
    }

    /// Largest sample, `None` when the series is empty (an empty fold
    /// would otherwise surface −inf, which `/timeseries` must never
    /// serialize).
    pub fn max(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|(_, v)| *v)
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))))
    }

    /// Smallest sample; `None` when the series is empty.
    pub fn min(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|(_, v)| *v)
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
    }

    /// Time-weighted mean over the sampled span; `None` when the series
    /// is empty.  A single sample (or a zero-width span of repeated
    /// timestamps) has no area to weight, so the plain average of the
    /// values stands in — never NaN.
    pub fn mean(&self) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let span = (self.points.last().unwrap().0 - self.points[0].0) as f64;
        if self.points.len() < 2 || span == 0.0 {
            let sum: f64 = self.points.iter().map(|(_, v)| *v).sum();
            return Some(sum / self.points.len() as f64);
        }
        let mut area = 0.0;
        for w in self.points.windows(2) {
            let (t0, v0) = w[0];
            let (t1, _) = w[1];
            area += v0 * (t1 - t0) as f64;
        }
        Some(area / span)
    }

    /// Collapse the series into one summary row (scenario-sweep tables).
    /// An empty series collapses to all-zero stats with `samples == 0`
    /// as the discriminator — finite everywhere, so a summary always
    /// survives JSON serialization.
    pub fn summary(&self) -> SeriesSummary {
        SeriesSummary {
            min: self.min().unwrap_or(0.0),
            max: self.max().unwrap_or(0.0),
            mean: self.mean().unwrap_or(0.0),
            last: self.last().unwrap_or(0.0),
            samples: self.len(),
        }
    }

    /// Downsample to at most `n` points (stride sampling, keeps ends).
    /// `n == 0` yields nothing, `n == 1` keeps the latest point; asking
    /// for fewer points than exist must never return *more*.
    pub fn downsample(&self, n: usize) -> Vec<(SimTime, f64)> {
        if n == 0 {
            return Vec::new();
        }
        if self.points.len() <= n {
            return self.points.clone();
        }
        if n == 1 {
            return vec![*self.points.last().unwrap()];
        }
        let stride = (self.points.len() - 1) as f64 / (n - 1) as f64;
        (0..n)
            .map(|i| self.points[(i as f64 * stride).round() as usize])
            .collect()
    }
}

/// One series collapsed to a summary row — what a scenario sweep keeps
/// from each replay's monitoring instead of the full sample stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesSummary {
    pub min: f64,
    pub max: f64,
    /// Time-weighted mean over the sampled span.
    pub mean: f64,
    pub last: f64,
    pub samples: usize,
}

/// The store: insertion-ordered named series.
#[derive(Debug, Default)]
pub struct Monitor {
    series: BTreeMap<String, TimeSeries>,
}

impl Monitor {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn sample(&mut self, name: &str, t: SimTime, v: f64) {
        self.series.entry(name.to_string()).or_default().push(t, v);
    }

    pub fn get(&self, name: &str) -> Option<&TimeSeries> {
        self.series.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.series.keys().map(|s| s.as_str())
    }

    /// Dump selected series as CSV: `t_s,<name1>,<name2>,...`.
    /// Series are aligned by sample index (the campaign samples everything
    /// on the same tick, so indexes line up).
    pub fn to_csv(&self, names: &[&str]) -> String {
        let mut out = String::new();
        out.push_str("t_s");
        for n in names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        let rows = names
            .iter()
            .filter_map(|n| self.get(n))
            .map(|s| s.len())
            .max()
            .unwrap_or(0);
        for i in 0..rows {
            let t = names
                .iter()
                .filter_map(|n| self.get(n))
                .filter_map(|s| s.points.get(i))
                .map(|(t, _)| *t)
                .next()
                .unwrap_or(0);
            out.push_str(&t.to_string());
            for n in names {
                out.push(',');
                if let Some((_, v)) =
                    self.get(n).and_then(|s| s.points.get(i))
                {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_stats() {
        let mut s = TimeSeries::default();
        s.push(0, 10.0);
        s.push(100, 20.0);
        s.push(200, 0.0);
        assert_eq!(s.len(), 3);
        assert_eq!(s.last(), Some(0.0));
        assert_eq!(s.max(), Some(20.0));
        assert_eq!(s.min(), Some(0.0));
        // time-weighted mean: (10*100 + 20*100) / 200 = 15
        assert!((s.mean().unwrap() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn summary_collapses_series() {
        let mut s = TimeSeries::default();
        s.push(0, 10.0);
        s.push(100, 20.0);
        s.push(200, 0.0);
        let sum = s.summary();
        assert_eq!(sum.min, 0.0);
        assert_eq!(sum.max, 20.0);
        assert!((sum.mean - 15.0).abs() < 1e-12);
        assert_eq!(sum.last, 0.0);
        assert_eq!(sum.samples, 3);
    }

    #[test]
    fn summary_of_empty_series() {
        let s = TimeSeries::default();
        let sum = s.summary();
        assert_eq!(sum.samples, 0);
        // all-zero, never NaN/−inf: the summary must survive JSON
        assert_eq!(sum.last, 0.0);
        assert_eq!(sum.min, 0.0);
        assert_eq!(sum.max, 0.0);
        assert_eq!(sum.mean, 0.0);
    }

    #[test]
    fn empty_series_stats_are_none_not_nan() {
        let s = TimeSeries::default();
        assert_eq!(s.max(), None);
        assert_eq!(s.min(), None);
        assert_eq!(s.mean(), None);
        assert_eq!(s.last(), None);
        assert!(s.downsample(10).is_empty());
    }

    #[test]
    fn single_point_mean_is_the_value() {
        let mut s = TimeSeries::default();
        s.push(42, 7.5);
        assert_eq!(s.mean(), Some(7.5));
        assert_eq!(s.min(), Some(7.5));
        assert_eq!(s.max(), Some(7.5));
    }

    #[test]
    fn zero_span_mean_is_plain_average() {
        // repeated timestamps: no area to weight, but still a number
        let mut s = TimeSeries::default();
        s.push(10, 2.0);
        s.push(10, 4.0);
        assert_eq!(s.mean(), Some(3.0));
    }

    #[test]
    fn downsample_degenerate_budgets() {
        let mut s = TimeSeries::default();
        for i in 0..100u64 {
            s.push(i, i as f64);
        }
        // n=0 returns nothing (the old code returned all 100 points)
        assert!(s.downsample(0).is_empty());
        // n=1 keeps the latest point, not the whole series
        assert_eq!(s.downsample(1), vec![(99, 99.0)]);
    }

    #[test]
    fn downsample_keeps_ends() {
        let mut s = TimeSeries::default();
        for i in 0..1000u64 {
            s.push(i, i as f64);
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0], (0, 0.0));
        assert_eq!(d[9], (999, 999.0));
    }

    #[test]
    fn downsample_small_series_unchanged() {
        let mut s = TimeSeries::default();
        s.push(0, 1.0);
        assert_eq!(s.downsample(10).len(), 1);
    }

    #[test]
    fn monitor_named_series() {
        let mut m = Monitor::new();
        m.sample("gpus.total", 0, 50.0);
        m.sample("gpus.total", 60, 55.0);
        m.sample("jobs.idle", 0, 100.0);
        assert_eq!(m.get("gpus.total").unwrap().len(), 2);
        assert_eq!(m.names().count(), 2);
    }

    #[test]
    fn csv_alignment() {
        let mut m = Monitor::new();
        for t in [0u64, 60, 120] {
            m.sample("a", t, t as f64);
            m.sample("b", t, 2.0 * t as f64);
        }
        let csv = m.to_csv(&["a", "b"]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t_s,a,b");
        assert_eq!(lines[1], "0,0,0");
        assert_eq!(lines[3], "120,120,240");
    }
}
