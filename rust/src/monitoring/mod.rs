//! Monitoring: named time series + terminal figure rendering.

pub mod plot;
pub mod timeseries;

pub use plot::{daily_bars, line_chart};
pub use timeseries::{Monitor, SeriesSummary, TimeSeries};
