//! ASCII plotting: terminal renditions of the paper's figures.

use super::timeseries::TimeSeries;
use crate::sim::{SimTime, DAY};

/// Render one or more series as an ASCII line chart.
///
/// Each series gets a glyph; the y-axis is shared. This is what
/// `icecloud reproduce --fig1` prints next to the CSV it writes.
pub fn line_chart(
    title: &str,
    series: &[(&str, &TimeSeries)],
    width: usize,
    height: usize,
) -> String {
    let glyphs = ['#', '*', '+', 'o', 'x', '~'];
    let mut y_max = f64::NEG_INFINITY;
    let mut t_min = SimTime::MAX;
    let mut t_max = 0;
    for (_, s) in series {
        if s.is_empty() {
            continue;
        }
        y_max = y_max.max(s.max().unwrap_or(f64::NEG_INFINITY));
        t_min = t_min.min(s.points[0].0);
        t_max = t_max.max(s.points[s.len() - 1].0);
    }
    if !y_max.is_finite() || t_max <= t_min {
        return format!("{title}\n(no data)\n");
    }
    let y_max = y_max.max(1.0) * 1.05;
    let mut grid = vec![vec![' '; width]; height];

    for (si, (_, s)) in series.iter().enumerate() {
        let glyph = glyphs[si % glyphs.len()];
        for &(t, v) in &s.points {
            let x = ((t - t_min) as f64 / (t_max - t_min) as f64
                * (width - 1) as f64)
                .round() as usize;
            let y = (v / y_max * (height - 1) as f64).round() as usize;
            let row = height - 1 - y.min(height - 1);
            grid[row][x.min(width - 1)] = glyph;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let yval = y_max * (height - 1 - i) as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>8.0} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    // x axis in days
    let days = (t_max - t_min) as f64 / DAY as f64;
    out.push_str(&format!(
        "{:>10}day 0{:>width$.1}\n",
        "",
        days,
        width = width - 4
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", glyphs[i % glyphs.len()], name))
        .collect();
    out.push_str(&format!("  legend: {}\n", legend.join("   ")));
    out
}

/// Render per-day bars (Fig 2 style): two stacked values per day.
pub fn daily_bars(
    title: &str,
    days: &[(u32, f64, f64)], // (day, bottom=onprem, top=cloud)
    width: usize,
) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let max_total = days
        .iter()
        .map(|(_, a, b)| a + b)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1.0);
    for (day, onprem, cloud) in days {
        let total = onprem + cloud;
        let bar_len = (total / max_total * width as f64).round() as usize;
        let onprem_len =
            (onprem / max_total * width as f64).round() as usize;
        let cloud_len = bar_len.saturating_sub(onprem_len);
        out.push_str(&format!(
            "d{day:02} |{}{}| {:>9.0} GPUh ({:.0} onprem + {:.0} cloud)\n",
            "=".repeat(onprem_len),
            "#".repeat(cloud_len),
            total,
            onprem,
            cloud,
        ));
    }
    out.push_str("  legend: = onprem   # cloud\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_and_scales() {
        let mut s = TimeSeries::default();
        for i in 0..100u64 {
            s.push(i * 3600, (i % 50) as f64 * 40.0);
        }
        let chart = line_chart("GPUs", &[("gpus", &s)], 60, 10);
        assert!(chart.contains("GPUs"));
        assert!(chart.contains('#'));
        assert!(chart.contains("legend"));
        assert_eq!(chart.lines().count(), 14);
    }

    #[test]
    fn chart_handles_empty() {
        let s = TimeSeries::default();
        let chart = line_chart("empty", &[("x", &s)], 40, 8);
        assert!(chart.contains("no data"));
    }

    #[test]
    fn bars_show_both_components() {
        let days = vec![(0u32, 24_000.0, 0.0), (1, 24_000.0, 26_000.0)];
        let out = daily_bars("Fig2", &days, 40);
        assert!(out.contains("d00"));
        assert!(out.contains("d01"));
        assert!(out.contains('='));
        assert!(out.contains('#'));
        assert!(out.contains("50000 GPUh"));
    }
}
