//! The typed knob registry: one declarative table for the whole
//! scenario surface.
//!
//! Every sweepable campaign knob is one [`Knob`] entry here, stating
//! its scenario-spec name, its campaign-TOML path, its value kind
//! (validated through the shared `util::json::require_*` +
//! [`spec_seconds`]/[`spec_u32`] helpers), how it applies into a
//! [`ScenarioConfig`] and a [`CampaignConfig`], and whether it is
//! `[grid]`-axis eligible.  The scenario parser
//! ([`parse_scenario`]), the campaign TOML parser
//! ([`apply_campaign_toml`]), the grid axis whitelist
//! (`sweep::grid`), the `icecloud knobs` CLI and the doc tables are
//! all derived from this one table, so a new axis is a single entry
//! plus its simulator hook — never a six-site cross-layer diff.
//!
//! **Byte stability.**  The registry changes how knob parsing is
//! *organized*, not what it produces: `CampaignConfig::canonical_json`
//! bytes (and therefore the server's content-addressed cache keys) are
//! pinned unchanged by `tests/golden_canonical.rs`.  Knobs whose
//! default matches the pre-registry behaviour are omitted from the
//! canonical form when still at that default (see
//! `CampaignConfig::canonical_json`), so registering a knob never
//! invalidates existing cache keys.
//!
//! **Error contexts.**  [`Scope`] is the one formatter for every
//! parse-error context: `[scenario.<name>] 'key'` on the scenario
//! path, `'toml.path'` on the campaign path, `[table]` /
//! `[scenario.<name>]` for table-level conflicts.  The shape is pinned
//! by tests below — the historical drift between `[scenario.<name>]
//! key` and `'key'` spellings cannot come back.

use super::{
    spec_seconds, spec_u32, CampaignConfig, CheckpointPolicy, NatOverride,
    OutageSpec, PolicyMode, ProviderWeights, RampStep,
};
use crate::coordinator::ScenarioConfig;
use crate::runtime::SimdMode;
use crate::sim::{DAY, HOUR};
use crate::util::json::{require_bool, require_f64, require_u64, Json};

/// Value kind of a registered knob; drives fetching + validation and
/// the type column of `icecloud knobs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    U64,
    /// u64 in the spec, range-checked into a `u32` field ([`spec_u32`]).
    U32,
    F64,
    Bool,
    Str,
    /// f64 count of days, converted to sim-seconds ([`spec_seconds`]).
    Days,
    /// f64 count of hours, converted to sim-seconds ([`spec_seconds`]).
    Hours,
    /// Array of u32 (ramp targets); group-parsed, never a grid axis.
    U32Array,
    /// Array of f64 (ramp holds); group-parsed, never a grid axis.
    F64Array,
}

impl KnobKind {
    pub fn label(self) -> &'static str {
        match self {
            KnobKind::U64 => "u64",
            KnobKind::U32 => "u32",
            KnobKind::F64 => "f64",
            KnobKind::Bool => "bool",
            KnobKind::Str => "string",
            KnobKind::Days => "days (f64)",
            KnobKind::Hours => "hours (f64)",
            KnobKind::U32Array => "u32 array",
            KnobKind::F64Array => "f64 array",
        }
    }
}

/// A fetched, type-checked knob value (scalar kinds only; the array
/// kinds are resolved by their group parser).
#[derive(Debug, Clone, PartialEq)]
pub enum KnobValue {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl KnobValue {
    fn u64(&self) -> u64 {
        match self {
            KnobValue::U64(v) => *v,
            _ => unreachable!("kind/value mismatch"),
        }
    }

    fn f64(&self) -> f64 {
        match self {
            KnobValue::F64(v) => *v,
            _ => unreachable!("kind/value mismatch"),
        }
    }
}

type ScenarioSetter =
    fn(&mut ScenarioConfig, &KnobValue, &str) -> Result<(), String>;
type CampaignSetter =
    fn(&mut CampaignConfig, &KnobValue, &str) -> Result<(), String>;

/// How a knob applies.  Scalars carry a setter per target; grouped
/// knobs (NAT pair, outage trio, ramp pair, checkpoint trio, policy)
/// are resolved together by their group parser because their meaning
/// is relational (conflicts, pairings, defaults).
enum Apply {
    Scalar { scenario: ScenarioSetter, campaign: CampaignSetter },
    Group(Group),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Group {
    Nat,
    Outage,
    Ramp,
    Policy,
    Checkpoint,
}

/// One registered scenario knob.
pub struct Knob {
    /// Flat scenario-spec key (`[scenario.<name>]` tables, `[grid]`
    /// axes, the JSON wire format).
    pub name: &'static str,
    /// Nested campaign-TOML path the same knob takes in a `--config`
    /// file or a `[base]` table.
    pub toml_path: &'static [&'static str],
    pub kind: KnobKind,
    /// Whether a `[grid]` section may sweep this knob.  Array-valued
    /// knobs are excluded: the TOML subset has no nested arrays.
    pub grid_axis: bool,
    /// Human-readable default for the `icecloud knobs` table.
    pub default_label: &'static str,
    /// One-line description for the `icecloud knobs` table.
    pub doc: &'static str,
    /// A valid TOML literal for this knob, used by the round-trip
    /// property suite (`tests/prop_registry.rs`) to drive every knob
    /// through both parse paths.
    pub sample: &'static str,
    apply: Apply,
}

macro_rules! scalar {
    ($s:ident, $c:ident) => {
        Apply::Scalar { scenario: $s, campaign: $c }
    };
}

/// The registry.  Order is the scalar-application order and the row
/// order of every rendering; grouped knobs keep their relational
/// parse order inside their group resolvers.
pub static KNOBS: [Knob; 20] = [
    Knob {
        name: "seed",
        toml_path: &["seed"],
        kind: KnobKind::U64,
        grid_axis: true,
        default_label: "20210921",
        doc: "PRNG root seed; every replay stream derives from it",
        sample: "7",
        apply: scalar!(set_seed_s, set_seed_c),
    },
    Knob {
        name: "duration_days",
        toml_path: &["duration_days"],
        kind: KnobKind::Days,
        grid_axis: true,
        default_label: "14",
        doc: "campaign length in days (fractional allowed)",
        sample: "2.5",
        apply: scalar!(set_duration_s, set_duration_c),
    },
    Knob {
        name: "budget_usd",
        toml_path: &["budget", "total_usd"],
        kind: KnobKind::F64,
        grid_axis: true,
        default_label: "58000",
        doc: "total CloudBank budget in USD",
        sample: "29000.0",
        apply: scalar!(set_budget_s, set_budget_c),
    },
    Knob {
        name: "preempt_multiplier",
        toml_path: &["preempt_multiplier"],
        kind: KnobKind::F64,
        grid_axis: true,
        default_label: "1",
        doc: "spot-reclaim rate multiplier on every provider",
        sample: "4.0",
        apply: scalar!(set_preempt_s, set_preempt_c),
    },
    Knob {
        name: "keepalive_s",
        toml_path: &["keepalive_s"],
        kind: KnobKind::U64,
        grid_axis: true,
        default_label: "60",
        doc: "worker keepalive period in seconds (NAT survival)",
        sample: "300",
        apply: scalar!(set_keepalive_s, set_keepalive_c),
    },
    Knob {
        name: "nat_disabled",
        toml_path: &["nat", "disabled"],
        kind: KnobKind::Bool,
        grid_axis: true,
        default_label: "false",
        doc: "disable NAT idle timeouts everywhere (infrastructure fix)",
        sample: "true",
        apply: Apply::Group(Group::Nat),
    },
    Knob {
        name: "nat_idle_timeout_s",
        toml_path: &["nat", "idle_timeout_s"],
        kind: KnobKind::U64,
        grid_axis: true,
        default_label: "provider default",
        doc: "force one NAT idle timeout (seconds) on every cloud region",
        sample: "120",
        apply: Apply::Group(Group::Nat),
    },
    Knob {
        name: "outage_disabled",
        toml_path: &["outage", "disabled"],
        kind: KnobKind::Bool,
        grid_axis: true,
        default_label: "false",
        doc: "remove the day-11 compute-element outage",
        sample: "true",
        apply: Apply::Group(Group::Outage),
    },
    Knob {
        name: "outage_at_days",
        toml_path: &["outage", "at_days"],
        kind: KnobKind::Days,
        grid_axis: true,
        default_label: "11.25",
        doc: "outage start, days from campaign start",
        sample: "1.5",
        apply: Apply::Group(Group::Outage),
    },
    Knob {
        name: "outage_duration_hours",
        toml_path: &["outage", "duration_hours"],
        kind: KnobKind::Hours,
        grid_axis: true,
        default_label: "2",
        doc: "outage length in hours (needs outage_at_days)",
        sample: "6.0",
        apply: Apply::Group(Group::Outage),
    },
    Knob {
        name: "ramp_targets",
        toml_path: &["ramp", "targets"],
        kind: KnobKind::U32Array,
        grid_axis: false,
        default_label: "paper staircase",
        doc: "cloud GPU ramp plateau targets (array; not a grid axis)",
        sample: "[100, 200]",
        apply: Apply::Group(Group::Ramp),
    },
    Knob {
        name: "ramp_hold_days",
        toml_path: &["ramp", "hold_days"],
        kind: KnobKind::F64Array,
        grid_axis: false,
        default_label: "2 per step",
        doc: "days to hold each ramp plateau (pairs with ramp_targets)",
        sample: "[1.0, 0.5]",
        apply: Apply::Group(Group::Ramp),
    },
    Knob {
        name: "onprem_slots",
        toml_path: &["onprem", "slots"],
        kind: KnobKind::U32,
        grid_axis: true,
        default_label: "1150",
        doc: "on-prem GPU slots federated under the cloud fleet",
        sample: "10",
        apply: scalar!(set_onprem_s, set_onprem_c),
    },
    Knob {
        name: "policy",
        toml_path: &["policy", "mode"],
        kind: KnobKind::Str,
        grid_axis: true,
        default_label: "paper (70/15/15)",
        doc: "provider split: paper|azure-favored|uniform|adaptive|risk-aware",
        sample: "\"risk-aware\"",
        apply: Apply::Group(Group::Policy),
    },
    Knob {
        name: "checkpoint_every_s",
        toml_path: &["checkpoint", "every_s"],
        kind: KnobKind::U64,
        grid_axis: true,
        default_label: "off",
        doc: "checkpoint interval in seconds (unset = restart from scratch)",
        sample: "900",
        apply: Apply::Group(Group::Checkpoint),
    },
    Knob {
        name: "checkpoint_resume_overhead_s",
        toml_path: &["checkpoint", "resume_overhead_s"],
        kind: KnobKind::U64,
        grid_axis: true,
        default_label: "120",
        doc: "seconds to restore state on resume (needs checkpoint_every_s)",
        sample: "30",
        apply: Apply::Group(Group::Checkpoint),
    },
    Knob {
        name: "checkpoint_disabled",
        toml_path: &["checkpoint", "disabled"],
        kind: KnobKind::Bool,
        grid_axis: true,
        default_label: "false",
        doc: "force the no-checkpoint paper baseline",
        sample: "true",
        apply: Apply::Group(Group::Checkpoint),
    },
    Knob {
        name: "gpu_slots_per_instance",
        toml_path: &["gpu_slots_per_instance"],
        kind: KnobKind::U32,
        grid_axis: true,
        default_label: "1",
        doc: "GPU slots carved from each instance (fractional-GPU accounting)",
        sample: "4",
        apply: scalar!(set_gpu_slots_s, set_gpu_slots_c),
    },
    Knob {
        name: "checkpoint_size_gb",
        toml_path: &["checkpoint", "size_gb"],
        kind: KnobKind::F64,
        grid_axis: true,
        default_label: "0",
        doc: "checkpoint image size in GB; adds restore transfer time",
        sample: "2.5",
        apply: scalar!(set_ckpt_size_s, set_ckpt_size_c),
    },
    Knob {
        name: "checkpoint_transfer_mbps",
        toml_path: &["checkpoint", "transfer_mbps"],
        kind: KnobKind::F64,
        grid_axis: true,
        default_label: "1000",
        doc: "network bandwidth for checkpoint restores, megabit/s",
        sample: "500.0",
        apply: scalar!(set_ckpt_mbps_s, set_ckpt_mbps_c),
    },
];

/// Find a knob by scenario-spec name.
pub fn lookup(name: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.name == name)
}

fn knob(name: &str) -> &'static Knob {
    lookup(name).expect("registered knob")
}

// ---------------------------------------------------------------------
// scalar setters
// ---------------------------------------------------------------------

fn set_seed_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    s.seed = Some(v.u64());
    Ok(())
}

fn set_seed_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    c.seed = v.u64();
    Ok(())
}

fn set_duration_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    s.duration_s = Some(spec_seconds(v.f64(), DAY, ctx)?);
    Ok(())
}

fn set_duration_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    c.duration_s = spec_seconds(v.f64(), DAY, ctx)?;
    Ok(())
}

fn set_budget_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    s.budget_usd = Some(v.f64());
    Ok(())
}

fn set_budget_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    c.budget_usd = v.f64();
    Ok(())
}

fn set_preempt_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    s.preempt_multiplier = Some(v.f64());
    Ok(())
}

fn set_preempt_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    c.preempt_multiplier = v.f64();
    Ok(())
}

fn set_keepalive_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    s.keepalive_s = Some(v.u64());
    Ok(())
}

fn set_keepalive_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    _ctx: &str,
) -> Result<(), String> {
    c.keepalive_s = v.u64();
    Ok(())
}

fn set_onprem_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    s.onprem_slots = Some(spec_u32(v.u64(), ctx)?);
    Ok(())
}

fn set_onprem_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    c.onprem.slots = spec_u32(v.u64(), ctx)?;
    Ok(())
}

/// `gpu_slots_per_instance = 0` would divide busy-hours by zero-ish
/// magic; a carve-up always has at least one slot.
fn check_gpu_slots(v: u64, ctx: &str) -> Result<u32, String> {
    if v == 0 {
        return Err(format!("{ctx} must be >= 1"));
    }
    spec_u32(v, ctx)
}

fn set_gpu_slots_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    s.gpu_slots_per_instance = Some(check_gpu_slots(v.u64(), ctx)?);
    Ok(())
}

fn set_gpu_slots_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    c.gpu_slots_per_instance = check_gpu_slots(v.u64(), ctx)?;
    Ok(())
}

fn check_ckpt_size(v: f64, ctx: &str) -> Result<f64, String> {
    if !v.is_finite() || v < 0.0 {
        return Err(format!(
            "{ctx} must be a finite non-negative number (got {v})"
        ));
    }
    Ok(v)
}

fn set_ckpt_size_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    s.checkpoint_size_gb = Some(check_ckpt_size(v.f64(), ctx)?);
    Ok(())
}

fn set_ckpt_size_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    c.checkpoint_size_gb = check_ckpt_size(v.f64(), ctx)?;
    Ok(())
}

fn check_ckpt_mbps(v: f64, ctx: &str) -> Result<f64, String> {
    if !v.is_finite() || v <= 0.0 {
        return Err(format!(
            "{ctx} must be a finite positive number (got {v})"
        ));
    }
    Ok(v)
}

fn set_ckpt_mbps_s(
    s: &mut ScenarioConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    s.checkpoint_transfer_mbps = Some(check_ckpt_mbps(v.f64(), ctx)?);
    Ok(())
}

fn set_ckpt_mbps_c(
    c: &mut CampaignConfig,
    v: &KnobValue,
    ctx: &str,
) -> Result<(), String> {
    c.checkpoint_transfer_mbps = check_ckpt_mbps(v.f64(), ctx)?;
    Ok(())
}

// ---------------------------------------------------------------------
// the shared error-context formatter
// ---------------------------------------------------------------------

/// Which spelling of the knob surface is being parsed; the single
/// source of every parse-error context string.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Scope<'a> {
    /// A `[scenario.<name>]` table (flat keys).
    Scenario(&'a str),
    /// A campaign TOML document (nested paths).
    Campaign,
}

impl Scope<'_> {
    fn get<'j>(&self, doc: &'j Json, k: &Knob) -> Option<&'j Json> {
        match self {
            Scope::Scenario(_) => doc.get(k.name),
            Scope::Campaign => doc.get_path(k.toml_path),
        }
    }

    /// Context for one key: `[scenario.<name>] 'key'` / `'toml.path'`.
    pub(crate) fn key_ctx(&self, k: &Knob) -> String {
        match self {
            Scope::Scenario(name) => {
                format!("[scenario.{name}] '{}'", k.name)
            }
            Scope::Campaign => format!("'{}'", k.toml_path.join(".")),
        }
    }

    /// Context for one array element of a key.
    fn key_ctx_idx(&self, k: &Knob, i: usize) -> String {
        match self {
            Scope::Scenario(name) => {
                format!("[scenario.{name}] '{}[{i}]'", k.name)
            }
            Scope::Campaign => {
                format!("'{}[{i}]'", k.toml_path.join("."))
            }
        }
    }

    /// A key mentioned inside another key's message (no table prefix).
    fn key_name(&self, k: &Knob) -> String {
        match self {
            Scope::Scenario(_) => format!("'{}'", k.name),
            Scope::Campaign => format!("'{}'", k.toml_path.join(".")),
        }
    }

    /// Context for a table-level (multi-key) conflict.
    fn table_ctx(&self, table: &str) -> String {
        match self {
            Scope::Scenario(name) => format!("[scenario.{name}]"),
            Scope::Campaign => format!("[{table}]"),
        }
    }
}

// ---------------------------------------------------------------------
// typed fetching
// ---------------------------------------------------------------------

/// Fetch + type-check one scalar knob value.  Present-but-mistyped is
/// an error, never a silent no-op — the strict-value contract both
/// parse paths share.
fn fetch(kind: KnobKind, v: &Json, ctx: &str) -> Result<KnobValue, String> {
    match kind {
        KnobKind::U64 | KnobKind::U32 => {
            Ok(KnobValue::U64(require_u64(v, ctx)?))
        }
        KnobKind::F64 | KnobKind::Days | KnobKind::Hours => {
            Ok(KnobValue::F64(require_f64(v, ctx)?))
        }
        KnobKind::Bool => Ok(KnobValue::Bool(require_bool(v, ctx)?)),
        KnobKind::Str => Ok(KnobValue::Str(
            v.as_str()
                .ok_or_else(|| format!("{ctx} must be a string"))?
                .to_string(),
        )),
        KnobKind::U32Array | KnobKind::F64Array => {
            Err(format!("{ctx} is array-valued; group-parsed"))
        }
    }
}

fn get_u64(
    doc: &Json,
    scope: &Scope,
    name: &str,
) -> Result<Option<u64>, String> {
    let k = knob(name);
    scope
        .get(doc, k)
        .map(|v| require_u64(v, &scope.key_ctx(k)))
        .transpose()
}

fn get_f64(
    doc: &Json,
    scope: &Scope,
    name: &str,
) -> Result<Option<f64>, String> {
    let k = knob(name);
    scope
        .get(doc, k)
        .map(|v| require_f64(v, &scope.key_ctx(k)))
        .transpose()
}

fn get_bool(
    doc: &Json,
    scope: &Scope,
    name: &str,
) -> Result<Option<bool>, String> {
    let k = knob(name);
    scope
        .get(doc, k)
        .map(|v| require_bool(v, &scope.key_ctx(k)))
        .transpose()
}

// ---------------------------------------------------------------------
// group resolvers (shared by both parse paths)
// ---------------------------------------------------------------------

/// NAT pair: `disabled` xor `idle_timeout_s`.
fn resolve_nat(
    doc: &Json,
    scope: &Scope,
) -> Result<Option<NatOverride>, String> {
    let disabled = get_bool(doc, scope, "nat_disabled")? == Some(true);
    let timeout = get_u64(doc, scope, "nat_idle_timeout_s")?;
    match (disabled, timeout) {
        (true, Some(_)) => Err(format!(
            "{} sets both {} and {}; pick one",
            scope.table_ctx("nat"),
            scope.key_name(knob("nat_disabled")),
            scope.key_name(knob("nat_idle_timeout_s")),
        )),
        (true, None) => Ok(Some(NatOverride::Disabled)),
        (false, Some(t)) => Ok(Some(NatOverride::IdleTimeout(t))),
        (false, None) => Ok(None),
    }
}

/// Outage trio: returns `(disabled, rescheduled_spec)`.  Precedence is
/// the *caller's* concern — the scenario path applies `disabled` first
/// so an explicit reschedule overrides it, while the campaign path
/// applies the reschedule first so `disabled` wins (both orders are
/// load-bearing, pre-registry behaviour).
fn resolve_outage(
    doc: &Json,
    scope: &Scope,
) -> Result<(bool, Option<OutageSpec>), String> {
    let disabled = get_bool(doc, scope, "outage_disabled")? == Some(true);
    let at = get_f64(doc, scope, "outage_at_days")?;
    let dur = get_f64(doc, scope, "outage_duration_hours")?;
    let spec = match (at, dur) {
        (Some(at), dur) => Some(OutageSpec {
            at_s: spec_seconds(
                at,
                DAY,
                &scope.key_ctx(knob("outage_at_days")),
            )?,
            duration_s: spec_seconds(
                dur.unwrap_or(2.0),
                HOUR,
                &scope.key_ctx(knob("outage_duration_hours")),
            )?,
        }),
        // a dangling duration would be validated and then silently
        // dropped — same contract as checkpoint_resume_overhead_s
        // without checkpoint_every_s
        (None, Some(_)) => {
            return Err(format!(
                "{} needs {}",
                scope.key_ctx(knob("outage_duration_hours")),
                scope.key_name(knob("outage_at_days")),
            ))
        }
        (None, None) => None,
    };
    Ok((disabled, spec))
}

/// Ramp pair: `targets` (required when present) + optional `hold_days`
/// with a 2-day tail default.  A lone `hold_days` without `targets` is
/// ignored on both paths (pre-registry behaviour).
fn resolve_ramp(
    doc: &Json,
    scope: &Scope,
) -> Result<Option<Vec<RampStep>>, String> {
    let tk = knob("ramp_targets");
    let hk = knob("ramp_hold_days");
    let Some(targets) = scope.get(doc, tk) else {
        return Ok(None);
    };
    let arr = targets.as_arr().ok_or_else(|| {
        format!("{} must be an array", scope.key_ctx(tk))
    })?;
    let holds = match scope.get(doc, hk) {
        None => Vec::new(),
        Some(h) => {
            let h = h.as_arr().ok_or_else(|| {
                format!("{} must be an array", scope.key_ctx(hk))
            })?;
            let mut out = Vec::with_capacity(h.len());
            for (i, v) in h.iter().enumerate() {
                out.push(v.as_f64().ok_or_else(|| {
                    format!(
                        "{} must be a number",
                        scope.key_ctx_idx(hk, i)
                    )
                })?);
            }
            out
        }
    };
    if holds.len() > arr.len() {
        return Err(format!(
            "{} has {} entries for {} targets",
            scope.key_ctx(hk),
            holds.len(),
            arr.len()
        ));
    }
    // strict: a dropped entry would shift the target/hold pairing (or
    // leave an empty ramp) without any diagnostic
    let mut ramp = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let target = v.as_u64().ok_or_else(|| {
            format!(
                "{} must be a non-negative integer",
                scope.key_ctx_idx(tk, i)
            )
        })?;
        ramp.push(RampStep {
            target: spec_u32(target, &scope.key_ctx_idx(tk, i))?,
            hold_s: spec_seconds(
                holds.get(i).copied().unwrap_or(2.0),
                DAY,
                &scope.key_ctx_idx(hk, i),
            )?,
        });
    }
    if ramp.is_empty() {
        return Err(format!("{} must not be empty", scope.key_ctx(tk)));
    }
    Ok(Some(ramp))
}

/// Checkpoint trio, shared decision table
/// ([`CheckpointPolicy::from_knobs`]).
fn resolve_checkpoint(
    doc: &Json,
    scope: &Scope,
) -> Result<Option<CheckpointPolicy>, String> {
    let disabled = get_bool(doc, scope, "checkpoint_disabled")? == Some(true);
    let every = get_u64(doc, scope, "checkpoint_every_s")?;
    let overhead = get_u64(doc, scope, "checkpoint_resume_overhead_s")?;
    CheckpointPolicy::from_knobs(
        disabled,
        every,
        overhead,
        &scope.table_ctx("checkpoint"),
    )
}

/// Resolve a scenario `policy` name.  The campaign `[policy]` table
/// speaks a different dialect (mode + explicit weights) and keeps its
/// bespoke parser in [`apply_campaign_toml`].
fn resolve_policy_name(
    doc: &Json,
    scope: &Scope,
) -> Result<Option<PolicyMode>, String> {
    let k = knob("policy");
    match scope.get(doc, k) {
        None => Ok(None),
        Some(v) => {
            let name = v.as_str().ok_or_else(|| {
                format!("{} must be a string", scope.key_ctx(k))
            })?;
            policy_from_str(name).map(Some)
        }
    }
}

/// Named provider-split policies for scenario specs.
pub fn policy_from_str(s: &str) -> Result<PolicyMode, String> {
    match s {
        "paper" | "azure-favored" => Ok(PolicyMode::Fixed(ProviderWeights {
            aws: 0.15,
            gcp: 0.15,
            azure: 0.70,
        })),
        "uniform" => Ok(PolicyMode::Fixed(ProviderWeights {
            aws: 1.0 / 3.0,
            gcp: 1.0 / 3.0,
            azure: 1.0 / 3.0,
        })),
        "adaptive" => Ok(PolicyMode::Adaptive),
        "risk-aware" => Ok(PolicyMode::RiskAware),
        other => Err(format!("unknown policy '{other}'")),
    }
}

// ---------------------------------------------------------------------
// the two parse paths
// ---------------------------------------------------------------------

/// Parse one `[scenario.<name>]` table (or JSON object) into a
/// [`ScenarioConfig`].  The key whitelist, every scalar parse and
/// every group resolution derive from [`KNOBS`]; anything not
/// registered is a typo, and a typo'd override would otherwise run as
/// a silent copy of the baseline — fatal for a tool whose rows are
/// meant to be citable.
pub fn parse_scenario(
    name: &str,
    body: &Json,
) -> Result<ScenarioConfig, String> {
    let table = body
        .as_obj()
        .ok_or_else(|| format!("[scenario.{name}] is not a table"))?;
    for key in table.keys() {
        if lookup(key).is_none() {
            return Err(format!(
                "[scenario.{name}] has unknown key '{key}'"
            ));
        }
    }
    let scope = Scope::Scenario(name);
    let mut s = ScenarioConfig::named(name);
    for k in &KNOBS {
        if let Apply::Scalar { scenario: set, .. } = &k.apply {
            if let Some(v) = scope.get(body, k) {
                let ctx = scope.key_ctx(k);
                let val = fetch(k.kind, v, &ctx)?;
                set(&mut s, &val, &ctx)?;
            }
        }
    }
    if let Some(nat) = resolve_nat(body, &scope)? {
        s.nat_override = Some(nat);
    }
    // scenario precedence: disabled first, an explicit reschedule wins
    let (outage_off, outage_spec) = resolve_outage(body, &scope)?;
    if outage_off {
        s.outage = Some(None);
    }
    if let Some(spec) = outage_spec {
        s.outage = Some(Some(spec));
    }
    if let Some(ramp) = resolve_ramp(body, &scope)? {
        s.ramp = Some(ramp);
    }
    if let Some(policy) = resolve_policy_name(body, &scope)? {
        s.policy = Some(policy);
    }
    s.checkpoint = resolve_checkpoint(body, &scope)?;
    Ok(s)
}

/// Apply a campaign TOML document onto a [`CampaignConfig`]: registry
/// scalars + group resolvers for the registered knobs, then the
/// campaign-only tables (`[engine]`, budget shaping, the `[policy]`
/// mode/weights dialect).  Strict on values: a present-but-mistyped
/// key is an error, never a silent no-op (the server feeds untrusted
/// `[base]` tables through here).
pub(crate) fn apply_campaign_toml(
    c: &mut CampaignConfig,
    doc: &Json,
) -> Result<(), String> {
    let scope = Scope::Campaign;
    for k in &KNOBS {
        if let Apply::Scalar { campaign: set, .. } = &k.apply {
            if let Some(v) = scope.get(doc, k) {
                let ctx = scope.key_ctx(k);
                let val = fetch(k.kind, v, &ctx)?;
                set(c, &val, &ctx)?;
            }
        }
    }
    // [engine]: campaign-only wall-time knobs, deliberately outside
    // the registry (they never split the cache key and are not part
    // of the scenario surface)
    if let Some(v) = want_u64(doc, &["engine", "threads"])? {
        c.engine.threads = u32::try_from(v)
            .map_err(|_| format!("'engine.threads' {v} is out of range"))?;
    }
    if let Some(v) = want_u64(doc, &["engine", "bunch"])? {
        if v == 0 {
            return Err("'engine.bunch' must be >= 1".into());
        }
        c.engine.bunch = u32::try_from(v)
            .map_err(|_| format!("'engine.bunch' {v} is out of range"))?;
    }
    if let Some(v) = want_str(doc, &["engine", "simd"])? {
        c.engine.simd = SimdMode::parse(v).ok_or_else(|| {
            format!("'engine.simd' must be \"off\" or \"lanes\", got {v:?}")
        })?;
    }
    if let Some(policy) = resolve_checkpoint(doc, &scope)? {
        c.checkpoint = policy;
    }
    if let Some(nat) = resolve_nat(doc, &scope)? {
        c.nat_override = nat;
    }
    // campaign-only budget shaping
    if let Some(v) = want_f64(doc, &["budget", "overhead_fraction"])? {
        c.overhead_fraction = v;
    }
    if let Some(arr) = doc.get_path(&["budget", "alerts"]).map(|v| {
        v.as_arr()
            .ok_or_else(|| "'budget.alerts' must be an array".to_string())
    }) {
        let arr = arr?;
        let mut alerts = Vec::with_capacity(arr.len());
        for (i, v) in arr.iter().enumerate() {
            alerts.push(v.as_f64().ok_or_else(|| {
                format!("'budget.alerts[{i}]' must be a number")
            })?);
        }
        c.alert_thresholds = alerts;
    }
    if let Some(ramp) = resolve_ramp(doc, &scope)? {
        c.ramp = ramp;
    }
    // campaign precedence: a reschedule applies first, disabled wins
    let (outage_off, outage_spec) = resolve_outage(doc, &scope)?;
    if let Some(spec) = outage_spec {
        c.outage = Some(spec);
    }
    if outage_off {
        c.outage = None;
    }
    // [policy]: the campaign dialect (mode + explicit aws/gcp/azure
    // weights) — relational enough to stay bespoke
    let weights = match (
        want_f64(doc, &["policy", "aws"])?,
        want_f64(doc, &["policy", "gcp"])?,
        want_f64(doc, &["policy", "azure"])?,
    ) {
        (Some(aws), Some(gcp), Some(azure)) => {
            Some(ProviderWeights { aws, gcp, azure })
        }
        (None, None, None) => None,
        _ => {
            return Err("[policy] weights need all three of \
                        aws/gcp/azure"
                .into())
        }
    };
    if let Some(mode) = doc.get_path(&["policy", "mode"]) {
        let mode = mode
            .as_str()
            .ok_or_else(|| "'policy.mode' must be a string".to_string())?;
        c.policy = match mode {
            "adaptive" | "risk-aware" if weights.is_some() => {
                return Err(format!(
                    "policy.mode = \"{mode}\" conflicts with fixed \
                     aws/gcp/azure weights"
                ))
            }
            "adaptive" => PolicyMode::Adaptive,
            "risk-aware" => PolicyMode::RiskAware,
            // mode = "fixed" must actually pin a fixed policy: take
            // this doc's weights, or keep already-fixed weights — but
            // never let it silently leave a non-fixed policy in place
            "fixed" => match (weights, c.policy) {
                (Some(w), _) => PolicyMode::Fixed(w),
                (None, fixed @ PolicyMode::Fixed(_)) => fixed,
                (None, _) => {
                    return Err("policy.mode = \"fixed\" needs \
                                aws/gcp/azure weights (current \
                                policy is not fixed)"
                        .into())
                }
            },
            other => return Err(format!("unknown policy mode '{other}'")),
        };
    } else if let Some(w) = weights {
        c.policy = PolicyMode::Fixed(w);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// strict nested-path fetch helpers (campaign TOML + [server]/[fleet]/
// [ops] tables)
// ---------------------------------------------------------------------

/// Fetch `path` as a u64 or error; absent keys are `Ok(None)`.  Built
/// on `util::json::require_*` so the strict-value contract (mistyped
/// values error, never silently no-op) has one implementation shared
/// with the scenario-spec parser.
pub(crate) fn want_u64(
    doc: &Json,
    path: &[&str],
) -> Result<Option<u64>, String> {
    doc.get_path(path)
        .map(|v| require_u64(v, &format!("'{}'", path.join("."))))
        .transpose()
}

pub(crate) fn want_f64(
    doc: &Json,
    path: &[&str],
) -> Result<Option<f64>, String> {
    doc.get_path(path)
        .map(|v| require_f64(v, &format!("'{}'", path.join("."))))
        .transpose()
}

pub(crate) fn want_str<'a>(
    doc: &'a Json,
    path: &[&str],
) -> Result<Option<&'a str>, String> {
    doc.get_path(path)
        .map(|v| {
            v.as_str().ok_or_else(|| {
                format!("'{}' must be a string", path.join("."))
            })
        })
        .transpose()
}

// ---------------------------------------------------------------------
// renderings (the `icecloud knobs` subcommand and the pinned docs)
// ---------------------------------------------------------------------

/// Plain-text table for `icecloud knobs`.
pub fn render_table() -> String {
    let name_w = KNOBS.iter().map(|k| k.name.len()).max().unwrap_or(4);
    let path_w = KNOBS
        .iter()
        .map(|k| k.toml_path.join(".").len())
        .max()
        .unwrap_or(4);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<name_w$}  {:<path_w$}  {:<12} {:<6} {:<17} description\n",
        "knob", "campaign TOML", "type", "grid", "default"
    ));
    for k in &KNOBS {
        out.push_str(&format!(
            "{:<name_w$}  {:<path_w$}  {:<12} {:<6} {:<17} {}\n",
            k.name,
            k.toml_path.join("."),
            k.kind.label(),
            if k.grid_axis { "yes" } else { "no" },
            k.default_label,
            k.doc,
        ));
    }
    out
}

/// Markdown table for `icecloud knobs --format markdown`; the README
/// knob table is pinned byte-for-byte against this rendering.
pub fn render_markdown() -> String {
    let mut out = String::new();
    out.push_str(
        "| knob | campaign TOML | type | default | grid axis | description |\n",
    );
    out.push_str("|---|---|---|---|---|---|\n");
    for k in &KNOBS {
        out.push_str(&format!(
            "| `{}` | `{}` | {} | {} | {} | {} |\n",
            k.name,
            k.toml_path.join("."),
            k.kind.label(),
            k.default_label,
            if k.grid_axis { "yes" } else { "no" },
            k.doc,
        ));
    }
    out
}

/// JSON rendering for `icecloud knobs --format json`.
pub fn render_json() -> Json {
    let rows = KNOBS
        .iter()
        .map(|k| {
            let mut o = Json::obj();
            o.set("name", Json::from(k.name));
            o.set("toml_path", Json::from(k.toml_path.join(".").as_str()));
            o.set("type", Json::from(k.kind.label()));
            o.set("grid_axis", Json::Bool(k.grid_axis));
            o.set("default", Json::from(k.default_label));
            o.set("doc", Json::from(k.doc));
            o.set("sample", Json::from(k.sample));
            o
        })
        .collect();
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn registry_names_and_paths_are_unique() {
        let mut names: Vec<&str> = KNOBS.iter().map(|k| k.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), KNOBS.len(), "duplicate knob name");
        let mut paths: Vec<String> =
            KNOBS.iter().map(|k| k.toml_path.join(".")).collect();
        paths.sort_unstable();
        paths.dedup();
        assert_eq!(paths.len(), KNOBS.len(), "duplicate TOML path");
    }

    #[test]
    fn only_array_knobs_are_grid_ineligible() {
        for k in &KNOBS {
            let is_array = matches!(
                k.kind,
                KnobKind::U32Array | KnobKind::F64Array
            );
            assert_eq!(
                k.grid_axis, !is_array,
                "knob '{}' grid eligibility must follow its kind",
                k.name
            );
        }
    }

    #[test]
    fn error_context_shape_is_pinned() {
        let b = lookup("budget_usd").unwrap();
        assert_eq!(
            Scope::Scenario("x").key_ctx(b),
            "[scenario.x] 'budget_usd'"
        );
        assert_eq!(Scope::Campaign.key_ctx(b), "'budget.total_usd'");
        let h = lookup("ramp_hold_days").unwrap();
        assert_eq!(
            Scope::Scenario("x").key_ctx_idx(h, 1),
            "[scenario.x] 'ramp_hold_days[1]'"
        );
        assert_eq!(Scope::Campaign.key_ctx_idx(h, 1), "'ramp.hold_days[1]'");
        assert_eq!(
            Scope::Scenario("x").table_ctx("checkpoint"),
            "[scenario.x]"
        );
        assert_eq!(Scope::Campaign.table_ctx("checkpoint"), "[checkpoint]");
    }

    #[test]
    fn both_parse_paths_emit_the_shared_context_shape() {
        // scenario spelling
        let doc = toml::parse("budget_usd = \"x\"").unwrap();
        let err = parse_scenario("a", &doc).unwrap_err();
        assert_eq!(err, "[scenario.a] 'budget_usd' must be a number");
        // campaign spelling, same knob, same formatter
        let doc = toml::parse("[budget]\ntotal_usd = \"x\"").unwrap();
        let mut c = CampaignConfig::default();
        let err = apply_campaign_toml(&mut c, &doc).unwrap_err();
        assert_eq!(err, "'budget.total_usd' must be a number");
    }

    #[test]
    fn policy_names_resolve() {
        assert_eq!(policy_from_str("adaptive").unwrap(), PolicyMode::Adaptive);
        assert_eq!(
            policy_from_str("risk-aware").unwrap(),
            PolicyMode::RiskAware
        );
        match policy_from_str("uniform").unwrap() {
            PolicyMode::Fixed(w) => assert!((w.aws - w.azure).abs() < 1e-12),
            _ => panic!(),
        }
        match policy_from_str("paper").unwrap() {
            PolicyMode::Fixed(w) => assert!(w.azure > w.aws),
            _ => panic!(),
        }
        assert!(policy_from_str("bogus").is_err());
    }

    #[test]
    fn new_axis_values_validate_in_both_scopes() {
        // gpu_slots_per_instance = 0 is a meaningless carve-up
        let doc = toml::parse("gpu_slots_per_instance = 0").unwrap();
        assert!(parse_scenario("a", &doc).is_err());
        let mut c = CampaignConfig::default();
        assert!(apply_campaign_toml(&mut c, &doc).is_err());
        // negative checkpoint size, non-positive bandwidth
        for bad in [
            "checkpoint_size_gb = -1.0",
            "checkpoint_transfer_mbps = 0.0",
            "checkpoint_transfer_mbps = -5.0",
        ] {
            let doc = toml::parse(bad).unwrap();
            assert!(parse_scenario("a", &doc).is_err(), "{bad}");
        }
        let mut c = CampaignConfig::default();
        let doc =
            toml::parse("[checkpoint]\nsize_gb = -1.0\nevery_s = 900")
                .unwrap();
        assert!(apply_campaign_toml(&mut c, &doc).is_err());
        // valid values land in both targets
        let doc = toml::parse(
            "gpu_slots_per_instance = 4\n\
             checkpoint_size_gb = 2.5\n\
             checkpoint_transfer_mbps = 500.0",
        )
        .unwrap();
        let s = parse_scenario("a", &doc).unwrap();
        assert_eq!(s.gpu_slots_per_instance, Some(4));
        assert_eq!(s.checkpoint_size_gb, Some(2.5));
        assert_eq!(s.checkpoint_transfer_mbps, Some(500.0));
        let mut c = CampaignConfig::default();
        let doc = toml::parse(
            "gpu_slots_per_instance = 4\n\n\
             [checkpoint]\nevery_s = 900\nsize_gb = 2.5\n\
             transfer_mbps = 500.0",
        )
        .unwrap();
        apply_campaign_toml(&mut c, &doc).unwrap();
        assert_eq!(c.gpu_slots_per_instance, 4);
        assert_eq!(c.checkpoint_size_gb, 2.5);
        assert_eq!(c.checkpoint_transfer_mbps, 500.0);
    }

    #[test]
    fn renderings_cover_every_knob() {
        let table = render_table();
        let md = render_markdown();
        let json = render_json().to_string_compact();
        for k in &KNOBS {
            assert!(table.contains(k.name), "table missing {}", k.name);
            assert!(
                md.contains(&format!("`{}`", k.name)),
                "markdown missing {}",
                k.name
            );
            assert!(
                json.contains(&format!("\"{}\"", k.name)),
                "json missing {}",
                k.name
            );
        }
    }

    #[test]
    fn readme_knob_table_matches_the_registry() {
        let readme = include_str!("../../../README.md");
        assert!(
            readme.contains(&render_markdown()),
            "README knob table drifted from the registry; paste the \
             output of `icecloud knobs --format markdown` back in"
        );
    }

    #[test]
    fn matrix_module_doc_names_every_knob() {
        let src = include_str!("../sweep/matrix.rs");
        let doc: String = src
            .lines()
            .take_while(|l| l.starts_with("//!"))
            .collect::<Vec<_>>()
            .join("\n");
        for k in &KNOBS {
            assert!(
                doc.contains(&format!("`{}`", k.name)),
                "sweep/matrix.rs module doc is missing `{}`; keep its \
                 key list in sync with `icecloud knobs`",
                k.name
            );
        }
    }
}
