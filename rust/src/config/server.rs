//! `icecloud serve` knobs (`[server]` table).

use super::registry::want_u64;
use crate::util::json::Json;

/// `icecloud serve` knobs, read from the same TOML file as the base
/// campaign (a `[server]` table) with the same strict-value contract:
/// a present-but-mistyped or out-of-range key is an error, never a
/// silent no-op.  Deliberately a separate struct from
/// [`CampaignConfig`]: serving knobs can never affect replay results,
/// so they must never reach `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerConfig {
    /// Bounded async-job admission queue (jobs waiting to run); async
    /// submissions beyond it are shed with `429 + Retry-After`.
    pub queue_max: u32,
    /// Async job-runner threads draining the admission queue.
    pub job_runners: u32,
    /// Result-cache (memory tier) budget in MiB.
    pub cache_mb: u64,
    /// Persistent result-store root; `None` = memory-only.  Durable by
    /// default: results must survive a restart unless the operator
    /// explicitly opts out (`store_dir = ""`).
    pub store_dir: Option<String>,
    /// How many finished async-job records the job table retains before
    /// the oldest age out (their cached *results* stay; only the
    /// `/jobs/<id>` status record is forgotten).
    pub jobs_keep: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_max: 32,
            job_runners: 2,
            cache_mb: 64,
            store_dir: Some("icecloud-store".to_string()),
            jobs_keep: 1024,
        }
    }
}

impl ServerConfig {
    /// Apply a `[server]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["server", "queue_max"])? {
            if v == 0 {
                return Err("'server.queue_max' must be >= 1".into());
            }
            self.queue_max = u32::try_from(v).map_err(|_| {
                format!("'server.queue_max' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["server", "job_runners"])? {
            if v == 0 {
                return Err("'server.job_runners' must be >= 1".into());
            }
            self.job_runners = u32::try_from(v).map_err(|_| {
                format!("'server.job_runners' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["server", "cache_mb"])? {
            if v == 0 {
                return Err("'server.cache_mb' must be >= 1".into());
            }
            self.cache_mb = v;
        }
        if let Some(v) = doc.get_path(&["server", "store_dir"]) {
            let dir = v.as_str().ok_or_else(|| {
                "'server.store_dir' must be a string".to_string()
            })?;
            // the empty string is the explicit "no persistence" spelling
            self.store_dir = if dir.is_empty() {
                None
            } else {
                Some(dir.to_string())
            };
        }
        if let Some(v) = want_u64(doc, &["server", "jobs_keep"])? {
            if v == 0 {
                return Err("'server.jobs_keep' must be >= 1".into());
            }
            self.jobs_keep = u32::try_from(v).map_err(|_| {
                format!("'server.jobs_keep' {v} is out of range")
            })?;
        }
        Ok(())
    }
}
