//! Worker-fleet coordinator knobs (`[fleet]` table).

use super::registry::{want_f64, want_u64};
use crate::util::json::Json;

/// Worker-fleet coordinator knobs, read from a `[fleet]` table with the
/// same strict-value contract as [`ServerConfig`].  Like the `[server]`
/// table, these can never affect replay results — a lease TTL changes
/// *when* a unit is requeued, never *what* its replay produces — so
/// they must never reach `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Seconds a lease survives without a heartbeat before its unit is
    /// requeued.
    pub lease_ttl_s: u64,
    /// Heartbeat cadence advertised to workers at registration.
    pub heartbeat_every_s: u64,
    /// Fraction of fleet-computed units the coordinator recomputes
    /// locally and byte-compares before admitting (0 = trust, 1 =
    /// verify everything).
    pub spot_check_rate: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            lease_ttl_s: 30,
            heartbeat_every_s: 10,
            spot_check_rate: 0.1,
        }
    }
}

impl FleetConfig {
    /// Apply a `[fleet]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["fleet", "lease_ttl_s"])? {
            if v == 0 {
                return Err("'fleet.lease_ttl_s' must be >= 1".into());
            }
            self.lease_ttl_s = v;
        }
        if let Some(v) = want_u64(doc, &["fleet", "heartbeat_every_s"])? {
            if v == 0 {
                return Err("'fleet.heartbeat_every_s' must be >= 1".into());
            }
            self.heartbeat_every_s = v;
        }
        if let Some(v) = want_f64(doc, &["fleet", "spot_check_rate"])? {
            if !(0.0..=1.0).contains(&v) {
                return Err(
                    "'fleet.spot_check_rate' must be within [0, 1]".into()
                );
            }
            self.spot_check_rate = v;
        }
        if self.heartbeat_every_s >= self.lease_ttl_s {
            return Err(format!(
                "'fleet.heartbeat_every_s' ({}) must be shorter than \
                 'fleet.lease_ttl_s' ({}) or every lease expires between \
                 heartbeats",
                self.heartbeat_every_s, self.lease_ttl_s
            ));
        }
        Ok(())
    }
}
