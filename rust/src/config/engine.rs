//! Batched photon-engine execution knobs (`[engine]` table) and the
//! real-compute sampling config.  Wall-time only: these knobs never
//! reach `canonical_json` or the result-cache key, because the batched
//! engine is bit-identical across them.

use crate::runtime::SimdMode;

/// Real-compute sampling: execute the AOT photon artifact for every Nth
/// completed job.
#[derive(Debug, Clone, PartialEq)]
pub struct RealComputeConfig {
    pub variant: String,
    pub every_n_completions: u64,
}

/// Photon-engine execution knobs (the batched SoA engine, DESIGN.md
/// §13/§18).  These trade wall time only: the batched engine is
/// bit-identical across thread counts, bunch sizes and sweep
/// implementations, which is why the knobs are deliberately *excluded*
/// from [`CampaignConfig::canonical_json`] — two requests that differ
/// only here replay the same campaign and must share a cache entry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads per bunch execution (0 = all available cores).
    pub threads: u32,
    /// Photons per SoA sub-bunch (locality knob; 0 = engine default).
    pub bunch: u32,
    /// Segment-sweep implementation (`[engine] simd = "off"|"lanes"`;
    /// default lanes — the parity suite pinned it bit-identical).
    pub simd: SimdMode,
}

impl EngineConfig {
    /// The concrete thread count this config asks for (auto resolved).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::runtime::available_threads()
        } else {
            self.threads as usize
        }
    }

    /// Cap the engine at `budget` threads, so nested parallelism
    /// (replay workers × engine threads) stays within the machine —
    /// the sweep runner and server replay pool call this with
    /// `cores / workers` (see `sweep::runner::engine_thread_budget`).
    pub fn clamp_threads(&mut self, budget: usize) {
        self.threads = self.resolved_threads().min(budget.max(1)) as u32;
    }

    /// The execution plan this config resolves to.
    pub fn plan(&self) -> crate::runtime::ExecPlan {
        crate::runtime::ExecPlan {
            threads: self.threads as usize,
            bunch: self.bunch as usize,
            simd: self.simd,
        }
    }
}
