//! Operations-plane knobs (`[ops]` table).

use super::registry::want_u64;
use crate::util::json::Json;

/// Operations-plane knobs (`/events`, `/timeseries`, `/dash`), read
/// from an `[ops]` table with the same strict-value contract as
/// [`ServerConfig`].  Like every serving knob these shape *observation*
/// only — ring capacity changes which events a slow subscriber misses,
/// never what a replay computes — so they must never reach
/// `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpsConfig {
    /// Event-bus ring capacity: how many recent events a late or
    /// resuming subscriber can still replay before hitting a gap.
    pub events_ring: u32,
    /// Wall-clock seconds between ops-monitor samples of the serving
    /// gauges (queue depths, outstanding leases, goodput hours).
    pub sample_every_s: u64,
}

impl Default for OpsConfig {
    fn default() -> Self {
        OpsConfig { events_ring: 1024, sample_every_s: 5 }
    }
}

impl OpsConfig {
    /// Apply an `[ops]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["ops", "events_ring"])? {
            if v == 0 {
                return Err("'ops.events_ring' must be >= 1".into());
            }
            self.events_ring = u32::try_from(v).map_err(|_| {
                format!("'ops.events_ring' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["ops", "sample_every_s"])? {
            if v == 0 {
                return Err("'ops.sample_every_s' must be >= 1".into());
            }
            self.sample_every_s = v;
        }
        Ok(())
    }
}
