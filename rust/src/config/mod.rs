//! Campaign configuration: defaults that encode the paper's exercise,
//! overridable from a TOML file and CLI flags.

use crate::runtime::SimdMode;
use crate::sim::{SimTime, DAY, HOUR, MINUTE};
use crate::util::json::{require_bool, require_f64, require_u64, Json};
use crate::util::toml;
use crate::workload::{GeneratorConfig, OnPremConfig};

/// One step of the operators' ramp plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampStep {
    /// Desired total cloud GPUs during this step.
    pub target: u32,
    /// How long to hold before advancing.
    pub hold_s: SimTime,
}

impl RampStep {
    /// Stable serialization for cache keying (see
    /// [`CampaignConfig::canonical_json`]).
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("target", Json::from(self.target as u64));
        o.set("hold_s", Json::from(self.hold_s));
        o
    }
}

/// A scheduled network outage of the provider hosting the CE.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutageSpec {
    pub at_s: SimTime,
    pub duration_s: SimTime,
}

impl OutageSpec {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("at_s", Json::from(self.at_s));
        o.set("duration_s", Json::from(self.duration_s));
        o
    }
}

/// Provider preference weights (aws, gcp, azure order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProviderWeights {
    pub aws: f64,
    pub gcp: f64,
    pub azure: f64,
}

/// Target distribution policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyMode {
    /// Fixed provider weights (the paper's Azure-favoring choice).
    Fixed(ProviderWeights),
    /// Adapt weights to observed price and preemption rates.
    Adaptive,
    /// Region-level risk pricing: each region's share of the ramp
    /// target is proportional to its market depth discounted by price
    /// and its *observed* reclaim+churn rate.  The paper's
    /// Azure-favoring becomes an emergent outcome instead of a
    /// hardcoded weight vector — see `coordinator::policy`.
    RiskAware,
}

impl PolicyMode {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            PolicyMode::Adaptive => Json::from("adaptive"),
            PolicyMode::RiskAware => Json::from("risk-aware"),
            PolicyMode::Fixed(w) => {
                let mut f = Json::obj();
                f.set("aws", Json::from(w.aws));
                f.set("gcp", Json::from(w.gcp));
                f.set("azure", Json::from(w.azure));
                let mut o = Json::obj();
                o.set("fixed", f);
                o
            }
        }
    }
}

/// Default checkpoint-restore cost: re-staging input state and
/// re-priming the GPU before fresh bunches propagate.
pub const DEFAULT_RESUME_OVERHEAD_S: u64 = 120;

/// Checkpoint/restart policy for IceCube jobs (DESIGN.md §15).
///
/// The paper's jobs restarted from scratch on every interruption —
/// every preempted wall-hour was wasted.  `Interval` models periodic
/// checkpoints at photon-bunch granularity: a preempted or
/// outage-killed job requeues at its last checkpoint and pays
/// `resume_overhead_s` before fresh work proceeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CheckpointPolicy {
    /// Paper baseline: interrupted jobs restart from zero.
    #[default]
    None,
    /// Checkpoint every `every_s` seconds of job progress.
    Interval {
        every_s: u64,
        /// Wall seconds a resumed attempt spends restoring state
        /// before fresh work proceeds (always badput).
        resume_overhead_s: u64,
    },
}

impl CheckpointPolicy {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            CheckpointPolicy::None => Json::from("none"),
            CheckpointPolicy::Interval { every_s, resume_overhead_s } => {
                let mut i = Json::obj();
                i.set("every_s", Json::from(*every_s));
                i.set(
                    "resume_overhead_s",
                    Json::from(*resume_overhead_s),
                );
                let mut o = Json::obj();
                o.set("interval", i);
                o
            }
        }
    }

    /// Shared validation of the three checkpoint knobs as they appear
    /// in campaign TOML (`[checkpoint]`) and sweep-matrix scenario
    /// tables — one decision table, two parsers.  `Ok(None)` means no
    /// knob was present (leave the current policy alone); `ctx`
    /// prefixes error messages.
    pub fn from_knobs(
        disabled: bool,
        every_s: Option<u64>,
        resume_overhead_s: Option<u64>,
        ctx: &str,
    ) -> Result<Option<CheckpointPolicy>, String> {
        match (disabled, every_s, resume_overhead_s) {
            (true, None, None) => Ok(Some(CheckpointPolicy::None)),
            (true, _, _) => Err(format!(
                "{ctx} sets the disabled knob next to interval knobs; \
                 pick one"
            )),
            (false, Some(0), _) => Err(format!(
                "{ctx} checkpoint interval must be >= 1 second"
            )),
            (false, Some(every_s), overhead) => {
                Ok(Some(CheckpointPolicy::Interval {
                    every_s,
                    resume_overhead_s: overhead
                        .unwrap_or(DEFAULT_RESUME_OVERHEAD_S),
                }))
            }
            (false, None, Some(_)) => Err(format!(
                "{ctx} resume overhead needs a checkpoint interval"
            )),
            (false, None, None) => Ok(None),
        }
    }

    /// Restore cost charged at the start of a resumed attempt.
    pub fn resume_overhead_s(&self) -> u64 {
        match self {
            CheckpointPolicy::None => 0,
            CheckpointPolicy::Interval { resume_overhead_s, .. } => {
                *resume_overhead_s
            }
        }
    }

    /// Largest checkpointed progress not exceeding `progress_s`.
    pub fn salvageable(&self, progress_s: u64) -> u64 {
        match self {
            CheckpointPolicy::None => 0,
            CheckpointPolicy::Interval { every_s, .. } => {
                crate::workload::icecube::salvageable_progress(
                    progress_s, *every_s,
                )
            }
        }
    }
}

/// Real-compute sampling: execute the AOT photon artifact for every Nth
/// completed job.
#[derive(Debug, Clone, PartialEq)]
pub struct RealComputeConfig {
    pub variant: String,
    pub every_n_completions: u64,
}

/// Photon-engine execution knobs (the batched SoA engine, DESIGN.md
/// §13/§18).  These trade wall time only: the batched engine is
/// bit-identical across thread counts, bunch sizes and sweep
/// implementations, which is why the knobs are deliberately *excluded*
/// from [`CampaignConfig::canonical_json`] — two requests that differ
/// only here replay the same campaign and must share a cache entry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads per bunch execution (0 = all available cores).
    pub threads: u32,
    /// Photons per SoA sub-bunch (locality knob; 0 = engine default).
    pub bunch: u32,
    /// Segment-sweep implementation (`[engine] simd = "off"|"lanes"`;
    /// default lanes — the parity suite pinned it bit-identical).
    pub simd: SimdMode,
}

impl EngineConfig {
    /// The concrete thread count this config asks for (auto resolved).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::runtime::available_threads()
        } else {
            self.threads as usize
        }
    }

    /// Cap the engine at `budget` threads, so nested parallelism
    /// (replay workers × engine threads) stays within the machine —
    /// the sweep runner and server replay pool call this with
    /// `cores / workers` (see `sweep::runner::engine_thread_budget`).
    pub fn clamp_threads(&mut self, budget: usize) {
        self.threads = self.resolved_threads().min(budget.max(1)) as u32;
    }

    /// The execution plan this config resolves to.
    pub fn plan(&self) -> crate::runtime::ExecPlan {
        crate::runtime::ExecPlan {
            threads: self.threads as usize,
            bunch: self.bunch as usize,
            simd: self.simd,
        }
    }
}

/// NAT behaviour override applied to every cloud region (scenario knob).
///
/// The paper's §IV incident hinges on Azure's default 4-minute NAT idle
/// timeout; sweeps use this to ask "what if the infrastructure had been
/// different" instead of only "what if our keepalive had been different".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NatOverride {
    /// Keep each provider's own NAT profile (Azure: 240 s idle timeout).
    #[default]
    ProviderDefault,
    /// Force an idle timeout of this many seconds on every region.
    IdleTimeout(u64),
    /// No NAT idle expiry anywhere (the fixed-infrastructure ablation).
    Disabled,
}

impl NatOverride {
    /// Stable serialization for cache keying.
    pub fn canonical_json(&self) -> Json {
        match self {
            NatOverride::ProviderDefault => Json::from("provider-default"),
            NatOverride::Disabled => Json::from("disabled"),
            NatOverride::IdleTimeout(t) => {
                let mut o = Json::obj();
                o.set("idle_timeout_s", Json::from(*t));
                o
            }
        }
    }
}

/// Everything the campaign runner needs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub seed: u64,
    pub duration_s: SimTime,
    pub tick_s: u64,
    pub sample_every_s: u64,
    /// Group/ledger/target reconciliation period.
    pub control_period_s: u64,
    pub negotiation_period_s: u64,

    pub budget_usd: f64,
    pub alert_thresholds: Vec<f64>,
    /// Non-instance costs (egress, disks, the CE VM) as a fraction of
    /// instance spend — the gap between GPU-hours x price and the paper's
    /// "all included" $58k.
    pub overhead_fraction: f64,
    /// Stop provisioning when remaining budget falls below this fraction.
    pub budget_reserve_fraction: f64,
    /// Resume after an outage at `post_outage_target` if the remaining
    /// budget fraction is at or below this (the paper's 1k-GPU decision).
    pub low_budget_resume_fraction: f64,
    pub post_outage_target: u32,

    /// Cloud worker keepalive (60 s = the post-incident tuned value;
    /// set 300 to re-live §IV).
    pub keepalive_s: u64,
    /// Multiplier on every region's baseline churn-preemption hazard
    /// (1.0 = the calibrated defaults; scenario sweeps raise it to model
    /// busier spot markets).
    pub preempt_multiplier: f64,
    /// NAT behaviour override applied to every region.
    pub nat_override: NatOverride,
    /// Job checkpoint/restart policy (None = the paper's
    /// restart-from-scratch baseline).
    pub checkpoint: CheckpointPolicy,

    pub ramp: Vec<RampStep>,
    pub outage: Option<OutageSpec>,
    pub policy: PolicyMode,

    pub onprem: OnPremConfig,
    pub generator: GeneratorConfig,
    /// fp32 FLOPs per photon bunch (overridden from artifact metadata
    /// when real compute is enabled).
    pub flops_per_bunch: f64,
    pub real_compute: Option<RealComputeConfig>,
    /// Batched photon-engine execution knobs (wall time only; never
    /// part of the cache key).
    pub engine: EngineConfig,
}

impl Default for CampaignConfig {
    /// The paper's two-week exercise.
    fn default() -> Self {
        CampaignConfig {
            seed: 20210921,
            duration_s: 14 * DAY,
            tick_s: MINUTE,
            sample_every_s: 10 * MINUTE,
            control_period_s: 5 * MINUTE,
            negotiation_period_s: 5 * MINUTE,
            budget_usd: 58_000.0,
            alert_thresholds: vec![0.75, 0.5, 0.25, 0.1],
            overhead_fraction: 0.18,
            budget_reserve_fraction: 0.02,
            low_budget_resume_fraction: 0.25,
            post_outage_target: 1000,
            keepalive_s: 60,
            preempt_multiplier: 1.0,
            nat_override: NatOverride::ProviderDefault,
            checkpoint: CheckpointPolicy::None,
            ramp: vec![
                // initial validation with a small fleet, then the paper's
                // 400 / 900 / 1.2k / 1.6k / 2k staircase
                RampStep { target: 50, hold_s: DAY },
                RampStep { target: 400, hold_s: 2 * DAY },
                RampStep { target: 900, hold_s: 2 * DAY },
                RampStep { target: 1200, hold_s: 2 * DAY },
                RampStep { target: 1600, hold_s: 2 * DAY },
                RampStep { target: 2000, hold_s: 30 * DAY }, // until outage
            ],
            outage: Some(OutageSpec {
                at_s: 11 * DAY + 6 * HOUR,
                duration_s: 2 * HOUR,
            }),
            policy: PolicyMode::Fixed(ProviderWeights {
                aws: 0.15,
                gcp: 0.15,
                azure: 0.70,
            }),
            onprem: OnPremConfig::default(),
            generator: GeneratorConfig::default(),
            flops_per_bunch: 1.2e10,
            real_compute: None,
            engine: EngineConfig::default(),
        }
    }
}

/// Fetch `path` as a u64 or error; absent keys are `Ok(None)`.  Built
/// on `util::json::require_*` so the strict-value contract (mistyped
/// values error, never silently no-op) has one implementation shared
/// with the scenario-spec parser.
fn want_u64(doc: &Json, path: &[&str]) -> Result<Option<u64>, String> {
    doc.get_path(path)
        .map(|v| require_u64(v, &format!("'{}'", path.join("."))))
        .transpose()
}

fn want_f64(doc: &Json, path: &[&str]) -> Result<Option<f64>, String> {
    doc.get_path(path)
        .map(|v| require_f64(v, &format!("'{}'", path.join("."))))
        .transpose()
}

fn want_bool(doc: &Json, path: &[&str]) -> Result<Option<bool>, String> {
    doc.get_path(path)
        .map(|v| require_bool(v, &format!("'{}'", path.join("."))))
        .transpose()
}

fn want_str<'a>(
    doc: &'a Json,
    path: &[&str],
) -> Result<Option<&'a str>, String> {
    doc.get_path(path)
        .map(|v| {
            v.as_str().ok_or_else(|| {
                format!("'{}' must be a string", path.join("."))
            })
        })
        .transpose()
}

/// Convert a spec-file duration expressed in `unit_s`-second units
/// (days, hours) to whole sim-seconds.  `f64 as u64` saturates NaN and
/// negatives to 0 and +inf to `u64::MAX`, so `duration_days = -1.0`
/// would replay a zero-length campaign under a citable name; reject
/// everything the cast would corrupt instead.  Shared by
/// [`CampaignConfig::apply_toml`], the scenario-spec parser
/// (`sweep::matrix`) and the `--days` CLI override.
pub fn spec_seconds(
    v: f64,
    unit_s: u64,
    ctx: &str,
) -> Result<u64, String> {
    if !v.is_finite() || v < 0.0 {
        return Err(format!(
            "{ctx} must be a finite non-negative number (got {v})"
        ));
    }
    let s = v * unit_s as f64;
    if s >= u64::MAX as f64 {
        return Err(format!("{ctx} ({v}) is out of range"));
    }
    Ok(s as u64)
}

/// Range-check a spec-file integer destined for a `u32` field (ramp
/// targets, on-prem slots).  `u64 as u32` truncates modulo 2^32, so
/// `ramp_targets = [4294967297]` would silently "ramp" to 1 GPU.
pub fn spec_u32(v: u64, ctx: &str) -> Result<u32, String> {
    u32::try_from(v).map_err(|_| {
        format!("{ctx} ({v}) is out of range (max {})", u32::MAX)
    })
}

impl CampaignConfig {
    /// Apply overrides from a parsed TOML document.  Strict on values:
    /// a present-but-mistyped key is an error, never a silent no-op
    /// (the server feeds untrusted `[base]` tables through here).
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["seed"])? {
            self.seed = v;
        }
        if let Some(v) = want_f64(doc, &["duration_days"])? {
            self.duration_s = spec_seconds(v, DAY, "'duration_days'")?;
        }
        if let Some(v) = want_u64(doc, &["keepalive_s"])? {
            self.keepalive_s = v;
        }
        if let Some(v) = want_f64(doc, &["preempt_multiplier"])? {
            self.preempt_multiplier = v;
        }
        if let Some(v) = want_u64(doc, &["engine", "threads"])? {
            self.engine.threads = u32::try_from(v)
                .map_err(|_| format!("'engine.threads' {v} is out of range"))?;
        }
        if let Some(v) = want_u64(doc, &["engine", "bunch"])? {
            if v == 0 {
                return Err("'engine.bunch' must be >= 1".into());
            }
            self.engine.bunch = u32::try_from(v)
                .map_err(|_| format!("'engine.bunch' {v} is out of range"))?;
        }
        if let Some(v) = want_str(doc, &["engine", "simd"])? {
            self.engine.simd = SimdMode::parse(v).ok_or_else(|| {
                format!(
                    "'engine.simd' must be \"off\" or \"lanes\", got {v:?}"
                )
            })?;
        }
        let ck_disabled =
            want_bool(doc, &["checkpoint", "disabled"])? == Some(true);
        let ck_every = want_u64(doc, &["checkpoint", "every_s"])?;
        let ck_overhead =
            want_u64(doc, &["checkpoint", "resume_overhead_s"])?;
        if let Some(policy) = CheckpointPolicy::from_knobs(
            ck_disabled,
            ck_every,
            ck_overhead,
            "[checkpoint]",
        )? {
            self.checkpoint = policy;
        }
        let nat_disabled =
            want_bool(doc, &["nat", "disabled"])? == Some(true);
        let nat_timeout = want_u64(doc, &["nat", "idle_timeout_s"])?;
        match (nat_disabled, nat_timeout) {
            (true, Some(_)) => {
                return Err("[nat] sets both disabled = true and \
                            idle_timeout_s; pick one"
                    .into())
            }
            (true, None) => self.nat_override = NatOverride::Disabled,
            (false, Some(t)) => {
                self.nat_override = NatOverride::IdleTimeout(t)
            }
            (false, None) => {}
        }
        if let Some(v) = want_f64(doc, &["budget", "total_usd"])? {
            self.budget_usd = v;
        }
        if let Some(v) = want_f64(doc, &["budget", "overhead_fraction"])? {
            self.overhead_fraction = v;
        }
        if let Some(arr) =
            doc.get_path(&["budget", "alerts"]).map(|v| {
                v.as_arr().ok_or_else(|| {
                    "'budget.alerts' must be an array".to_string()
                })
            })
        {
            let arr = arr?;
            let mut alerts = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                alerts.push(v.as_f64().ok_or_else(|| {
                    format!("'budget.alerts[{i}]' must be a number")
                })?);
            }
            self.alert_thresholds = alerts;
        }
        if let Some(v) = want_u64(doc, &["onprem", "slots"])? {
            self.onprem.slots = spec_u32(v, "'onprem.slots'")?;
        }
        if let Some(arr) = doc.get_path(&["ramp", "targets"]) {
            let arr = arr.as_arr().ok_or_else(|| {
                "'ramp.targets' must be an array".to_string()
            })?;
            let holds = match doc.get_path(&["ramp", "hold_days"]) {
                None => Vec::new(),
                Some(h) => {
                    let h = h.as_arr().ok_or_else(|| {
                        "'ramp.hold_days' must be an array".to_string()
                    })?;
                    let mut out = Vec::with_capacity(h.len());
                    for (i, v) in h.iter().enumerate() {
                        out.push(v.as_f64().ok_or_else(|| {
                            format!(
                                "'ramp.hold_days[{i}]' must be a number"
                            )
                        })?);
                    }
                    out
                }
            };
            if holds.len() > arr.len() {
                return Err(format!(
                    "'ramp.hold_days' has {} entries for {} targets",
                    holds.len(),
                    arr.len()
                ));
            }
            // strict: a dropped entry would shift the target/hold
            // pairing (or leave an empty ramp) without any diagnostic
            let mut ramp = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                let target = v.as_u64().ok_or_else(|| {
                    format!(
                        "'ramp.targets[{i}]' must be a non-negative \
                         integer"
                    )
                })?;
                ramp.push(RampStep {
                    target: spec_u32(
                        target,
                        &format!("'ramp.targets[{i}]'"),
                    )?,
                    hold_s: spec_seconds(
                        holds.get(i).copied().unwrap_or(2.0),
                        DAY,
                        &format!("'ramp.hold_days[{i}]'"),
                    )?,
                });
            }
            if ramp.is_empty() {
                return Err("'ramp.targets' must not be empty".into());
            }
            self.ramp = ramp;
        }
        match (
            want_f64(doc, &["outage", "at_days"])?,
            want_f64(doc, &["outage", "duration_hours"])?,
        ) {
            (Some(at), dur) => {
                self.outage = Some(OutageSpec {
                    at_s: spec_seconds(at, DAY, "'outage.at_days'")?,
                    duration_s: spec_seconds(
                        dur.unwrap_or(2.0),
                        HOUR,
                        "'outage.duration_hours'",
                    )?,
                });
            }
            // a dangling duration would otherwise be validated and then
            // silently dropped — same contract as
            // checkpoint.resume_overhead_s without every_s
            (None, Some(_)) => {
                return Err("'outage.duration_hours' needs \
                            'outage.at_days'"
                    .into())
            }
            (None, None) => {}
        }
        if want_bool(doc, &["outage", "disabled"])? == Some(true) {
            self.outage = None;
        }
        let weights = match (
            want_f64(doc, &["policy", "aws"])?,
            want_f64(doc, &["policy", "gcp"])?,
            want_f64(doc, &["policy", "azure"])?,
        ) {
            (Some(aws), Some(gcp), Some(azure)) => {
                Some(ProviderWeights { aws, gcp, azure })
            }
            (None, None, None) => None,
            _ => {
                return Err("[policy] weights need all three of \
                            aws/gcp/azure"
                    .into())
            }
        };
        if let Some(mode) = doc.get_path(&["policy", "mode"]) {
            let mode = mode.as_str().ok_or_else(|| {
                "'policy.mode' must be a string".to_string()
            })?;
            self.policy = match mode {
                "adaptive" | "risk-aware" if weights.is_some() => {
                    return Err(format!(
                        "policy.mode = \"{mode}\" conflicts with fixed \
                         aws/gcp/azure weights"
                    ))
                }
                "adaptive" => PolicyMode::Adaptive,
                "risk-aware" => PolicyMode::RiskAware,
                // mode = "fixed" must actually pin a fixed policy: take
                // this doc's weights, or keep already-fixed weights —
                // but never let it silently leave a non-fixed policy in
                // place
                "fixed" => match (weights, self.policy) {
                    (Some(w), _) => PolicyMode::Fixed(w),
                    (None, fixed @ PolicyMode::Fixed(_)) => fixed,
                    (None, _) => {
                        return Err("policy.mode = \"fixed\" needs \
                                    aws/gcp/azure weights (current \
                                    policy is not fixed)"
                            .into())
                    }
                },
                other => return Err(format!("unknown policy mode '{other}'")),
            };
        } else if let Some(w) = weights {
            self.policy = PolicyMode::Fixed(w);
        }
        Ok(())
    }

    /// Canonical serialization: every semantically-relevant field, in a
    /// deterministic key order (`Json::Obj` is a `BTreeMap`), with
    /// deterministic number formatting (`util::json::write_num`).  Two
    /// configs produce the same string iff they replay the same
    /// campaign, which is what makes the server's content-addressed
    /// result cache sound — see `crate::server::cache`.
    ///
    /// Adding a field to `CampaignConfig` that affects the replay MUST
    /// be mirrored here; the version tag lets the cache key change
    /// shape without aliasing old keys.  [`EngineConfig`] is the one
    /// deliberate omission: the batched engine is bit-identical across
    /// its knobs, so they must NOT split the cache.
    pub fn canonical_json(&self) -> Json {
        let mut o = Json::obj();
        // v2: adds the `checkpoint` policy (PR 5); the bump keeps every
        // pre-checkpoint cache key from aliasing a v2 key
        o.set("v", Json::from(2u64));
        o.set("seed", Json::from(self.seed));
        o.set("duration_s", Json::from(self.duration_s));
        o.set("tick_s", Json::from(self.tick_s));
        o.set("sample_every_s", Json::from(self.sample_every_s));
        o.set("control_period_s", Json::from(self.control_period_s));
        o.set(
            "negotiation_period_s",
            Json::from(self.negotiation_period_s),
        );
        o.set("budget_usd", Json::from(self.budget_usd));
        o.set(
            "alert_thresholds",
            Json::Arr(
                self.alert_thresholds
                    .iter()
                    .map(|&t| Json::from(t))
                    .collect(),
            ),
        );
        o.set("overhead_fraction", Json::from(self.overhead_fraction));
        o.set(
            "budget_reserve_fraction",
            Json::from(self.budget_reserve_fraction),
        );
        o.set(
            "low_budget_resume_fraction",
            Json::from(self.low_budget_resume_fraction),
        );
        o.set(
            "post_outage_target",
            Json::from(self.post_outage_target as u64),
        );
        o.set("keepalive_s", Json::from(self.keepalive_s));
        o.set(
            "preempt_multiplier",
            Json::from(self.preempt_multiplier),
        );
        o.set("nat_override", self.nat_override.canonical_json());
        o.set("checkpoint", self.checkpoint.canonical_json());
        o.set(
            "ramp",
            Json::Arr(self.ramp.iter().map(RampStep::canonical_json).collect()),
        );
        o.set(
            "outage",
            match &self.outage {
                None => Json::Null,
                Some(spec) => spec.canonical_json(),
            },
        );
        o.set("policy", self.policy.canonical_json());
        let mut onprem = Json::obj();
        onprem.set("slots", Json::from(self.onprem.slots as u64));
        onprem.set("keepalive_s", Json::from(self.onprem.keepalive_s));
        onprem.set("availability", Json::from(self.onprem.availability));
        o.set("onprem", onprem);
        let mut generator = Json::obj();
        generator.set(
            "backlog_factor",
            Json::from(self.generator.backlog_factor),
        );
        generator.set(
            "min_backlog",
            Json::from(self.generator.min_backlog as u64),
        );
        generator.set(
            "request_memory_mb",
            Json::from(self.generator.request_memory_mb),
        );
        let mut runtimes = Json::obj();
        runtimes.set("median_s", Json::from(self.generator.runtimes.median_s));
        runtimes.set("sigma", Json::from(self.generator.runtimes.sigma));
        runtimes.set("min_s", Json::from(self.generator.runtimes.min_s));
        runtimes.set("max_s", Json::from(self.generator.runtimes.max_s));
        generator.set("runtimes", runtimes);
        o.set("generator", generator);
        o.set("flops_per_bunch", Json::from(self.flops_per_bunch));
        o.set(
            "real_compute",
            match &self.real_compute {
                None => Json::Null,
                Some(rc) => {
                    let mut r = Json::obj();
                    r.set("variant", Json::from(rc.variant.as_str()));
                    r.set(
                        "every_n_completions",
                        Json::from(rc.every_n_completions),
                    );
                    r
                }
            },
        );
        o
    }

    /// Inverse of [`canonical_json`](Self::canonical_json):
    /// reconstruct a replaying config from its canonical form.  This
    /// is how fleet workers receive their unit of work — the
    /// coordinator sends the *applied* config's canonical JSON in a
    /// lease grant, and because the canonical form covers every
    /// replay-relevant field, the worker's replay is byte-identical to
    /// the coordinator's.  Strict: a missing or mistyped field is an
    /// error, never a silent default — a worker replaying a different
    /// campaign than leased would fail every sha compare.
    ///
    /// [`EngineConfig`] is deliberately absent from the canonical form
    /// (results are engine-thread-invariant), so the worker keeps its
    /// own engine defaults and clamps its own thread budget.
    pub fn from_canonical_json(doc: &Json) -> Result<Self, String> {
        fn canon<'a>(doc: &'a Json, key: &str) -> Result<&'a Json, String> {
            doc.get(key)
                .ok_or_else(|| format!("canonical config missing '{key}'"))
        }
        fn canon_u64(doc: &Json, key: &str) -> Result<u64, String> {
            require_u64(canon(doc, key)?, &format!("canonical '{key}'"))
        }
        fn canon_f64(doc: &Json, key: &str) -> Result<f64, String> {
            require_f64(canon(doc, key)?, &format!("canonical '{key}'"))
        }
        fn canon_u32(doc: &Json, key: &str) -> Result<u32, String> {
            let v = canon_u64(doc, key)?;
            u32::try_from(v)
                .map_err(|_| format!("canonical '{key}' {v} is out of range"))
        }
        fn canon_i64(doc: &Json, key: &str) -> Result<i64, String> {
            let v = canon_f64(doc, key)?;
            if v.fract() != 0.0 || !(-9e15..=9e15).contains(&v) {
                return Err(format!("canonical '{key}' must be an integer"));
            }
            Ok(v as i64)
        }

        let v = canon_u64(doc, "v")?;
        if v != 2 {
            return Err(format!("unsupported canonical config version {v}"));
        }
        let mut c = CampaignConfig::default();
        c.seed = canon_u64(doc, "seed")?;
        c.duration_s = canon_u64(doc, "duration_s")?;
        c.tick_s = canon_u64(doc, "tick_s")?;
        c.sample_every_s = canon_u64(doc, "sample_every_s")?;
        c.control_period_s = canon_u64(doc, "control_period_s")?;
        c.negotiation_period_s = canon_u64(doc, "negotiation_period_s")?;
        c.budget_usd = canon_f64(doc, "budget_usd")?;
        let alerts = canon(doc, "alert_thresholds")?
            .as_arr()
            .ok_or("canonical 'alert_thresholds' must be an array")?;
        c.alert_thresholds = alerts
            .iter()
            .map(|a| {
                a.as_f64().ok_or_else(|| {
                    "canonical 'alert_thresholds' entries must be numbers"
                        .to_string()
                })
            })
            .collect::<Result<_, _>>()?;
        c.overhead_fraction = canon_f64(doc, "overhead_fraction")?;
        c.budget_reserve_fraction = canon_f64(doc, "budget_reserve_fraction")?;
        c.low_budget_resume_fraction =
            canon_f64(doc, "low_budget_resume_fraction")?;
        c.post_outage_target = canon_u32(doc, "post_outage_target")?;
        c.keepalive_s = canon_u64(doc, "keepalive_s")?;
        c.preempt_multiplier = canon_f64(doc, "preempt_multiplier")?;
        c.nat_override = match canon(doc, "nat_override")? {
            Json::Str(s) if s == "provider-default" => {
                NatOverride::ProviderDefault
            }
            Json::Str(s) if s == "disabled" => NatOverride::Disabled,
            v @ Json::Obj(_) => {
                NatOverride::IdleTimeout(canon_u64(v, "idle_timeout_s")?)
            }
            _ => return Err("canonical 'nat_override' is malformed".into()),
        };
        c.checkpoint = match canon(doc, "checkpoint")? {
            Json::Str(s) if s == "none" => CheckpointPolicy::None,
            v @ Json::Obj(_) => {
                let i = v
                    .get("interval")
                    .ok_or("canonical 'checkpoint' is malformed")?;
                CheckpointPolicy::Interval {
                    every_s: canon_u64(i, "every_s")?,
                    resume_overhead_s: canon_u64(i, "resume_overhead_s")?,
                }
            }
            _ => return Err("canonical 'checkpoint' is malformed".into()),
        };
        let ramp = canon(doc, "ramp")?
            .as_arr()
            .ok_or("canonical 'ramp' must be an array")?;
        c.ramp = ramp
            .iter()
            .map(|step| {
                Ok(RampStep {
                    target: canon_u32(step, "target")?,
                    hold_s: canon_u64(step, "hold_s")?,
                })
            })
            .collect::<Result<_, String>>()?;
        c.outage = match canon(doc, "outage")? {
            Json::Null => None,
            v => Some(OutageSpec {
                at_s: canon_u64(v, "at_s")?,
                duration_s: canon_u64(v, "duration_s")?,
            }),
        };
        c.policy = match canon(doc, "policy")? {
            Json::Str(s) if s == "adaptive" => PolicyMode::Adaptive,
            Json::Str(s) if s == "risk-aware" => PolicyMode::RiskAware,
            v @ Json::Obj(_) => {
                let f =
                    v.get("fixed").ok_or("canonical 'policy' is malformed")?;
                PolicyMode::Fixed(ProviderWeights {
                    aws: canon_f64(f, "aws")?,
                    gcp: canon_f64(f, "gcp")?,
                    azure: canon_f64(f, "azure")?,
                })
            }
            _ => return Err("canonical 'policy' is malformed".into()),
        };
        let onprem = canon(doc, "onprem")?;
        c.onprem.slots = canon_u32(onprem, "slots")?;
        c.onprem.keepalive_s = canon_u64(onprem, "keepalive_s")?;
        c.onprem.availability = canon_f64(onprem, "availability")?;
        let generator = canon(doc, "generator")?;
        c.generator.backlog_factor = canon_f64(generator, "backlog_factor")?;
        c.generator.min_backlog = canon_u64(generator, "min_backlog")? as usize;
        c.generator.request_memory_mb =
            canon_i64(generator, "request_memory_mb")?;
        let runtimes = canon(generator, "runtimes")?;
        c.generator.runtimes.median_s = canon_f64(runtimes, "median_s")?;
        c.generator.runtimes.sigma = canon_f64(runtimes, "sigma")?;
        c.generator.runtimes.min_s = canon_u64(runtimes, "min_s")?;
        c.generator.runtimes.max_s = canon_u64(runtimes, "max_s")?;
        c.flops_per_bunch = canon_f64(doc, "flops_per_bunch")?;
        c.real_compute = match canon(doc, "real_compute")? {
            Json::Null => None,
            v => Some(RealComputeConfig {
                variant: v
                    .get("variant")
                    .and_then(Json::as_str)
                    .ok_or("canonical 'real_compute.variant' must be a string")?
                    .to_string(),
                every_n_completions: canon_u64(v, "every_n_completions")?,
            }),
        };
        Ok(c)
    }

    /// Build from an already-parsed TOML document over the defaults.
    pub fn from_toml_doc(doc: &Json) -> Result<Self, String> {
        let mut cfg = CampaignConfig::default();
        cfg.apply_toml(doc)?;
        Ok(cfg)
    }

    /// Load from a TOML file over the defaults.
    pub fn from_toml_file(path: &str) -> Result<Self, String> {
        Self::from_toml_doc(&load_toml_doc(path)?)
    }

    /// Total ticks in the campaign.
    pub fn num_ticks(&self) -> u64 {
        self.duration_s / self.tick_s
    }
}

/// Read and parse one TOML config file — the single loading path for
/// every `--config` consumer (campaign, sweep, serve).
pub fn load_toml_doc(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    toml::parse(&text).map_err(|e| e.to_string())
}

/// `icecloud serve` knobs, read from the same TOML file as the base
/// campaign (a `[server]` table) with the same strict-value contract:
/// a present-but-mistyped or out-of-range key is an error, never a
/// silent no-op.  Deliberately a separate struct from
/// [`CampaignConfig`]: serving knobs can never affect replay results,
/// so they must never reach `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerConfig {
    /// Bounded async-job admission queue (jobs waiting to run); async
    /// submissions beyond it are shed with `429 + Retry-After`.
    pub queue_max: u32,
    /// Async job-runner threads draining the admission queue.
    pub job_runners: u32,
    /// Result-cache (memory tier) budget in MiB.
    pub cache_mb: u64,
    /// Persistent result-store root; `None` = memory-only.  Durable by
    /// default: results must survive a restart unless the operator
    /// explicitly opts out (`store_dir = ""`).
    pub store_dir: Option<String>,
    /// How many finished async-job records the job table retains before
    /// the oldest age out (their cached *results* stay; only the
    /// `/jobs/<id>` status record is forgotten).
    pub jobs_keep: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_max: 32,
            job_runners: 2,
            cache_mb: 64,
            store_dir: Some("icecloud-store".to_string()),
            jobs_keep: 1024,
        }
    }
}

impl ServerConfig {
    /// Apply a `[server]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["server", "queue_max"])? {
            if v == 0 {
                return Err("'server.queue_max' must be >= 1".into());
            }
            self.queue_max = u32::try_from(v).map_err(|_| {
                format!("'server.queue_max' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["server", "job_runners"])? {
            if v == 0 {
                return Err("'server.job_runners' must be >= 1".into());
            }
            self.job_runners = u32::try_from(v).map_err(|_| {
                format!("'server.job_runners' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["server", "cache_mb"])? {
            if v == 0 {
                return Err("'server.cache_mb' must be >= 1".into());
            }
            self.cache_mb = v;
        }
        if let Some(v) = doc.get_path(&["server", "store_dir"]) {
            let dir = v.as_str().ok_or_else(|| {
                "'server.store_dir' must be a string".to_string()
            })?;
            // the empty string is the explicit "no persistence" spelling
            self.store_dir = if dir.is_empty() {
                None
            } else {
                Some(dir.to_string())
            };
        }
        if let Some(v) = want_u64(doc, &["server", "jobs_keep"])? {
            if v == 0 {
                return Err("'server.jobs_keep' must be >= 1".into());
            }
            self.jobs_keep = u32::try_from(v).map_err(|_| {
                format!("'server.jobs_keep' {v} is out of range")
            })?;
        }
        Ok(())
    }
}

/// Worker-fleet coordinator knobs, read from a `[fleet]` table with the
/// same strict-value contract as [`ServerConfig`].  Like the `[server]`
/// table, these can never affect replay results — a lease TTL changes
/// *when* a unit is requeued, never *what* its replay produces — so
/// they must never reach `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Seconds a lease survives without a heartbeat before its unit is
    /// requeued.
    pub lease_ttl_s: u64,
    /// Heartbeat cadence advertised to workers at registration.
    pub heartbeat_every_s: u64,
    /// Fraction of fleet-computed units the coordinator recomputes
    /// locally and byte-compares before admitting (0 = trust, 1 =
    /// verify everything).
    pub spot_check_rate: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            lease_ttl_s: 30,
            heartbeat_every_s: 10,
            spot_check_rate: 0.1,
        }
    }
}

impl FleetConfig {
    /// Apply a `[fleet]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["fleet", "lease_ttl_s"])? {
            if v == 0 {
                return Err("'fleet.lease_ttl_s' must be >= 1".into());
            }
            self.lease_ttl_s = v;
        }
        if let Some(v) = want_u64(doc, &["fleet", "heartbeat_every_s"])? {
            if v == 0 {
                return Err("'fleet.heartbeat_every_s' must be >= 1".into());
            }
            self.heartbeat_every_s = v;
        }
        if let Some(v) = want_f64(doc, &["fleet", "spot_check_rate"])? {
            if !(0.0..=1.0).contains(&v) {
                return Err(
                    "'fleet.spot_check_rate' must be within [0, 1]".into()
                );
            }
            self.spot_check_rate = v;
        }
        if self.heartbeat_every_s >= self.lease_ttl_s {
            return Err(format!(
                "'fleet.heartbeat_every_s' ({}) must be shorter than \
                 'fleet.lease_ttl_s' ({}) or every lease expires between \
                 heartbeats",
                self.heartbeat_every_s, self.lease_ttl_s
            ));
        }
        Ok(())
    }
}

/// Operations-plane knobs (`/events`, `/timeseries`, `/dash`), read
/// from an `[ops]` table with the same strict-value contract as
/// [`ServerConfig`].  Like every serving knob these shape *observation*
/// only — ring capacity changes which events a slow subscriber misses,
/// never what a replay computes — so they must never reach
/// `canonical_json` and the result-cache key.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpsConfig {
    /// Event-bus ring capacity: how many recent events a late or
    /// resuming subscriber can still replay before hitting a gap.
    pub events_ring: u32,
    /// Wall-clock seconds between ops-monitor samples of the serving
    /// gauges (queue depths, outstanding leases, goodput hours).
    pub sample_every_s: u64,
}

impl Default for OpsConfig {
    fn default() -> Self {
        OpsConfig { events_ring: 1024, sample_every_s: 5 }
    }
}

impl OpsConfig {
    /// Apply an `[ops]` table from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = want_u64(doc, &["ops", "events_ring"])? {
            if v == 0 {
                return Err("'ops.events_ring' must be >= 1".into());
            }
            self.events_ring = u32::try_from(v).map_err(|_| {
                format!("'ops.events_ring' {v} is out of range")
            })?;
        }
        if let Some(v) = want_u64(doc, &["ops", "sample_every_s"])? {
            if v == 0 {
                return Err("'ops.sample_every_s' must be >= 1".into());
            }
            self.sample_every_s = v;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_encode_the_paper() {
        let c = CampaignConfig::default();
        assert_eq!(c.duration_s, 14 * DAY);
        assert_eq!(c.budget_usd, 58_000.0);
        let targets: Vec<u32> = c.ramp.iter().map(|s| s.target).collect();
        assert_eq!(targets, vec![50, 400, 900, 1200, 1600, 2000]);
        assert!(c.outage.is_some());
        match c.policy {
            PolicyMode::Fixed(w) => assert!(w.azure > w.aws && w.azure > w.gcp),
            _ => panic!("default policy is fixed Azure-favoring"),
        }
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::parse(
            r#"
seed = 7
duration_days = 2.0
keepalive_s = 300

[budget]
total_usd = 1000.0
alerts = [0.5]

[ramp]
targets = [10, 20]
hold_days = [0.5, 1.0]

[outage]
at_days = 1.0
duration_hours = 3.0

[policy]
aws = 0.2
gcp = 0.2
azure = 0.6
"#,
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.duration_s, 2 * DAY);
        assert_eq!(c.keepalive_s, 300);
        assert_eq!(c.budget_usd, 1000.0);
        assert_eq!(c.alert_thresholds, vec![0.5]);
        assert_eq!(c.ramp.len(), 2);
        assert_eq!(c.ramp[0], RampStep { target: 10, hold_s: DAY / 2 });
        assert_eq!(
            c.outage,
            Some(OutageSpec { at_s: DAY, duration_s: 3 * HOUR })
        );
        match c.policy {
            PolicyMode::Fixed(w) => assert_eq!(w.azure, 0.6),
            _ => panic!(),
        }
    }

    #[test]
    fn outage_can_be_disabled() {
        let doc = toml::parse("[outage]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert!(c.outage.is_none());
    }

    #[test]
    fn scenario_knobs_from_toml() {
        let doc = toml::parse(
            "preempt_multiplier = 4.0\n[nat]\nidle_timeout_s = 120",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.preempt_multiplier, 4.0);
        assert_eq!(c.nat_override, NatOverride::IdleTimeout(120));

        let doc = toml::parse("[nat]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.nat_override, NatOverride::Disabled);
    }

    #[test]
    fn conflicting_nat_knobs_rejected() {
        let doc =
            toml::parse("[nat]\ndisabled = true\nidle_timeout_s = 120")
                .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn scenario_knob_defaults_are_neutral() {
        let c = CampaignConfig::default();
        assert_eq!(c.preempt_multiplier, 1.0);
        assert_eq!(c.nat_override, NatOverride::ProviderDefault);
    }

    #[test]
    fn adaptive_policy_selectable() {
        let doc = toml::parse("[policy]\nmode = \"adaptive\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.policy, PolicyMode::Adaptive);
    }

    #[test]
    fn bad_policy_mode_rejected() {
        let doc = toml::parse("[policy]\nmode = \"nope\"").unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn fixed_mode_without_weights_cannot_mask_adaptive() {
        // mode = "fixed" on an already-fixed policy keeps its weights
        let doc = toml::parse("[policy]\nmode = \"fixed\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert!(matches!(c.policy, PolicyMode::Fixed(_)));
        // ...but on an adaptive policy it must error, not silently
        // replay adaptive under a "fixed" spec
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        assert!(c.apply_toml(&doc).is_err());
        // mode = "fixed" + weights pins those weights
        let doc = toml::parse(
            "[policy]\nmode = \"fixed\"\naws = 0.1\ngcp = 0.1\nazure = 0.8",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        c.apply_toml(&doc).unwrap();
        match c.policy {
            PolicyMode::Fixed(w) => assert_eq!(w.azure, 0.8),
            _ => panic!("expected fixed policy"),
        }
    }

    #[test]
    fn adaptive_mode_with_weights_is_a_conflict() {
        let doc = toml::parse(
            "[policy]\nmode = \"adaptive\"\naws = 0.5\ngcp = 0.3\nazure = 0.2",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn mistyped_values_rejected_not_silently_ignored() {
        for src in [
            "seed = \"7\"",
            "duration_days = true",
            "keepalive_s = 1.5",
            "[budget]\ntotal_usd = \"1000\"",
            "[budget]\nalerts = [0.5, \"0.25\"]",
            "[nat]\ndisabled = \"yes\"",
            "[outage]\nat_days = \"1\"",
            "[policy]\nmode = 3",
            "[policy]\naws = 0.5",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(
                c.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn lenient_ramp_parsing_is_gone() {
        // a dropped entry used to shift the target/hold pairing and an
        // all-mistyped list used to leave an empty (dead) ramp
        for src in [
            "[ramp]\ntargets = [100.5, 500]",
            "[ramp]\ntargets = []",
            "[ramp]\ntargets = [\"100\"]",
            "[ramp]\ntargets = [100]\nhold_days = [1.0, 2.0]",
            "[ramp]\ntargets = [100, 200]\nhold_days = [1.0, \"2\"]",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
        // fewer holds than targets still defaults the tail to 2 days
        let doc = toml::parse(
            "[ramp]\ntargets = [100, 200]\nhold_days = [1.0]",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.ramp[0].hold_s, DAY);
        assert_eq!(c.ramp[1].hold_s, 2 * DAY);
    }

    #[test]
    fn corrupting_casts_rejected_not_saturated() {
        // `f64 as u64` saturates negatives/NaN to 0 and +inf to
        // u64::MAX; `u64 as u32` truncates modulo 2^32.  Every one of
        // these used to parse Ok with a silently corrupted value.
        for src in [
            "duration_days = -1.0",
            "[outage]\nat_days = -3.0",
            "[outage]\nat_days = 1.0\nduration_hours = -2.0",
            "[outage]\nduration_hours = 2.0",
            "[ramp]\ntargets = [100]\nhold_days = [-1.0]",
            "[ramp]\ntargets = [4294967297]",
            "[onprem]\nslots = 4294967297",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
        // non-finite values have no TOML/JSON spelling, but the Json
        // tree can carry them (and the cast saturates them too)
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut doc = Json::obj();
            doc.set("duration_days", Json::from(v));
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "{v} must error");
        }
    }

    #[test]
    fn spec_helpers_guard_ranges() {
        assert_eq!(spec_seconds(2.0, DAY, "x").unwrap(), 2 * DAY);
        assert_eq!(spec_seconds(0.5, DAY, "x").unwrap(), DAY / 2);
        assert_eq!(spec_seconds(0.0, HOUR, "x").unwrap(), 0);
        assert!(spec_seconds(-0.5, DAY, "x").is_err());
        assert!(spec_seconds(f64::NAN, DAY, "x").is_err());
        assert!(spec_seconds(f64::INFINITY, HOUR, "x").is_err());
        // a duration that overflows u64 seconds is out of range, not
        // saturated
        assert!(spec_seconds(3.0e18, DAY, "x").is_err());
        assert_eq!(spec_u32(10, "x").unwrap(), 10);
        assert_eq!(spec_u32(u32::MAX as u64, "x").unwrap(), u32::MAX);
        let err = spec_u32(u32::MAX as u64 + 2, "x").unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn engine_knobs_from_toml() {
        let doc = toml::parse(
            "[engine]\nthreads = 4\nbunch = 1024\nsimd = \"off\"",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.engine.threads, 4);
        assert_eq!(c.engine.bunch, 1024);
        assert_eq!(c.engine.simd, SimdMode::Off);
        assert_eq!(c.engine.resolved_threads(), 4);
        assert_eq!(c.engine.plan().threads, 4);
        assert_eq!(c.engine.plan().bunch, 1024);
        assert_eq!(c.engine.plan().simd, SimdMode::Off);

        // the default is the lane sweep; "lanes" spells it explicitly
        let doc = toml::parse("[engine]\nsimd = \"lanes\"").unwrap();
        let mut c = CampaignConfig::default();
        c.engine.simd = SimdMode::Off;
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.engine.simd, SimdMode::Lanes);

        // mistyped, degenerate, or u32-truncating values are rejected,
        // not dropped (4294967296 = 2^32 would truncate to 0)
        for src in [
            "[engine]\nthreads = \"4\"",
            "[engine]\nbunch = 0",
            "[engine]\nbunch = 4294967296",
            "[engine]\nthreads = 4294967296",
            "[engine]\nsimd = \"avx\"",
            "[engine]\nsimd = 4",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
    }

    #[test]
    fn engine_default_is_auto() {
        let c = CampaignConfig::default();
        assert_eq!(c.engine.threads, 0);
        assert!(c.engine.resolved_threads() >= 1);
    }

    #[test]
    fn engine_clamp_respects_budget() {
        let mut e = EngineConfig { threads: 16, ..EngineConfig::default() };
        e.clamp_threads(4);
        assert_eq!(e.threads, 4);
        let mut e = EngineConfig { threads: 2, ..EngineConfig::default() };
        e.clamp_threads(4);
        assert_eq!(e.threads, 2);
        // auto resolves to a concrete count within budget
        let mut e = EngineConfig::default();
        e.clamp_threads(1);
        assert_eq!(e.threads, 1);
        // a zero budget still leaves one engine thread
        let mut e = EngineConfig { threads: 8, ..EngineConfig::default() };
        e.clamp_threads(0);
        assert_eq!(e.threads, 1);
    }

    #[test]
    fn engine_knobs_never_split_the_cache_key() {
        // the batched engine is bit-identical across these knobs, so
        // they are excluded from the canonical serialization
        let base = CampaignConfig::default().canonical_json().to_string_compact();
        let mut c = CampaignConfig::default();
        c.engine.threads = 7;
        c.engine.bunch = 128;
        c.engine.simd = SimdMode::Off;
        assert_eq!(base, c.canonical_json().to_string_compact());
    }

    #[test]
    fn canonical_json_is_stable_and_complete() {
        let a = CampaignConfig::default().canonical_json().to_string_compact();
        let b = CampaignConfig::default().canonical_json().to_string_compact();
        assert_eq!(a, b, "identical configs must serialize identically");
        // every replay-relevant scalar knob must appear by name
        for key in [
            "seed", "duration_s", "tick_s", "budget_usd", "keepalive_s",
            "preempt_multiplier", "nat_override", "checkpoint", "ramp",
            "outage", "policy", "onprem", "generator", "flops_per_bunch",
        ] {
            assert!(a.contains(&format!("\"{key}\"")), "missing {key}: {a}");
        }
    }

    #[test]
    fn canonical_json_distinguishes_configs() {
        let base = CampaignConfig::default().canonical_json().to_string_compact();
        let mut c = CampaignConfig::default();
        c.seed += 1;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::IdleTimeout(240);
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.outage = None;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::Adaptive;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::RiskAware;
        assert_ne!(base, c.canonical_json().to_string_compact());
        let mut c = CampaignConfig::default();
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 120,
        };
        assert_ne!(base, c.canonical_json().to_string_compact());
        // the two interval knobs split keys independently
        let mut d = CampaignConfig::default();
        d.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 60,
        };
        assert_ne!(
            c.canonical_json().to_string_compact(),
            d.canonical_json().to_string_compact()
        );
    }

    #[test]
    fn checkpoint_knobs_from_toml() {
        let doc = toml::parse(
            "[checkpoint]\nevery_s = 1800\nresume_overhead_s = 60",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.checkpoint,
            CheckpointPolicy::Interval { every_s: 1800, resume_overhead_s: 60 }
        );

        // overhead defaults when only the interval is given
        let doc = toml::parse("[checkpoint]\nevery_s = 600").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.checkpoint,
            CheckpointPolicy::Interval {
                every_s: 600,
                resume_overhead_s: DEFAULT_RESUME_OVERHEAD_S,
            }
        );

        // disabled = true forces the paper baseline over a set policy
        let doc = toml::parse("[checkpoint]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 60,
        };
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.checkpoint, CheckpointPolicy::None);

        // mistyped / degenerate / conflicting spellings are errors
        for src in [
            "[checkpoint]\nevery_s = 0",
            "[checkpoint]\nevery_s = \"1800\"",
            "[checkpoint]\nevery_s = 30.5",
            "[checkpoint]\nresume_overhead_s = 60",
            "[checkpoint]\ndisabled = true\nevery_s = 600",
            "[checkpoint]\ndisabled = \"yes\"",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut c = CampaignConfig::default();
            assert!(c.apply_toml(&doc).is_err(), "'{src}' must error");
        }
    }

    #[test]
    fn checkpoint_default_is_paper_baseline() {
        let c = CampaignConfig::default();
        assert_eq!(c.checkpoint, CheckpointPolicy::None);
        assert_eq!(c.checkpoint.resume_overhead_s(), 0);
        assert_eq!(c.checkpoint.salvageable(10_000), 0);
    }

    #[test]
    fn checkpoint_salvage_floors_to_interval() {
        let p = CheckpointPolicy::Interval {
            every_s: 600,
            resume_overhead_s: 120,
        };
        assert_eq!(p.salvageable(0), 0);
        assert_eq!(p.salvageable(599), 0);
        assert_eq!(p.salvageable(600), 600);
        assert_eq!(p.salvageable(1799), 1200);
        assert_eq!(p.resume_overhead_s(), 120);
    }

    #[test]
    fn risk_aware_policy_selectable_and_conflicts_with_weights() {
        let doc = toml::parse("[policy]\nmode = \"risk-aware\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.policy, PolicyMode::RiskAware);

        let doc = toml::parse(
            "[policy]\nmode = \"risk-aware\"\naws = 0.5\ngcp = 0.3\nazure = 0.2",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());

        // mode = "fixed" on a risk-aware policy without weights errors
        let doc = toml::parse("[policy]\nmode = \"fixed\"").unwrap();
        let mut c = CampaignConfig::default();
        c.policy = PolicyMode::RiskAware;
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn canonical_json_round_trips_through_parser() {
        let j = CampaignConfig::default().canonical_json();
        let parsed =
            crate::util::json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn server_knobs_from_toml() {
        let doc = toml::parse(
            "[server]\nqueue_max = 8\njob_runners = 3\ncache_mb = 16\n\
             store_dir = \"/var/lib/icecloud\"\njobs_keep = 16",
        )
        .unwrap();
        let mut s = ServerConfig::default();
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.queue_max, 8);
        assert_eq!(s.job_runners, 3);
        assert_eq!(s.cache_mb, 16);
        assert_eq!(s.store_dir.as_deref(), Some("/var/lib/icecloud"));
        assert_eq!(s.jobs_keep, 16);

        // the empty string is the explicit memory-only spelling
        let doc = toml::parse("[server]\nstore_dir = \"\"").unwrap();
        let mut s = ServerConfig::default();
        s.store_dir = Some("something".into());
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.store_dir, None);
    }

    #[test]
    fn server_defaults_are_sane() {
        let s = ServerConfig::default();
        assert!(s.queue_max >= 1);
        assert!(s.job_runners >= 1);
        assert!(s.cache_mb >= 1);
        assert_eq!(s.store_dir.as_deref(), Some("icecloud-store"));
        assert_eq!(s.jobs_keep, 1024);
        // a doc without a [server] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut t = ServerConfig::default();
        t.apply_toml(&doc).unwrap();
        assert_eq!(t, s);
    }

    #[test]
    fn mistyped_server_knobs_rejected_not_silently_ignored() {
        for src in [
            "[server]\nqueue_max = \"8\"",
            "[server]\nqueue_max = 0",
            "[server]\nqueue_max = 4294967296",
            "[server]\njob_runners = 0",
            "[server]\njob_runners = 1.5",
            "[server]\ncache_mb = 0",
            "[server]\ncache_mb = \"64\"",
            "[server]\nstore_dir = 7",
            "[server]\njobs_keep = 0",
            "[server]\njobs_keep = \"1024\"",
            "[server]\njobs_keep = 4294967296",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut s = ServerConfig::default();
            assert!(
                s.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn server_knobs_never_touch_the_campaign_cache_key() {
        // the [server] table rides in the same TOML file as the
        // campaign; applying it to CampaignConfig must be a no-op for
        // the canonical serialization (serving knobs cannot split the
        // result cache)
        let doc = toml::parse(
            "[server]\nqueue_max = 2\nstore_dir = \"x\"",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }

    /// Round-trip helper: `from_canonical_json` must reconstruct a
    /// config whose canonical form is byte-identical (no `PartialEq`
    /// on `CampaignConfig`; the canonical string IS its identity).
    fn assert_canonical_round_trip(c: &CampaignConfig) {
        let j = c.canonical_json();
        let back = CampaignConfig::from_canonical_json(&j).unwrap();
        assert_eq!(
            back.canonical_json().to_string_compact(),
            j.to_string_compact()
        );
    }

    #[test]
    fn canonical_json_inverts_for_every_variant() {
        assert_canonical_round_trip(&CampaignConfig::default());

        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::IdleTimeout(240);
        c.checkpoint = CheckpointPolicy::Interval {
            every_s: 1800,
            resume_overhead_s: 60,
        };
        c.outage = None;
        c.policy = PolicyMode::Adaptive;
        c.alert_thresholds = vec![0.9];
        assert_canonical_round_trip(&c);

        let mut c = CampaignConfig::default();
        c.nat_override = NatOverride::Disabled;
        c.policy = PolicyMode::RiskAware;
        c.real_compute = Some(RealComputeConfig {
            variant: "small".into(),
            every_n_completions: 100,
        });
        c.generator.request_memory_mb = 4096;
        c.ramp = vec![RampStep { target: 10, hold_s: DAY }];
        assert_canonical_round_trip(&c);
    }

    #[test]
    fn canonical_json_round_trip_survives_the_wire() {
        // the fleet sends the canonical form through the JSON parser
        let c = CampaignConfig::default();
        let wire = c.canonical_json().to_string_compact();
        let parsed = crate::util::json::parse(&wire).unwrap();
        let back = CampaignConfig::from_canonical_json(&parsed).unwrap();
        assert_eq!(back.canonical_json().to_string_compact(), wire);
    }

    #[test]
    fn from_canonical_json_is_strict() {
        let good = CampaignConfig::default().canonical_json();

        // wrong version
        let mut wrong_v = good.clone();
        wrong_v.set("v", Json::from(1u64));
        assert!(CampaignConfig::from_canonical_json(&wrong_v).is_err());

        // missing field
        let mut missing = good.clone();
        if let Json::Obj(m) = &mut missing {
            m.remove("keepalive_s");
        }
        assert!(CampaignConfig::from_canonical_json(&missing).is_err());

        // mistyped field
        let mut mistyped = good.clone();
        mistyped.set("budget_usd", Json::from("58000"));
        assert!(CampaignConfig::from_canonical_json(&mistyped).is_err());

        // malformed enum encodings
        for (key, bad) in [
            ("nat_override", Json::from("nope")),
            ("checkpoint", Json::from(3u64)),
            ("policy", Json::from("fixed")),
        ] {
            let mut doc = good.clone();
            doc.set(key, bad);
            assert!(
                CampaignConfig::from_canonical_json(&doc).is_err(),
                "malformed '{key}' must be rejected"
            );
        }
    }

    #[test]
    fn fleet_knobs_from_toml() {
        let doc = toml::parse(
            "[fleet]\nlease_ttl_s = 60\nheartbeat_every_s = 15\n\
             spot_check_rate = 0.5",
        )
        .unwrap();
        let mut f = FleetConfig::default();
        f.apply_toml(&doc).unwrap();
        assert_eq!(f.lease_ttl_s, 60);
        assert_eq!(f.heartbeat_every_s, 15);
        assert_eq!(f.spot_check_rate, 0.5);

        // a doc without a [fleet] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut f = FleetConfig::default();
        f.apply_toml(&doc).unwrap();
        assert_eq!(f, FleetConfig::default());
    }

    #[test]
    fn mistyped_fleet_knobs_rejected_not_silently_ignored() {
        for src in [
            "[fleet]\nlease_ttl_s = \"30\"",
            "[fleet]\nlease_ttl_s = 0",
            "[fleet]\nlease_ttl_s = 1.5",
            "[fleet]\nheartbeat_every_s = 0",
            "[fleet]\nheartbeat_every_s = true",
            "[fleet]\nspot_check_rate = \"0.1\"",
            "[fleet]\nspot_check_rate = -0.5",
            "[fleet]\nspot_check_rate = 1.5",
            // a heartbeat slower than the TTL would expire every lease
            "[fleet]\nlease_ttl_s = 10\nheartbeat_every_s = 10",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut f = FleetConfig::default();
            assert!(
                f.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn ops_knobs_from_toml() {
        let doc = toml::parse(
            "[ops]\nevents_ring = 64\nsample_every_s = 2",
        )
        .unwrap();
        let mut o = OpsConfig::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o.events_ring, 64);
        assert_eq!(o.sample_every_s, 2);

        // a doc without an [ops] table changes nothing
        let doc = toml::parse("seed = 7").unwrap();
        let mut o = OpsConfig::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o, OpsConfig::default());
    }

    #[test]
    fn ops_defaults_are_sane() {
        let o = OpsConfig::default();
        assert!(o.events_ring >= 1);
        assert!(o.sample_every_s >= 1);
    }

    #[test]
    fn mistyped_ops_knobs_rejected_not_silently_ignored() {
        for src in [
            "[ops]\nevents_ring = 0",
            "[ops]\nevents_ring = \"1024\"",
            "[ops]\nevents_ring = 1.5",
            "[ops]\nevents_ring = 4294967296",
            "[ops]\nsample_every_s = 0",
            "[ops]\nsample_every_s = true",
        ] {
            let doc = toml::parse(src).unwrap();
            let mut o = OpsConfig::default();
            assert!(
                o.apply_toml(&doc).is_err(),
                "'{src}' must be rejected, not dropped"
            );
        }
    }

    #[test]
    fn ops_knobs_never_touch_the_campaign_cache_key() {
        // the [ops] table rides in the same TOML file as the campaign;
        // applying it to CampaignConfig must be a no-op for the
        // canonical serialization (observation knobs cannot split the
        // result cache)
        let doc = toml::parse(
            "[ops]\nevents_ring = 2\nsample_every_s = 1",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }

    #[test]
    fn fleet_knobs_never_touch_the_campaign_cache_key() {
        let doc = toml::parse(
            "[fleet]\nlease_ttl_s = 5\nheartbeat_every_s = 1",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(
            c.canonical_json().to_string_compact(),
            CampaignConfig::default()
                .canonical_json()
                .to_string_compact()
        );
    }
}
