//! Campaign configuration: defaults that encode the paper's exercise,
//! overridable from a TOML file and CLI flags.

use crate::sim::{SimTime, DAY, HOUR, MINUTE};
use crate::util::json::Json;
use crate::util::toml;
use crate::workload::{GeneratorConfig, OnPremConfig};

/// One step of the operators' ramp plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampStep {
    /// Desired total cloud GPUs during this step.
    pub target: u32,
    /// How long to hold before advancing.
    pub hold_s: SimTime,
}

/// A scheduled network outage of the provider hosting the CE.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutageSpec {
    pub at_s: SimTime,
    pub duration_s: SimTime,
}

/// Provider preference weights (aws, gcp, azure order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProviderWeights {
    pub aws: f64,
    pub gcp: f64,
    pub azure: f64,
}

/// Target distribution policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyMode {
    /// Fixed provider weights (the paper's Azure-favoring choice).
    Fixed(ProviderWeights),
    /// Adapt weights to observed price and preemption rates.
    Adaptive,
}

/// Real-compute sampling: execute the AOT photon artifact for every Nth
/// completed job.
#[derive(Debug, Clone, PartialEq)]
pub struct RealComputeConfig {
    pub variant: String,
    pub every_n_completions: u64,
}

/// NAT behaviour override applied to every cloud region (scenario knob).
///
/// The paper's §IV incident hinges on Azure's default 4-minute NAT idle
/// timeout; sweeps use this to ask "what if the infrastructure had been
/// different" instead of only "what if our keepalive had been different".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NatOverride {
    /// Keep each provider's own NAT profile (Azure: 240 s idle timeout).
    #[default]
    ProviderDefault,
    /// Force an idle timeout of this many seconds on every region.
    IdleTimeout(u64),
    /// No NAT idle expiry anywhere (the fixed-infrastructure ablation).
    Disabled,
}

/// Everything the campaign runner needs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub seed: u64,
    pub duration_s: SimTime,
    pub tick_s: u64,
    pub sample_every_s: u64,
    /// Group/ledger/target reconciliation period.
    pub control_period_s: u64,
    pub negotiation_period_s: u64,

    pub budget_usd: f64,
    pub alert_thresholds: Vec<f64>,
    /// Non-instance costs (egress, disks, the CE VM) as a fraction of
    /// instance spend — the gap between GPU-hours x price and the paper's
    /// "all included" $58k.
    pub overhead_fraction: f64,
    /// Stop provisioning when remaining budget falls below this fraction.
    pub budget_reserve_fraction: f64,
    /// Resume after an outage at `post_outage_target` if the remaining
    /// budget fraction is at or below this (the paper's 1k-GPU decision).
    pub low_budget_resume_fraction: f64,
    pub post_outage_target: u32,

    /// Cloud worker keepalive (60 s = the post-incident tuned value;
    /// set 300 to re-live §IV).
    pub keepalive_s: u64,
    /// Multiplier on every region's baseline churn-preemption hazard
    /// (1.0 = the calibrated defaults; scenario sweeps raise it to model
    /// busier spot markets).
    pub preempt_multiplier: f64,
    /// NAT behaviour override applied to every region.
    pub nat_override: NatOverride,

    pub ramp: Vec<RampStep>,
    pub outage: Option<OutageSpec>,
    pub policy: PolicyMode,

    pub onprem: OnPremConfig,
    pub generator: GeneratorConfig,
    /// fp32 FLOPs per photon bunch (overridden from artifact metadata
    /// when real compute is enabled).
    pub flops_per_bunch: f64,
    pub real_compute: Option<RealComputeConfig>,
}

impl Default for CampaignConfig {
    /// The paper's two-week exercise.
    fn default() -> Self {
        CampaignConfig {
            seed: 20210921,
            duration_s: 14 * DAY,
            tick_s: MINUTE,
            sample_every_s: 10 * MINUTE,
            control_period_s: 5 * MINUTE,
            negotiation_period_s: 5 * MINUTE,
            budget_usd: 58_000.0,
            alert_thresholds: vec![0.75, 0.5, 0.25, 0.1],
            overhead_fraction: 0.18,
            budget_reserve_fraction: 0.02,
            low_budget_resume_fraction: 0.25,
            post_outage_target: 1000,
            keepalive_s: 60,
            preempt_multiplier: 1.0,
            nat_override: NatOverride::ProviderDefault,
            ramp: vec![
                // initial validation with a small fleet, then the paper's
                // 400 / 900 / 1.2k / 1.6k / 2k staircase
                RampStep { target: 50, hold_s: DAY },
                RampStep { target: 400, hold_s: 2 * DAY },
                RampStep { target: 900, hold_s: 2 * DAY },
                RampStep { target: 1200, hold_s: 2 * DAY },
                RampStep { target: 1600, hold_s: 2 * DAY },
                RampStep { target: 2000, hold_s: 30 * DAY }, // until outage
            ],
            outage: Some(OutageSpec {
                at_s: 11 * DAY + 6 * HOUR,
                duration_s: 2 * HOUR,
            }),
            policy: PolicyMode::Fixed(ProviderWeights {
                aws: 0.15,
                gcp: 0.15,
                azure: 0.70,
            }),
            onprem: OnPremConfig::default(),
            generator: GeneratorConfig::default(),
            flops_per_bunch: 1.2e10,
            real_compute: None,
        }
    }
}

impl CampaignConfig {
    /// Apply overrides from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &Json) -> Result<(), String> {
        if let Some(v) = doc.get_path(&["seed"]).and_then(Json::as_u64) {
            self.seed = v;
        }
        if let Some(v) = doc.get_path(&["duration_days"]).and_then(Json::as_f64) {
            self.duration_s = (v * DAY as f64) as SimTime;
        }
        if let Some(v) = doc.get_path(&["keepalive_s"]).and_then(Json::as_u64) {
            self.keepalive_s = v;
        }
        if let Some(v) =
            doc.get_path(&["preempt_multiplier"]).and_then(Json::as_f64)
        {
            self.preempt_multiplier = v;
        }
        let nat_disabled = doc
            .get_path(&["nat", "disabled"])
            .and_then(Json::as_bool)
            == Some(true);
        let nat_timeout =
            doc.get_path(&["nat", "idle_timeout_s"]).and_then(Json::as_u64);
        match (nat_disabled, nat_timeout) {
            (true, Some(_)) => {
                return Err("[nat] sets both disabled = true and \
                            idle_timeout_s; pick one"
                    .into())
            }
            (true, None) => self.nat_override = NatOverride::Disabled,
            (false, Some(t)) => {
                self.nat_override = NatOverride::IdleTimeout(t)
            }
            (false, None) => {}
        }
        if let Some(v) = doc.get_path(&["budget", "total_usd"]).and_then(Json::as_f64)
        {
            self.budget_usd = v;
        }
        if let Some(v) =
            doc.get_path(&["budget", "overhead_fraction"]).and_then(Json::as_f64)
        {
            self.overhead_fraction = v;
        }
        if let Some(arr) =
            doc.get_path(&["budget", "alerts"]).and_then(Json::as_arr)
        {
            self.alert_thresholds =
                arr.iter().filter_map(Json::as_f64).collect();
        }
        if let Some(v) = doc.get_path(&["onprem", "slots"]).and_then(Json::as_u64)
        {
            self.onprem.slots = v as u32;
        }
        if let Some(arr) = doc.get_path(&["ramp", "targets"]).and_then(Json::as_arr)
        {
            let holds = doc
                .get_path(&["ramp", "hold_days"])
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
                .unwrap_or_default();
            self.ramp = arr
                .iter()
                .filter_map(Json::as_u64)
                .enumerate()
                .map(|(i, t)| RampStep {
                    target: t as u32,
                    hold_s: (holds.get(i).copied().unwrap_or(2.0) * DAY as f64)
                        as SimTime,
                })
                .collect();
        }
        if let Some(at) = doc.get_path(&["outage", "at_days"]).and_then(Json::as_f64)
        {
            let dur = doc
                .get_path(&["outage", "duration_hours"])
                .and_then(Json::as_f64)
                .unwrap_or(2.0);
            self.outage = Some(OutageSpec {
                at_s: (at * DAY as f64) as SimTime,
                duration_s: (dur * HOUR as f64) as SimTime,
            });
        }
        if doc.get_path(&["outage", "disabled"]).and_then(Json::as_bool)
            == Some(true)
        {
            self.outage = None;
        }
        if let Some(mode) = doc.get_path(&["policy", "mode"]).and_then(Json::as_str)
        {
            self.policy = match mode {
                "adaptive" => PolicyMode::Adaptive,
                "fixed" => self.policy,
                other => return Err(format!("unknown policy mode '{other}'")),
            };
        }
        if let (Some(aws), Some(gcp), Some(azure)) = (
            doc.get_path(&["policy", "aws"]).and_then(Json::as_f64),
            doc.get_path(&["policy", "gcp"]).and_then(Json::as_f64),
            doc.get_path(&["policy", "azure"]).and_then(Json::as_f64),
        ) {
            self.policy = PolicyMode::Fixed(ProviderWeights { aws, gcp, azure });
        }
        Ok(())
    }

    /// Load from a TOML file over the defaults.
    pub fn from_toml_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        let doc = toml::parse(&text).map_err(|e| e.to_string())?;
        let mut cfg = CampaignConfig::default();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }

    /// Total ticks in the campaign.
    pub fn num_ticks(&self) -> u64 {
        self.duration_s / self.tick_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_encode_the_paper() {
        let c = CampaignConfig::default();
        assert_eq!(c.duration_s, 14 * DAY);
        assert_eq!(c.budget_usd, 58_000.0);
        let targets: Vec<u32> = c.ramp.iter().map(|s| s.target).collect();
        assert_eq!(targets, vec![50, 400, 900, 1200, 1600, 2000]);
        assert!(c.outage.is_some());
        match c.policy {
            PolicyMode::Fixed(w) => assert!(w.azure > w.aws && w.azure > w.gcp),
            _ => panic!("default policy is fixed Azure-favoring"),
        }
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::parse(
            r#"
seed = 7
duration_days = 2.0
keepalive_s = 300

[budget]
total_usd = 1000.0
alerts = [0.5]

[ramp]
targets = [10, 20]
hold_days = [0.5, 1.0]

[outage]
at_days = 1.0
duration_hours = 3.0

[policy]
aws = 0.2
gcp = 0.2
azure = 0.6
"#,
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.duration_s, 2 * DAY);
        assert_eq!(c.keepalive_s, 300);
        assert_eq!(c.budget_usd, 1000.0);
        assert_eq!(c.alert_thresholds, vec![0.5]);
        assert_eq!(c.ramp.len(), 2);
        assert_eq!(c.ramp[0], RampStep { target: 10, hold_s: DAY / 2 });
        assert_eq!(
            c.outage,
            Some(OutageSpec { at_s: DAY, duration_s: 3 * HOUR })
        );
        match c.policy {
            PolicyMode::Fixed(w) => assert_eq!(w.azure, 0.6),
            _ => panic!(),
        }
    }

    #[test]
    fn outage_can_be_disabled() {
        let doc = toml::parse("[outage]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert!(c.outage.is_none());
    }

    #[test]
    fn scenario_knobs_from_toml() {
        let doc = toml::parse(
            "preempt_multiplier = 4.0\n[nat]\nidle_timeout_s = 120",
        )
        .unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.preempt_multiplier, 4.0);
        assert_eq!(c.nat_override, NatOverride::IdleTimeout(120));

        let doc = toml::parse("[nat]\ndisabled = true").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.nat_override, NatOverride::Disabled);
    }

    #[test]
    fn conflicting_nat_knobs_rejected() {
        let doc =
            toml::parse("[nat]\ndisabled = true\nidle_timeout_s = 120")
                .unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn scenario_knob_defaults_are_neutral() {
        let c = CampaignConfig::default();
        assert_eq!(c.preempt_multiplier, 1.0);
        assert_eq!(c.nat_override, NatOverride::ProviderDefault);
    }

    #[test]
    fn adaptive_policy_selectable() {
        let doc = toml::parse("[policy]\nmode = \"adaptive\"").unwrap();
        let mut c = CampaignConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.policy, PolicyMode::Adaptive);
    }

    #[test]
    fn bad_policy_mode_rejected() {
        let doc = toml::parse("[policy]\nmode = \"nope\"").unwrap();
        let mut c = CampaignConfig::default();
        assert!(c.apply_toml(&doc).is_err());
    }
}
